"""Beyond-paper features: straggler masking, int8 grad compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.des import DESParams, simulate_spare
from repro.dist.collectives import compress_grad_int8, decompress_grad_int8


# ------------------------------------------------------------------ #
# straggler masking                                                   #
# ------------------------------------------------------------------ #
def test_straggler_masking_caps_slowdown_at_one_extra_stack():
    """The paper's early-all-reduce trigger doubles as straggler
    mitigation: a k-x slow group costs SPARe at most ONE extra stack
    (fast hosts supply its types at depth S_A+1), while synchronous
    DP/replication wait the full k-x. With 5% stragglers at 5x slowdown,
    SPARe's wall stays ~2x clean instead of ~5x."""
    p = DESParams(n=200, steps=300).with_(mtbf=1e12, jitter_std=0.0)
    clean = simulate_spare(p, r=9, seed=0)
    masked = simulate_spare(p, r=9, seed=0, straggler_frac=0.05,
                            straggler_slowdown=5.0)
    thin = simulate_spare(p, r=2, seed=0, straggler_frac=0.05,
                          straggler_slowdown=5.0)
    # masked cost bounded by the extra-stack policy, far below 5x
    assert masked.wall < clean.wall * 2.6
    # the extra stacks are genuinely paid (no free lunch at 5% incidence)
    assert masked.wall > clean.wall * 1.5
    # r=2 caps the covering depth at 2: double-slow chains force full
    # waits ~39% of steps — higher redundancy masks measurably better
    assert thin.wall > masked.wall * 1.2
    assert thin.wall < clean.wall * 5.0 * 0.8  # still beats waiting it out


def test_straggler_masking_under_failures_too():
    p = DESParams(n=200, steps=250)
    res = simulate_spare(p, r=9, seed=1, straggler_frac=0.05)
    assert res.steps_done >= 250  # completes


# ------------------------------------------------------------------ #
# int8 error-feedback compression                                     #
# ------------------------------------------------------------------ #
def test_compress_roundtrip_bounded_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
    err0 = jnp.zeros_like(g)
    q, scale, err = compress_grad_int8(g, err0)
    assert q.dtype == jnp.int8
    deq = decompress_grad_int8(q, scale)
    # quantization error bounded by one step
    assert float(jnp.abs(deq - g).max()) <= float(scale) + 1e-7
    # error feedback holds the residual exactly
    np.testing.assert_allclose(np.asarray(err), np.asarray(g - deq),
                               atol=1e-6)


def test_error_feedback_unbiased_over_steps():
    """Repeatedly compressing the same gradient with error feedback:
    the cumulative transmitted signal converges to the true sum (the
    long-run-unbiasedness property that makes EF-compression safe)."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    err = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    steps = 50
    for _ in range(steps):
        q, scale, err = compress_grad_int8(g, err)
        sent = sent + decompress_grad_int8(q, scale)
    rel = float(jnp.linalg.norm(sent / steps - g) / jnp.linalg.norm(g))
    assert rel < 0.01


def test_compression_ratio():
    g = jnp.zeros((1024, 1024), jnp.float32)
    q, scale, _ = compress_grad_int8(g, jnp.zeros_like(g))
    assert q.size * q.dtype.itemsize * 4 == g.size * g.dtype.itemsize


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_error_feedback_invariant_across_dtypes(dtype, seed):
    """The documented invariant ``restored + new_error == grad + error``
    must hold for non-fp32 grads too: the residual is computed in fp32
    (the dtype decompress returns), not in ``grad.dtype`` — a bf16
    residual silently lost ~1e-2 of relative signal per step."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(257,)) * 10.0 ** rng.integers(-3, 3),
                    dtype)
    err = jnp.asarray(rng.normal(size=(257,)) * 1e-3, jnp.float32)
    q, scale, new_err = compress_grad_int8(g, err)
    assert new_err.dtype == jnp.float32
    restored = decompress_grad_int8(q, scale)
    x = g.astype(jnp.float32) + err
    # the residual is exactly what the receiver is missing...
    np.testing.assert_array_equal(np.asarray(new_err),
                                  np.asarray(x - restored))
    # ...so the transmitted + residual signal reconstructs x to fp32
    # rounding of a single addition (half an ulp), not dtype rounding
    np.testing.assert_allclose(np.asarray(restored + new_err),
                               np.asarray(x),
                               rtol=1e-7, atol=float(scale) * 1e-6)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_error_feedback_unbiased_for_low_precision_grads(dtype):
    """EF long-run unbiasedness survives low-precision grads now that
    the residual no longer collapses to the grad dtype."""
    rng = np.random.default_rng(5)
    g32 = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    g = g32.astype(dtype)
    err = jnp.zeros((256,), jnp.float32)
    sent = jnp.zeros((256,), jnp.float32)
    steps = 50
    for _ in range(steps):
        q, scale, err = compress_grad_int8(g, err)
        sent = sent + decompress_grad_int8(q, scale)
    target = g.astype(jnp.float32)
    rel = float(jnp.linalg.norm(sent / steps - target)
                / jnp.linalg.norm(target))
    assert rel < 0.01
