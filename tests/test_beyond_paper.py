"""Beyond-paper features: straggler masking, int8 grad compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.des import DESParams, simulate_spare
from repro.dist.collectives import compress_grad_int8, decompress_grad_int8


# ------------------------------------------------------------------ #
# straggler masking                                                   #
# ------------------------------------------------------------------ #
def test_straggler_masking_caps_slowdown_at_one_extra_stack():
    """The paper's early-all-reduce trigger doubles as straggler
    mitigation: a k-x slow group costs SPARe at most ONE extra stack
    (fast hosts supply its types at depth S_A+1), while synchronous
    DP/replication wait the full k-x. With 5% stragglers at 5x slowdown,
    SPARe's wall stays ~2x clean instead of ~5x."""
    p = DESParams(n=200, steps=300).with_(mtbf=1e12, jitter_std=0.0)
    clean = simulate_spare(p, r=9, seed=0)
    masked = simulate_spare(p, r=9, seed=0, straggler_frac=0.05,
                            straggler_slowdown=5.0)
    thin = simulate_spare(p, r=2, seed=0, straggler_frac=0.05,
                          straggler_slowdown=5.0)
    # masked cost bounded by the extra-stack policy, far below 5x
    assert masked.wall < clean.wall * 2.6
    # the extra stacks are genuinely paid (no free lunch at 5% incidence)
    assert masked.wall > clean.wall * 1.5
    # r=2 caps the covering depth at 2: double-slow chains force full
    # waits ~39% of steps — higher redundancy masks measurably better
    assert thin.wall > masked.wall * 1.2
    assert thin.wall < clean.wall * 5.0 * 0.8  # still beats waiting it out


def test_straggler_masking_under_failures_too():
    p = DESParams(n=200, steps=250)
    res = simulate_spare(p, r=9, seed=1, straggler_frac=0.05)
    assert res.steps_done >= 250  # completes


# ------------------------------------------------------------------ #
# int8 error-feedback compression                                     #
# ------------------------------------------------------------------ #
def test_compress_roundtrip_bounded_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
    err0 = jnp.zeros_like(g)
    q, scale, err = compress_grad_int8(g, err0)
    assert q.dtype == jnp.int8
    deq = decompress_grad_int8(q, scale)
    # quantization error bounded by one step
    assert float(jnp.abs(deq - g).max()) <= float(scale) + 1e-7
    # error feedback holds the residual exactly
    np.testing.assert_allclose(np.asarray(err), np.asarray(g - deq),
                               atol=1e-6)


def test_error_feedback_unbiased_over_steps():
    """Repeatedly compressing the same gradient with error feedback:
    the cumulative transmitted signal converges to the true sum (the
    long-run-unbiasedness property that makes EF-compression safe)."""
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    err = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    steps = 50
    for _ in range(steps):
        q, scale, err = compress_grad_int8(g, err)
        sent = sent + decompress_grad_int8(q, scale)
    rel = float(jnp.linalg.norm(sent / steps - g) / jnp.linalg.norm(g))
    assert rel < 0.01


def test_compression_ratio():
    g = jnp.zeros((1024, 1024), jnp.float32)
    q, scale, _ = compress_grad_int8(g, jnp.zeros_like(g))
    assert q.size * q.dtype.itemsize * 4 == g.size * g.dtype.itemsize
