"""Closed-form theory vs the paper's own numbers (Tables 4-6, Thm 4.3)."""
import math

import pytest

from repro.core import theory


# Paper App. C theory columns (red columns of Tables 4-6): mu(N, r)
PAPER_MU = {
    (200, 2): 12.5, (200, 3): 30.5, (200, 9): 105.1, (200, 12): 123.2,
    (600, 2): 21.7, (600, 8): 254.0, (600, 20): 424.2,
    (1000, 2): 28.0, (1000, 9): 439.5, (1000, 26): 750.7,
}


@pytest.mark.parametrize("key,expected", sorted(PAPER_MU.items()))
def test_mu_matches_paper_tables(key, expected):
    n, r = key
    assert theory.mu(n, r) == pytest.approx(expected, abs=0.06)


def test_mu_poisson_sum_close_to_gamma_form():
    # Eq. 4: the integral (Gamma form) approximates the Poisson sum
    for n in (200, 600, 1000):
        for r in (3, 8, 12):
            s = theory.mu_poisson_sum(n, r)
            g = theory.mu(n, r)
            assert abs(s - g) / g < 0.02


def test_capacity_step_function():
    n = 600
    assert theory.capacity(0, n) == 1
    assert theory.capacity(1, n) == 2
    assert theory.capacity(n // 2, n) == 2
    assert theory.capacity(n // 2 + 1, n) == 3
    assert theory.capacity(2 * n // 3 + 1, n) == 4  # > 2N/3 -> c = 4


def test_patch_probability_bounds():
    n = 600
    for k in range(0, n - 1, 7):
        rho = theory.patch_probability(k, n)
        assert 0.0 <= rho <= 1.0


def test_s_bar_near_constant_paper_fig5():
    # Fig. 5: SPARe overhead stays ~2-2.8x even at r=20 (vs replication's r)
    for n in (200, 600, 1000):
        for r in range(3, 21):
            if r * (r - 1) > n - 1:
                continue
            s = theory.s_bar(n, r)
            assert 1.0 <= s <= 3.0, f"S_bar({n},{r})={s}"
    assert theory.s_bar(600, 20) == pytest.approx(2.8, abs=0.15)


def test_s_bar_lower_bound_relation():
    for n in (200, 600, 1000):
        for r in (3, 8, 12):
            assert theory.s_bar_lower(n, r) <= theory.s_bar(n, r)


# Paper App. C: E[S(U_k)] theory column == our Eq. 6 lower bound
PAPER_S_LOWER = {
    (200, 9): 2.03, (200, 12): 2.17,
    (600, 8): 1.99, (600, 20): 2.34,
    (1000, 9): 2.00, (1000, 26): 2.44,
}


@pytest.mark.parametrize("key,expected", sorted(PAPER_S_LOWER.items()))
def test_s_lower_matches_paper_tables(key, expected):
    n, r = key
    assert theory.s_bar_lower(n, r) == pytest.approx(expected, abs=0.02)


def test_tc_star_and_availability():
    # Eq. 1 closed form and its optimality (numerically perturb T_c)
    t_f, t_s, t_r = 300.0 * 254.0, 60.0, 3600.0
    t_c = theory.tc_star(t_f, t_s, t_r)
    assert t_c == pytest.approx(t_s + math.sqrt(t_s**2 + 2 * t_s * (t_f + t_r)))

    def avail(tc):
        return (t_f - t_f * t_s / tc) / (t_f + tc / 2.0 + t_r)

    a_star = theory.availability_star(t_f, t_s, t_r)
    assert a_star == pytest.approx(avail(t_c))
    for delta in (-0.1, 0.1):
        assert avail(t_c * (1 + delta)) <= a_star + 1e-12


def test_r_star_closed_form_thm43():
    # Thm. 4.3 numbers quoted in Sec. 5.2.2: r* = 8, 10, 10 at N=200/600/1000
    assert theory.r_star(200) == 8
    assert theory.r_star(600) == 10
    assert theory.r_star(1000) == 10


def test_r_star_search_agrees_with_closed_form_in_value():
    """J(r) is very flat near its minimum (the paper's own Table 2 empirical
    optima drift +-1-2 from Eq. 8). We assert *value* closeness: the closed
    form's J is within 5 % of the numerically optimal J, and both optima lie
    in the paper's operating band 4 <= r <= 14."""
    for n in (200, 600, 1000):
        num = theory.r_star_search(n)
        cf = theory.r_star(n)
        j_num = theory.j_normalized(num, n)
        j_cf = theory.j_normalized(cf, n)
        assert j_cf <= j_num * 1.08
        assert 4 <= num <= 14 and 4 <= cf <= 14


def test_j_curve_shape_paper_fig6():
    """J(r) decreases from r=2, reaches a minimum near r*, and the minimum
    beats traditional replication's J(r)=r/A by a wide margin."""
    n = 600
    js = {r: theory.j_normalized(r, n) for r in range(2, 21)}
    r_best = min(js, key=js.get)
    assert 4 <= r_best <= 14
    assert js[r_best] < 3.0  # paper Table 2: best SPARe+CKPT <= 2.92
