"""repro.elastic: degraded-continue between SPARe masking and restart.

Covers the ISSUE-9 acceptance points. Host-side pieces (the TTT policy,
divisor shrinking, EF row remapping, the sharding rule fitter, and the
injector outage clock) run everywhere; the mesh pieces are
``spmd``-marked (>= 8 devices, see tests/conftest.py) and prove:

* resharding is bit-transparent — params/Adam moments/EF residuals
  round-trip the full -> survivor -> full mesh byte-for-byte;
* a reshaped run continues bit-exactly as a from-scratch run at the
  shrunken shape (same seed, same schedule, same losses);
* an unmaskable burst continues degraded with ZERO wipe-outs and
  exactly one extra executable-cache entry (the new mesh shape);
* a later wipe-out restores the full mesh, and the adaptive scheme's
  ``decide_unmaskable`` is the live policy tier.
"""
import numpy as np
import pytest

from repro.configs import smoke_config


@pytest.fixture(scope="module")
def cfg():
    return smoke_config("qwen2.5-3b").scaled(grad_accum=1)


def _elastic(cfg, **kw):
    from repro.elastic import ElasticMeshExecutor
    kw.setdefault("n_groups", 8)
    kw.setdefault("redundancy", 2)
    kw.setdefault("model_degree", 1)
    kw.setdefault("seq", 32)
    kw.setdefault("per_type_batch", 2)
    kw.setdefault("total_steps", 24)
    kw.setdefault("t_reshape", 60.0)
    return ElasticMeshExecutor(cfg, **kw)


# ------------------------------------------------------------------ #
# host-side: TTT policy                                              #
# ------------------------------------------------------------------ #
def test_ttt_policy_prefers_reshape_when_restart_dearer():
    from repro.elastic import ttt_estimates
    est = ttt_estimates(dp_full=8, dp_new=4, remaining_steps=16,
                        seconds_per_step=64.0, rollback_steps=8,
                        t_restart=3600.0, t_reshape=60.0)
    # degraded finish: 60 + 16*64*(8/4) = 2108; restart: 3600 + 24*64
    assert est["reshape_ttt"] == pytest.approx(2108.0)
    assert est["restart_ttt"] == pytest.approx(5136.0)
    assert est["action"] == "reshape"


def test_ttt_policy_prefers_restart_near_no_survivors_or_cheap_restart():
    from repro.elastic import ttt_estimates
    # no viable submesh -> reshape is infinitely expensive
    est = ttt_estimates(dp_full=8, dp_new=0, remaining_steps=16,
                        seconds_per_step=64.0, t_restart=3600.0,
                        t_reshape=60.0)
    assert est["reshape_ttt"] == float("inf")
    assert est["action"] == "restart"
    # cheap restart + tiny submesh + long remaining run -> restart wins
    est = ttt_estimates(dp_full=8, dp_new=2, remaining_steps=1000,
                        seconds_per_step=64.0, rollback_steps=0,
                        t_restart=60.0, t_reshape=60.0)
    assert est["restart_ttt"] < est["reshape_ttt"]
    assert est["action"] == "restart"


def test_ttt_policy_tie_goes_to_reshape():
    from repro.elastic import ttt_estimates
    # identical outage + identical rework: prefer not losing progress
    est = ttt_estimates(dp_full=4, dp_new=2, remaining_steps=10,
                        seconds_per_step=10.0, rollback_steps=10,
                        t_restart=100.0, t_reshape=100.0)
    assert est["reshape_ttt"] == est["restart_ttt"]
    assert est["action"] == "reshape"


def test_shrink_degree_picks_largest_divisor():
    from repro.elastic import shrink_degree
    assert shrink_degree(8, 7) == 4
    assert shrink_degree(8, 6) == 4
    assert shrink_degree(8, 4) == 4
    assert shrink_degree(8, 3) == 2
    assert shrink_degree(8, 1) == 1
    assert shrink_degree(8, 0) == 0
    assert shrink_degree(6, 5) == 3


def test_adaptive_scheme_decide_unmaskable_records_estimates():
    from repro.des import get_scheme
    scheme = get_scheme("adaptive", r=2, initial="spare")
    action = scheme.decide_unmaskable(
        dp_full=8, dp_new=4, remaining_steps=16, seconds_per_step=64.0,
        rollback_steps=8, t_restart=3600.0, t_reshape=60.0)
    assert action == "reshape"
    assert scheme.unmaskable_decisions[-1]["action"] == "reshape"
    assert scheme.unmaskable_decisions[-1]["reshape_ttt"] == \
        pytest.approx(2108.0)


# ------------------------------------------------------------------ #
# host-side: EF row remapping                                        #
# ------------------------------------------------------------------ #
def test_remap_ef_rows_follows_physical_rows():
    from repro.elastic import remap_ef_rows
    B = 6
    old_rows = np.arange(8)
    err1 = np.arange(8 * B, dtype=np.float32)       # row i = [i*B, ...)
    ef = {"err1": [err1], "err2": [np.ones(B, np.float32)]}
    out = remap_ef_rows(ef, [B], old_rows, np.array([2, 3, 4, 5]))
    got = np.asarray(out["err1"][0]).reshape(4, B)
    for j, p in enumerate([2, 3, 4, 5]):
        np.testing.assert_array_equal(got[j], err1.reshape(8, B)[p])
    np.testing.assert_array_equal(np.asarray(out["err2"][0]),
                                  np.ones(B, np.float32))
    # growing back: surviving rows return to their slots, fresh rows zero
    back = remap_ef_rows(out, [B], np.array([2, 3, 4, 5]), old_rows)
    full = np.asarray(back["err1"][0]).reshape(8, B)
    for p in [2, 3, 4, 5]:
        np.testing.assert_array_equal(full[p], err1.reshape(8, B)[p])
    for p in [0, 1, 6, 7]:
        assert not full[p].any()


# ------------------------------------------------------------------ #
# host-side: sharding rule fitter                                    #
# ------------------------------------------------------------------ #
def test_sharding_fit_identity_on_original_shape(cfg):
    """The one rule table serves every mesh: fitting it to the original
    axis sizes changes nothing, and ``axis_sizes=None`` is the identity
    by construction."""
    import jax

    from repro.dist.sharding import param_specs
    from repro.models import build_model

    p_shapes = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
    base = param_specs(p_shapes, cfg, multi_pod=False)
    fitted = param_specs(p_shapes, cfg, multi_pod=False,
                         axis_sizes={"data": 4, "model": 2})
    assert jax.tree.map(tuple, base) == jax.tree.map(tuple, fitted)
    assert param_specs(p_shapes, cfg, multi_pod=False, axis_sizes=None) \
        == base


def test_sharding_fit_drops_nondividing_entries(cfg):
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import param_specs
    from repro.models import build_model

    p_shapes = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
    # a data degree no dimension divides: every "data" entry must fall
    # back to replicated instead of failing partitioning
    fitted = param_specs(p_shapes, cfg, multi_pod=False,
                         axis_sizes={"data": 7, "model": 1})
    for spec in jax.tree.leaves(fitted,
                                is_leaf=lambda x: isinstance(x, P)):
        for entry in spec:
            axes = entry if isinstance(entry, tuple) else (entry,)
            assert "data" not in axes
    # unknown axes pass through untouched
    loose = param_specs(p_shapes, cfg, multi_pod=False,
                        axis_sizes={"model": 2})
    assert jax.tree.map(tuple, loose) == \
        jax.tree.map(tuple, param_specs(p_shapes, cfg, multi_pod=False))


def test_mesh_axis_sizes_reads_any_mesh():
    import jax
    from jax.sharding import Mesh

    from repro.dist.sharding import mesh_axis_sizes

    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    assert mesh_axis_sizes(Mesh(devs, ("data", "model"))) == \
        {"data": 1, "model": 1}


# ------------------------------------------------------------------ #
# host-side: injector outage clock                                   #
# ------------------------------------------------------------------ #
def test_notify_outage_accounting_and_rearming():
    from repro.des.params import DESParams
    from repro.scenarios import ClusterTopology
    from repro.train.injection import ScenarioInjector

    topo = ClusterTopology(n_groups=8, hosts_per_group=2, hosts_per_rack=4)
    spec = {"kind": "poisson", "mtbf": 1e9}
    inj = ScenarioInjector(spec, topo, n_groups=8, seconds_per_step=100.0,
                           seed=0, params=DESParams(t_restart=3600.0))
    armed = inj._next_fail
    inj.notify_outage(60.0, kind="reshape")
    assert inj.clock == pytest.approx(60.0)
    assert inj.outage_seconds == pytest.approx(60.0)
    assert inj._next_fail == armed, \
        "a reshape outage must NOT re-arm the arrival model"
    inj.notify_outage(kind="restart")          # seconds default: t_restart
    assert inj.clock == pytest.approx(3660.0)
    assert inj.outage_seconds == pytest.approx(3660.0)
    assert inj._next_fail != armed, "a restart re-arms every group"


def test_notify_wipeout_is_the_restart_alias():
    from repro.des.params import DESParams
    from repro.scenarios import ClusterTopology
    from repro.train.injection import ScenarioInjector

    topo = ClusterTopology(n_groups=8, hosts_per_group=2, hosts_per_rack=4)
    a = ScenarioInjector({"kind": "poisson", "mtbf": 1e9}, topo, n_groups=8,
                         seconds_per_step=100.0, seed=0,
                         params=DESParams(t_restart=1234.0))
    b = ScenarioInjector({"kind": "poisson", "mtbf": 1e9}, topo, n_groups=8,
                         seconds_per_step=100.0, seed=0,
                         params=DESParams(t_restart=1234.0))
    a.notify_wipeout()
    b.notify_outage(1234.0, kind="restart")
    assert a.clock == b.clock == pytest.approx(1234.0)
    assert a.outage_seconds == b.outage_seconds


def test_scripted_injector_delivers_once_and_tracks_outage():
    from repro.core import SpareState
    from repro.train.injection import ScriptedInjector

    inj = ScriptedInjector({2: [0, 1]}, seconds_per_step=64.0)
    st = SpareState(8, 2)
    victims = []
    for _ in range(5):
        victims += [ev.victims for ev in inj.poll(st)]
    assert victims == [[0, 1]]
    assert inj.clock == pytest.approx(5 * 64.0)
    inj.notify_outage(60.0, kind="reshape")
    assert inj.clock == pytest.approx(5 * 64.0 + 60.0)
    assert inj.outage_seconds == pytest.approx(60.0)
    assert inj.events_delivered == 1
    assert inj.victims_delivered == 2


# ------------------------------------------------------------------ #
# spmd: bit-transparent resharding                                   #
# ------------------------------------------------------------------ #
@pytest.mark.spmd
def test_resharding_round_trips_bit_identical(cfg):
    """full -> survivor submesh -> full: params, Adam moments, and the
    surviving EF residual rows come back byte-for-byte."""
    import jax

    ex = _elastic(cfg, grad_compress="int8_ef")
    ex.run(3)                                   # make state nonzero
    host = lambda t: jax.tree.map(np.asarray, t)        # noqa: E731
    p0, o0, e0 = host(ex.params), host(ex.opt_state), host(ex._ef_state)

    ex.reshape([0, 1])                          # DP 8 -> 4 on rows 2..5
    assert ex.state.n == 4
    assert [int(r) for r in ex._logical_phys] == [2, 3, 4, 5]
    for a, b in zip(jax.tree.leaves(host(ex.params)), jax.tree.leaves(p0)):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(jax.tree.leaves(host(ex.opt_state)),
                    jax.tree.leaves(o0)):
        np.testing.assert_array_equal(a, b)
    # err1 rows followed their physical devices
    for b, size in enumerate(ex._layout.bucket_sizes):
        got = np.asarray(ex._ef_state["err1"][b]).reshape(4, size)
        ref = np.asarray(e0["err1"][b]).reshape(8, size)
        for j, p in enumerate([2, 3, 4, 5]):
            np.testing.assert_array_equal(got[j], ref[p])

    ex.restore_full_mesh()
    assert ex.state.n == 8
    for a, b in zip(jax.tree.leaves(host(ex.params)), jax.tree.leaves(p0)):
        np.testing.assert_array_equal(a, b)
    for b, size in enumerate(ex._layout.bucket_sizes):
        got = np.asarray(ex._ef_state["err1"][b]).reshape(8, size)
        ref = np.asarray(e0["err1"][b]).reshape(8, size)
        for p in [2, 3, 4, 5]:
            np.testing.assert_array_equal(got[p], ref[p])
    # shardings land where the full-mesh plumbing declares them
    assert ex.params["embed"].sharding == ex._pshard["embed"]
    ex.close()


@pytest.mark.spmd
def test_post_reshape_run_matches_from_scratch_shrunken_run(cfg):
    """A reshaped executor IS a fresh executor at the shrunken shape:
    same seed + same schedule => bit-identical losses, params, and EF
    residuals (spare_batch content is a pure function of (type, step))."""
    import jax

    from repro.exec import MeshExecutor

    elx = _elastic(cfg, grad_compress="int8_ef")
    elx.reshape([0, 1])
    rep_e = elx.run(3)

    ref = MeshExecutor(cfg, n_groups=4, redundancy=2, model_degree=1,
                       seq=32, per_type_batch=2, total_steps=24,
                       grad_compress="int8_ef")
    rep_r = ref.run(3)

    assert [float(x) for x in rep_e.losses] == \
        [float(x) for x in rep_r.losses]
    for a, b in zip(jax.tree.leaves(jax.tree.map(np.asarray, elx.params)),
                    jax.tree.leaves(jax.tree.map(np.asarray, ref.params))):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(jax.tree.leaves(jax.tree.map(np.asarray,
                                                 elx._ef_state)),
                    jax.tree.leaves(jax.tree.map(np.asarray,
                                                 ref._ef_state))):
        np.testing.assert_array_equal(a, b)
    elx.close()
    ref.close()


# ------------------------------------------------------------------ #
# spmd: the live degraded-continue loop                              #
# ------------------------------------------------------------------ #
@pytest.mark.spmd
def test_unmaskable_burst_continues_degraded_zero_wipeouts(cfg):
    """The tentpole scenario: an adjacent pair at n=8,r=2 (beyond
    RECTLR) reshapes to DP 4 and finishes — zero wipe-outs, zero
    rollback, and exactly one extra cache entry (the new mesh shape)."""
    from repro.train.injection import ScriptedInjector

    ex = _elastic(cfg, grad_compress="int8_ef")
    inj = ScriptedInjector({8: [0, 1]}, seconds_per_step=64.0)
    rep = ex.run(24, injector=inj, snapshot_every=10)

    assert rep.steps_done == 24
    assert rep.wipeouts == 0
    assert rep.reshapes == 1
    assert rep.rollback_steps == 0
    assert ex.state.n == 4
    assert all(np.isfinite(rep.losses))
    ev = [e for e in rep.events if e.reshape]
    assert len(ev) == 1
    assert (ev[0].dp_before, ev[0].dp_after) == (8, 4)
    assert ev[0].reshape_seconds == pytest.approx(60.0)
    assert not ev[0].wipeout, "a reshape is not a wipe-out"
    # one executable per (shape, depth) visited: (8,1)@S_A=1 + (4,1)@S_A=1
    assert sorted(k[:2] for k in ex.cache_keys) == [(4, 1), (8, 1)]
    assert rep.recompiles == 2
    # the outage clock took one reshape, no restart
    assert inj.outage_seconds == pytest.approx(60.0)
    # the policy tier chose reshape on live TTT numbers
    assert ex.policy_log[-1]["action"] == "reshape"
    assert ex.policy_log[-1]["reshape_ttt"] < \
        ex.policy_log[-1]["restart_ttt"]
    ex.close()


@pytest.mark.spmd
def test_cascading_failures_reshape_again(cfg):
    """Survivor submeshes are first-class: a second unmaskable burst on
    the shrunken mesh shrinks again (8 -> 4 -> 2) instead of wiping."""
    from repro.train.injection import ScriptedInjector

    ex = _elastic(cfg, grad_compress="int8_ef")
    inj = ScriptedInjector({4: [0, 1], 8: [2, 3]}, seconds_per_step=64.0)
    rep = ex.run(12, injector=inj, snapshot_every=4)
    assert rep.wipeouts == 0
    assert rep.reshapes == 2
    assert ex.state.n == 2
    assert ex.state.r == 1, "n=2 has no cyclic Golomb ruler at r=2"
    assert all(np.isfinite(rep.losses))
    ex.close()


@pytest.mark.spmd
def test_restart_after_reshape_restores_full_mesh(cfg):
    """When the policy picks restart while degraded, the global restart
    returns to the ORIGINAL mesh with its executables still cached."""
    ex = _elastic(cfg, grad_compress="int8_ef", t_reshape=60.0)
    ex.run(4, snapshot_every=4)
    keys_before = set(ex.cache_keys)
    ex.reshape([0, 1])
    ex.run(2)
    ex._global_restart()
    assert ex.state.n == 8
    assert ex._phys_alive.all()
    assert keys_before <= set(ex.cache_keys)
    rep = ex.run(2)
    assert all(np.isfinite(rep.losses))
    ex.close()


@pytest.mark.spmd
def test_adaptive_scheme_is_the_live_policy_tier(cfg):
    """With the adaptive scheme, reshape decisions flow through
    ``decide_unmaskable`` — the scheme's own decision log records the
    same TTT estimate the executor acted on."""
    from repro.des import get_scheme
    from repro.train.injection import ScriptedInjector

    scheme = get_scheme("adaptive", r=2, initial="spare")
    ex = _elastic(cfg, scheme=scheme)
    inj = ScriptedInjector({4: [0, 1]}, seconds_per_step=64.0)
    rep = ex.run(8, injector=inj, snapshot_every=4)
    assert rep.reshapes == 1
    assert rep.wipeouts == 0
    assert scheme.unmaskable_decisions, \
        "the decision must route through the scheme"
    assert scheme.unmaskable_decisions[-1]["action"] == "reshape"
    assert ex.policy_log[-1]["action"] == "reshape"
    ex.close()


@pytest.mark.spmd
def test_masking_still_first_resort(cfg):
    """A maskable failure never reaches the elastic tier: no reshape,
    no policy consult, no recompile at constant S_A beyond the depth."""
    from repro.train.injection import ScriptedInjector

    ex = _elastic(cfg)
    inj = ScriptedInjector({3: [0]}, seconds_per_step=64.0)
    rep = ex.run(8, injector=inj)
    assert rep.failures == 1
    assert rep.reshapes == 0
    assert rep.wipeouts == 0
    assert ex.policy_log == []
    assert ex.state.n == 8
    ex.close()
