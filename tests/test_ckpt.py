"""Checkpoint layer: save/restore round-trips, async writer, Eq.-1 interval."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.core.theory import mu, tc_star


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2, 2), jnp.bfloat16),
                  "d": jnp.zeros((5,), jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 7, t)
    step, restored = restore_checkpoint(tmp_path, t)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, restored)


def test_restore_latest_of_many(tmp_path):
    t = _tree()
    for s in (1, 5, 3):
        save_checkpoint(tmp_path, s, jax.tree.map(lambda x: x + s, t))
    step, restored = restore_checkpoint(tmp_path, t)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t["a"]) + 5)


def test_async_manager_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, n_groups=8, redundancy=3, mtbf=300,
                            t_save=60, t_restart=3600, keep=2)
    t = _tree()
    for s in range(4):
        assert mgr.maybe_save(s, t, force=True, block=True)
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2 and kept[-1] == "step_00000003"


def test_interval_is_eq1_optimal(tmp_path):
    n, r, m, ts, tr = 600, 8, 300.0, 60.0, 3600.0
    mgr = CheckpointManager(tmp_path, n_groups=n, redundancy=r, mtbf=m,
                            t_save=ts, t_restart=tr)
    assert mgr.interval == pytest.approx(tc_star(mu(n, r) * m, ts, tr))
    # SPARe redundancy lengthens the interval vs no masking
    base = tc_star(m, ts, tr)
    assert mgr.interval > 3 * base


def test_snapshot_survives_donation(tmp_path):
    """The in-memory tier must hold real host copies (donated device
    buffers get deleted under the snapshot otherwise — regression test)."""
    mgr = CheckpointManager(tmp_path, n_groups=8, redundancy=3, mtbf=300,
                            t_save=60, t_restart=3600)
    x = jnp.ones((4,), jnp.float32)
    mgr.snapshot(0, {"x": x})
    f = jax.jit(lambda v: v * 2, donate_argnums=0)
    _ = f(x)                              # donates/deletes x
    step, tree = mgr.rollback()
    np.testing.assert_array_equal(np.asarray(tree["x"]), np.ones((4,)))


def test_universal_restore_across_dtypes(tmp_path):
    """Leaves restore into the target structure's dtype/shape (enables
    elastic re-shard / parallelism-change restore)."""
    t = {"w": jnp.arange(8, dtype=jnp.float32)}
    save_checkpoint(tmp_path, 1, t)
    target = {"w": jnp.zeros((8,), jnp.float32)}
    _, restored = restore_checkpoint(tmp_path, target)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8))
