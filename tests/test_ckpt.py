"""Checkpoint layer: save/restore round-trips, async writer, Eq.-1 interval."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (CheckpointManager, restore_checkpoint,
                        save_checkpoint, sweep_stale_tmp)
from repro.core.theory import mu, tc_star


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2, 2), jnp.bfloat16),
                  "d": jnp.zeros((5,), jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 7, t)
    step, restored = restore_checkpoint(tmp_path, t)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, restored)


def test_restore_latest_of_many(tmp_path):
    t = _tree()
    for s in (1, 5, 3):
        save_checkpoint(tmp_path, s, jax.tree.map(lambda x: x + s, t))
    step, restored = restore_checkpoint(tmp_path, t)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t["a"]) + 5)


def test_async_manager_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, n_groups=8, redundancy=3, mtbf=300,
                            t_save=60, t_restart=3600, keep=2)
    t = _tree()
    for s in range(4):
        assert mgr.maybe_save(s, t, force=True, block=True)
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2 and kept[-1] == "step_00000003"


def test_interval_is_eq1_optimal(tmp_path):
    n, r, m, ts, tr = 600, 8, 300.0, 60.0, 3600.0
    mgr = CheckpointManager(tmp_path, n_groups=n, redundancy=r, mtbf=m,
                            t_save=ts, t_restart=tr)
    assert mgr.interval == pytest.approx(tc_star(mu(n, r) * m, ts, tr))
    # SPARe redundancy lengthens the interval vs no masking
    base = tc_star(m, ts, tr)
    assert mgr.interval > 3 * base


def test_snapshot_survives_donation(tmp_path):
    """The in-memory tier must hold real host copies (donated device
    buffers get deleted under the snapshot otherwise — regression test)."""
    mgr = CheckpointManager(tmp_path, n_groups=8, redundancy=3, mtbf=300,
                            t_save=60, t_restart=3600)
    x = jnp.ones((4,), jnp.float32)
    mgr.snapshot(0, {"x": x})
    f = jax.jit(lambda v: v * 2, donate_argnums=0)
    _ = f(x)                              # donates/deletes x
    step, tree = mgr.rollback()
    np.testing.assert_array_equal(np.asarray(tree["x"]), np.ones((4,)))


def test_crash_leftover_tmp_does_not_break_restore(tmp_path):
    """Regression: a crash mid-save must leave restore working. The old
    staging name ``step_<n>.tmp`` matched the ``step_*`` glob and made
    ``int("00000100.tmp")`` raise on every subsequent restore."""
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    # plant crash leftovers in both the legacy and the current form
    legacy = tmp_path / "step_00000100.tmp"
    legacy.mkdir()
    (legacy / "shard_0.npz").write_bytes(b"partial garbage")
    (tmp_path / ".tmp_step_00000002").mkdir()
    step, restored = restore_checkpoint(tmp_path, t)   # must not raise
    assert step == 1
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 t, restored)


def test_manager_sweeps_stale_tmp_on_init(tmp_path):
    save_checkpoint(tmp_path, 3, _tree())
    (tmp_path / ".tmp_step_00000004").mkdir()
    (tmp_path / "step_00000005.tmp").mkdir()
    (tmp_path / ".old_step_00000003").mkdir()
    mgr = CheckpointManager(tmp_path, n_groups=8, redundancy=3, mtbf=300,
                            t_save=60, t_restart=3600)
    assert not (tmp_path / ".tmp_step_00000004").exists()
    assert not (tmp_path / "step_00000005.tmp").exists()
    assert not (tmp_path / ".old_step_00000003").exists()
    step, _ = mgr.restore_latest(_tree())
    assert step == 3


def test_sweep_stale_tmp_leaves_real_checkpoints(tmp_path):
    save_checkpoint(tmp_path, 2, _tree())
    (tmp_path / ".tmp_step_00000009").mkdir()
    removed = sweep_stale_tmp(tmp_path)
    assert [p.name for p in removed] == [".tmp_step_00000009"]
    assert (tmp_path / "step_00000002").is_dir()


def test_crash_inside_overwrite_commit_recovers_parked_copy(tmp_path):
    """A crash between parking the old step dir and committing the new
    one must not lose the checkpoint: the sweep renames the complete
    parked copy back instead of deleting the only good copy."""
    t = _tree()
    save_checkpoint(tmp_path, 5, t)
    # simulate the crash window of a re-save of step 5: the committed
    # name is gone (parked), the staging dir holds the half-done new copy
    (tmp_path / "step_00000005").rename(tmp_path / ".old_step_00000005")
    (tmp_path / ".tmp_step_00000005").mkdir()
    # the bare restore API reads the parked copy in place (no rename —
    # a rename here could race a concurrent in-flight commit)...
    step, restored = restore_checkpoint(tmp_path, t)
    assert step == 5
    assert (tmp_path / ".old_step_00000005").is_dir()
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 t, restored)
    # ...and the manager's init sweep heals the name and clears the
    # staging leftover
    mgr = CheckpointManager(tmp_path, n_groups=8, redundancy=3, mtbf=300,
                            t_save=60, t_restart=3600)
    assert not (tmp_path / ".tmp_step_00000005").exists()
    assert not (tmp_path / ".old_step_00000005").exists()
    assert (tmp_path / "step_00000005").is_dir()
    step, restored = mgr.restore_latest(t)
    assert step == 5
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 t, restored)


def test_resave_same_step_after_rollback(tmp_path):
    """Re-saving a step the directory already holds (the wipe-out →
    rollback → retrain path) must replace it, not crash the rename."""
    t = _tree()
    save_checkpoint(tmp_path, 5, t)
    bumped = jax.tree.map(lambda x: x + 1, t)
    save_checkpoint(tmp_path, 5, bumped)               # must not raise
    step, restored = restore_checkpoint(tmp_path, t)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t["a"]) + 1)
    # no stray staging/parked dirs left behind
    assert [p.name for p in tmp_path.iterdir()] == ["step_00000005"]


def test_manager_resave_same_step(tmp_path):
    mgr = CheckpointManager(tmp_path, n_groups=8, redundancy=3, mtbf=300,
                            t_save=60, t_restart=3600)
    t = _tree()
    assert mgr.maybe_save(7, t, force=True, block=True)
    assert mgr.maybe_save(7, t, force=True, block=True)
    step, _ = mgr.restore_latest(t)
    assert step == 7 and mgr.saves == 2


def test_universal_restore_across_dtypes(tmp_path):
    """Leaves restore into the target structure's dtype/shape (enables
    elastic re-shard / parallelism-change restore)."""
    t = {"w": jnp.arange(8, dtype=jnp.float32)}
    save_checkpoint(tmp_path, 1, t)
    target = {"w": jnp.zeros((8,), jnp.float32)}
    _, restored = restore_checkpoint(tmp_path, target)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8))


def test_fixed_clock_resave_is_byte_identical(tmp_path):
    """With an injected clock, a checkpoint of the same tree is
    byte-identical down to the npz payloads — the manifest timestamp is
    the ONLY nondeterministic input to a save. Guards the injectable
    ``clock`` seam (and np.savez determinism) against regressions."""
    t = _tree()
    clock = lambda: 1726000000.0  # noqa: E731
    a = save_checkpoint(tmp_path / "a", 7, t, clock=clock)
    b = save_checkpoint(tmp_path / "b", 7, t, clock=clock)
    for name in ("manifest.json", "shard_0.npz"):
        assert (a / name).read_bytes() == (b / name).read_bytes(), name
    # default wall clock still stamps real provenance
    c = save_checkpoint(tmp_path / "c", 7, t)
    import json
    stamp = json.loads((c / "manifest.json").read_text())["time"]
    assert abs(stamp - time.time()) < 60.0  # lint: ignore[wall-clock] -- asserting the default IS wall time


def test_manager_threads_clock_to_manifest(tmp_path):
    mgr = CheckpointManager(tmp_path, n_groups=4, redundancy=2,
                            mtbf=300.0, t_save=1.0, t_restart=60.0,
                            clock=lambda: 42.0)
    mgr.maybe_save(3, _tree(), block=True, force=True)
    import json
    man = json.loads(
        (tmp_path / "step_00000003" / "manifest.json").read_text())
    assert man["time"] == 42.0


class _FakeMonotonic:
    """Injectable interval clock: advances only when told to."""

    def __init__(self, start: float = 1000.0):
        self.now = start

    def __call__(self) -> float:
        return self.now


def test_due_is_deterministic_with_injected_monotonic(tmp_path):
    """``due()`` must consult the injected monotonic clock, never the
    wall — the interval decision becomes a pure function of the fake."""
    fake = _FakeMonotonic()
    mgr = CheckpointManager(tmp_path, n_groups=8, redundancy=3, mtbf=300,
                            t_save=60, t_restart=3600, monotonic=fake)
    assert not mgr.due()
    fake.now += mgr.interval - 1e-6
    assert not mgr.due()
    fake.now += 2e-6                      # cross the Eq.-1 interval
    assert mgr.due()
    # a save re-arms the interval from the injected clock's reading
    assert mgr.maybe_save(1, _tree(), block=True)
    assert not mgr.due()
    fake.now += mgr.interval + 1.0
    assert mgr.due()
    # explicit `now` still wins over the injected clock
    assert not mgr.due(now=fake.now - mgr.interval)


def test_failed_background_save_is_captured_and_reraised(tmp_path, monkeypatch):
    """A background save that fails (even after its one retry) must not
    be silent: ``saves`` stays put, the interval clock rewinds so the
    next step re-attempts, and the error surfaces from the next
    ``wait()``/``maybe_save()`` on the training thread — chained to the
    original storage exception."""
    import repro.ckpt.checkpoint as ckpt_mod

    fake = _FakeMonotonic()
    mgr = CheckpointManager(tmp_path, n_groups=8, redundancy=3, mtbf=300,
                            t_save=60, t_restart=3600, monotonic=fake,
                            retry_backoff=0.0)
    attempts = []

    def boom(directory, step, tree, *, clock=None):
        attempts.append(step)
        raise OSError("disk full")

    monkeypatch.setattr(ckpt_mod, "save_checkpoint", boom)
    fake.now += mgr.interval + 1.0        # an interval elapses: save due
    assert mgr.due()
    assert mgr.maybe_save(1, _tree(), force=True)      # dispatch succeeds
    with pytest.raises(RuntimeError, match="background checkpoint save "
                                           "failed") as ei:
        mgr.wait()
    assert isinstance(ei.value.__cause__, OSError)
    assert attempts == [1, 1]             # original attempt + one retry
    assert mgr.saves == 0 and mgr.save_failures == 1
    assert mgr.due(), "failed save must rewind the interval clock"
    # the error does not re-raise twice, and recovery works: restore the
    # real writer and the next save commits + counts
    monkeypatch.setattr(ckpt_mod, "save_checkpoint", save_checkpoint)
    assert mgr.maybe_save(2, _tree(), force=True, block=True)
    assert mgr.saves == 1
    step, _ = mgr.restore_latest(_tree())
    assert step == 2


def test_failed_save_retry_succeeds_transparently(tmp_path, monkeypatch):
    """One transient failure + a good retry must look like a normal
    save: committed checkpoint, ``saves`` incremented, no error raised."""
    import repro.ckpt.checkpoint as ckpt_mod

    real = save_checkpoint
    calls = []

    def flaky(directory, step, tree, *, clock=None):
        calls.append(step)
        if len(calls) == 1:
            raise OSError("transient")
        return real(directory, step, tree, clock=clock or time.time)

    monkeypatch.setattr(ckpt_mod, "save_checkpoint", flaky)
    mgr = CheckpointManager(tmp_path, n_groups=8, redundancy=3, mtbf=300,
                            t_save=60, t_restart=3600, retry_backoff=0.0)
    assert mgr.maybe_save(4, _tree(), force=True, block=True)
    mgr.wait()                            # must not raise
    assert calls == [4, 4]
    assert mgr.saves == 1 and mgr.save_failures == 0
    step, _ = mgr.restore_latest(_tree())
    assert step == 4


def test_restore_reads_parked_old_step_directly(tmp_path):
    """``restore_checkpoint`` must read a ``.old_step_*`` park even when
    it is the ONLY copy of the newest step (mid-commit crash), and the
    next manager init must heal it back to the committed name."""
    t = _tree()
    save_checkpoint(tmp_path, 2, t)
    bumped = jax.tree.map(lambda x: x + 3, t)
    save_checkpoint(tmp_path, 9, bumped)
    # crash window: step 9's re-save parked the old copy and died before
    # committing the replacement
    (tmp_path / "step_00000009").rename(tmp_path / ".old_step_00000009")
    (tmp_path / ".tmp_step_00000009").mkdir()
    step, restored = restore_checkpoint(tmp_path, t)
    assert step == 9                      # park beats the older step 2
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t["a"]) + 3)
    # explicit-step restore reads the park too
    step, _ = restore_checkpoint(tmp_path, t, step=9)
    assert step == 9
    # next manager init sweeps: park healed, staging leftover gone
    mgr = CheckpointManager(tmp_path, n_groups=8, redundancy=3, mtbf=300,
                            t_save=60, t_restart=3600)
    assert (tmp_path / "step_00000009").is_dir()
    assert not (tmp_path / ".old_step_00000009").exists()
    assert not (tmp_path / ".tmp_step_00000009").exists()
    step, restored = mgr.restore_latest(t)
    assert step == 9
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t["a"]) + 3)
