"""RECTLR controller tests: Alg. 2 phases, Fig. 3 walkthrough, properties."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # pragma: no cover
    from _hypothesis_compat import given, settings, st

from repro.core import Rectlr, SpareState
from repro.core.theory import capacity


def make(n, r):
    return SpareState(n, r), Rectlr()


# ------------------------------------------------------------------ #
# paper Fig. 3 walkthrough (N=9, r=3)                                 #
# ------------------------------------------------------------------ #
def test_fig3_walkthrough():
    st_, ctl = make(9, 3)
    # (b) before any failure: all types collectible after the 1st stack
    assert st_.s_a == 1
    assert st_.prefix_coverage().all()

    # (c) group 1 fails: need 2nd stack
    out = ctl.on_failures(st_, [1])
    assert not out.wipeout
    assert st_.s_a == 2
    assert st_.prefix_coverage().all()

    # (d)/(e) group 2 fails later: type 2 lost from the 2nd stack, but
    # reordering keeps the all-reduce stack at 2 (no need for 3rd)
    out = ctl.on_failures(st_, [2])
    assert not out.wipeout
    assert st_.s_a == 2, "Fig. 3(e): reordering keeps S_A at 2"
    assert st_.prefix_coverage().all()
    st_.assert_invariants()


def test_wipeout_detection():
    st_, ctl = make(9, 3)
    hosts_of_0 = [int(w) for w in st_.hosts[0]]
    out = ctl.on_failures(st_, hosts_of_0)
    assert out.wipeout


def test_patch_compute_reported():
    st_, ctl = make(9, 3)
    # group 1's slot-0 type is 1 and it is the designated supplier of type 1
    out = ctl.on_failures(st_, [1])
    # type 1 must be patched (or re-designated) — supplier for every type
    # must be alive afterwards
    assert (st_.supplier[:, 0] != 1).all()
    for w, i in out.patch:
        assert st_.alive[w]
        assert i in set(map(int, st_.types[w]))


def test_reset_restores_pristine_state():
    st_, ctl = make(20, 4)
    ctl.on_failures(st_, [3])
    ctl.on_failures(st_, [7])
    st_.reset()
    assert st_.s_a == 1
    assert st_.alive.all()
    assert np.array_equal(st_.stacks, st_.types)
    st_.assert_invariants()


# ------------------------------------------------------------------ #
# property tests                                                      #
# ------------------------------------------------------------------ #
@given(st.data())
@settings(max_examples=40, deadline=None)
def test_random_failure_trails_maintain_invariants(data):
    n = data.draw(st.sampled_from([12, 20, 30, 42]))
    r = data.draw(st.sampled_from([3, 4, 5]))
    if r * (r - 1) > n - 1:
        return
    st_, ctl = make(n, r)
    order = data.draw(st.permutations(list(range(n))))
    k = 0
    for w in order:
        out = ctl.on_failures(st_, [int(w)])
        k += 1
        if out.wipeout:
            # verify wipe-out is real: some type has no surviving host
            st_.alive[w] = False
            assert (st_.surviving_host_counts() == 0).any() or out.hk_free_calls > 0
            break
        st_.assert_invariants()
        # all types collectible within the committed prefix
        assert st_.prefix_coverage().all()
        # S_A never below the capacity bound c(k) (Thm. 4.2)
        assert st_.s_a >= capacity(k, n) or st_.s_a == st_.r
        # weights: exactly one supplier per type, total = 1
        _, wts = st_.device_schedule()
        assert wts.sum() == pytest.approx(1.0)
        assert ((wts > 0).sum()) == n


@given(st.data())
@settings(max_examples=25, deadline=None)
def test_binary_search_hkfree_equivalent(data):
    """App. D acceleration: binary-search HK-FREE finds the same minimal
    all-reduce stack as the linear scan."""
    n = data.draw(st.sampled_from([20, 30]))
    r = 4
    lin_state, lin = SpareState(n, r), Rectlr(binary_search=False)
    bin_state, bin_ = SpareState(n, r), Rectlr(binary_search=True)
    order = data.draw(st.permutations(list(range(n))))
    for w in order[: n - 2]:
        o1 = lin.on_failures(lin_state, [int(w)])
        o2 = bin_.on_failures(bin_state, [int(w)])
        assert o1.wipeout == o2.wipeout
        if o1.wipeout:
            break
        assert lin_state.s_a == bin_state.s_a


def test_multi_failure_batch():
    st_, ctl = make(30, 4)
    out = ctl.on_failures(st_, [0, 5, 11])
    if not out.wipeout:
        st_.assert_invariants()
        assert st_.prefix_coverage().all()
        assert st_.failure_count == 3


def test_controller_speed_n1000():
    """Paper App. D claims sub-100ms at N ~ 1e3; we assert the same bound
    for a single failure event on the realistic (N=1000, r=10) config."""
    st_, ctl = make(1000, 10)
    out = ctl.on_failures(st_, [123])
    assert out.controller_seconds < 0.1, f"RECTLR took {out.controller_seconds:.3f}s"


def test_gradient_equivalence_weights():
    """The §3.1 invariant: whatever the reordering, the weighted psum
    reconstructs exactly (1/N) sum_i g_i. We emulate gradients as one-hot
    vectors per type and check the weighted collection."""
    n, r = 24, 4
    st_, ctl = make(n, r)
    rng = np.random.default_rng(0)
    for w in rng.permutation(n)[:10]:
        out = ctl.on_failures(st_, [int(w)])
        if out.wipeout:
            break
        stack_types, weights = st_.device_schedule()
        # emulate: g_i = e_i; group w's contribution = sum_j wts[w,j]*e_{type}
        collected = np.zeros(n)
        for g in range(n):
            for j in range(st_.s_a):
                collected[stack_types[g, j]] += weights[g, j]
        np.testing.assert_allclose(collected, np.full(n, 1.0 / n), atol=1e-12)
