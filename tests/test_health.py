"""Gray-failure tier: fail-slow models, straggler detection, demotion.

Layers, bottom-up:

* fail-slow failure models: deterministic episode streams, blast-radius
  scoping, flaky-link self-healing, the shared one-draw-per-event
  window drain;
* injector slow channel: step-window inflation under the sync barrier,
  demotion removing a straggler's factor from the window max, episode
  expiry, restart clearing, and — critically — adding a slow channel
  never perturbing the kill stream's pinned draw order;
* the online detector: robust flagging within the dwell window,
  hysteresis (no flap in the dead band), warmup, dead-group handover to
  fail-stop recovery, and bit-determinism over identical streams;
* the closed-form degraded-TTT policy and the adaptive scheme's
  ``decide_degraded`` hook;
* trainer integration: detector -> demote (pure weight-table edit) ->
  bit-identical re-admission on heal, plus restart hygiene;
* serving: detector-weighted routing steers traffic around a flagged
  replica without dropping requests;
* (spmd) the demote round trip on the 8-device emulated mesh with both
  stacking depths pre-warmed: zero run-attributed recompiles.
"""
import math

import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.state import SpareState
from repro.des import DESParams, get_scheme
from repro.health import StragglerDetector, degraded_ttt_estimates
from repro.scenarios import (FailSlowModel, FlakyLinkModel,
                             drain_slow_window, model_from_spec)
from repro.scenarios.topology import ClusterTopology
from repro.train.injection import ScenarioInjector, ScriptedInjector
from repro.train.trainer import SpareTrainer, TrainReport


# ------------------------------------------------------------------ #
# fail-slow models                                                   #
# ------------------------------------------------------------------ #
def _bound(model, n=8, seed=0, topology=None):
    rng = np.random.default_rng(seed)
    model.bind(DESParams(n=n), rng, topology)
    return model


def test_fail_slow_registry_and_episode_shape():
    m = model_from_spec({"kind": "fail_slow", "mtbs": 100.0,
                         "factor_min": 2.0, "factor_max": 4.0})
    assert isinstance(m, FailSlowModel)
    _bound(m)
    t = m.next_arrival(0.0, 8, 8)
    assert t > 0.0
    groups, factor, until = m.draw_episode(t, set())
    assert len(groups) == 1 and 0 <= groups[0] < 8
    assert 2.0 <= factor <= 4.0
    assert until == math.inf               # persistent until repaired
    assert m.draw_victims(t, set()) == []  # slow streams never kill


def test_fail_slow_stream_is_deterministic():
    a = _bound(FailSlowModel(mtbs=50.0), seed=3)
    b = _bound(FailSlowModel(mtbs=50.0), seed=3)
    slowed_a, slowed_b = set(), set()
    for _ in range(6):
        ta, tb = a.next_arrival(0.0, 8, 8), b.next_arrival(0.0, 8, 8)
        assert ta == tb
        ea, eb = a.draw_episode(ta, slowed_a), b.draw_episode(tb, slowed_b)
        assert ea == eb
        slowed_a.update(ea[0])
        slowed_b.update(eb[0])


def test_fail_slow_scope_slows_whole_blast_radius():
    topo = ClusterTopology(n_groups=8, hosts_per_group=2, hosts_per_rack=4)
    m = _bound(FailSlowModel(scope="rack"), topology=topo)
    groups, _, _ = m.draw_episode(1.0, set())
    assert len(groups) == 2                # 2 groups/rack in this layout
    # the seed's whole rack, i.e. an adjacent pair
    assert sorted(groups) in ([0, 1], [2, 3], [4, 5], [6, 7])


def test_flaky_link_episodes_self_heal():
    m = _bound(FlakyLinkModel(mtbs=100.0, episode_len=30.0), seed=1)
    groups, factor, until = m.draw_episode(500.0, set())
    assert 1.5 <= factor <= 3.0
    assert math.isfinite(until) and until > 500.0


def test_drain_slow_window_delivers_in_window_episodes():
    m = _bound(FailSlowModel(mtbs=10.0), seed=5)
    slowed: set[int] = set()
    nxt = m.next_arrival(0.0, 8, 8)
    episodes, nxt2 = drain_slow_window(m, nxt, nxt + 100.0, slowed)
    assert episodes, "a 10s-MTBS stream must land events in 100s"
    for t, groups, factor, until in episodes:
        assert t <= nxt + 100.0
        assert set(groups) <= slowed       # drain mutates `slowed`
        assert factor >= 2.0 and until == math.inf
    assert nxt2 > nxt + 100.0


def test_slow_channel_does_not_perturb_kill_stream():
    """The slow model runs on its own RNG (seed+1): the kill stream's
    draw order is pinned regardless of the slow channel."""
    topo = ClusterTopology(n_groups=8, hosts_per_group=1, hosts_per_rack=2)

    def kills(slow_model):
        inj = ScenarioInjector({"kind": "poisson", "mtbf": 200.0}, topo,
                               n_groups=8, seconds_per_step=64.0, seed=9,
                               slow_model=slow_model)
        st = SpareState(8, 2)
        out = []
        for _ in range(40):
            for ev in inj.poll(st):
                out.append((round(ev.time, 9), tuple(ev.victims)))
        return out, inj

    plain, _ = kills(None)
    assert plain, "no kills in 40 windows — comparison is vacuous"
    # an attached-but-idle slow stream (arrivals beyond the horizon)
    # must leave the kill stream bit-identical: windows, times, victims
    idle, inj_idle = kills({"kind": "fail_slow", "mtbs": 1e9})
    assert inj_idle.slow_events_delivered == 0
    assert plain == idle
    # an *active* slow stream stretches windows (more sim time per
    # poll, so later kills re-draw against different drain states —
    # intended renewal physics, not RNG perturbation): everything up to
    # the first inflated window must still be bit-identical, and the
    # slow run must have covered strictly more sim time
    busy, inj_busy = kills({"kind": "fail_slow", "mtbs": 300.0})
    assert inj_busy.slow_events_delivered > 0
    first_inflated = next(
        i for i, w in enumerate(inj_busy.window_log) if w > 64.0)
    boundary = 64.0 * first_inflated
    assert [e for e in busy if e[0] < boundary] == \
        [e for e in plain if e[0] < boundary]
    assert inj_busy.clock > 40 * 64.0


def test_scenario_injector_rejects_fail_stop_slow_model():
    with pytest.raises(TypeError):
        ScenarioInjector({"kind": "poisson"}, None, n_groups=4,
                         slow_model={"kind": "poisson"})


# ------------------------------------------------------------------ #
# injector slow channel (scripted)                                   #
# ------------------------------------------------------------------ #
def test_scripted_slow_window_inflation_and_expiry():
    inj = ScriptedInjector({}, seconds_per_step=10.0,
                           slow_schedule={2: [(1, 3.0, 5)]}, n_groups=4)
    st = SpareState(4, 2)
    for _ in range(8):
        inj.poll(st)
    # windows 2..4 inflate 3x; the episode expires at poll 5
    assert inj.window_log == [10.0, 10.0, 30.0, 30.0, 30.0,
                              10.0, 10.0, 10.0]
    assert inj.clock == sum(inj.window_log)


def test_scripted_demotion_removes_factor_from_barrier():
    inj = ScriptedInjector({}, seconds_per_step=10.0,
                           slow_schedule={0: [(2, 4.0, None)]}, n_groups=4)
    st = SpareState(4, 2)
    inj.poll(st)
    assert inj.last_step_seconds == 40.0
    inj.notify_demoted([2])
    inj.poll(st)
    assert inj.last_step_seconds == 10.0   # straggler out of the barrier
    assert inj.slow_factor(2) == 4.0       # still tracked for re-admit
    np.testing.assert_array_equal(inj.group_step_seconds(),
                                  [10.0, 10.0, 40.0, 10.0])
    inj.notify_demoted([2], flag=False)
    inj.poll(st)
    assert inj.last_step_seconds == 40.0   # re-admitted, still slow


def test_scripted_restart_clears_slow_state():
    inj = ScriptedInjector({}, seconds_per_step=10.0,
                           slow_schedule={0: [(0, 5.0, None)]}, n_groups=4)
    st = SpareState(4, 2)
    inj.poll(st)
    inj.notify_demoted([0])
    inj.notify_outage(100.0, kind="restart")
    assert inj.slow_factor(0) == 1.0 and not inj.demoted
    inj.poll(st)
    assert inj.last_step_seconds == 10.0


def test_dead_group_does_not_inflate_window():
    inj = ScriptedInjector({}, seconds_per_step=10.0,
                           slow_schedule={0: [(3, 9.0, None)]}, n_groups=4)
    st = SpareState(4, 2)
    st.alive[3] = False
    inj.poll(st)
    assert inj.last_step_seconds == 10.0


# ------------------------------------------------------------------ #
# detector                                                           #
# ------------------------------------------------------------------ #
def _stream(det, slow_group=None, factor=3.0, n=8, steps=12, base=64.0):
    reports = []
    for _ in range(steps):
        x = np.full(n, base)
        if slow_group is not None:
            x[slow_group] *= factor
        reports.append(det.observe(x))
    return reports


def test_detector_flags_within_dwell_window():
    det = StragglerDetector(8)
    x = np.full(8, 64.0)
    for _ in range(4):                     # healthy warm-up
        det.observe(x)
    slow = x.copy()
    slow[2] *= 3.0
    flagged_at = None
    for i in range(10):
        hr = det.observe(slow)
        if hr.flagged:
            flagged_at = i
            break
    # EWMA(0.4) crosses 1.5x in 2 samples; +min_dwell(3) => flag by ~5
    assert flagged_at is not None and flagged_at <= det.min_dwell + 2
    assert det.flagged == (2,)
    assert det.estimated_factor(2) > 2.0


def test_detector_is_deterministic():
    a = StragglerDetector(8)
    b = StragglerDetector(8)
    rng = np.random.default_rng(0)
    xs = 64.0 * (1.0 + 0.01 * rng.standard_normal((20, 8)))
    xs[8:, 5] *= 2.5
    for x in xs:
        ra, rb = a.observe(x), b.observe(x)
        assert ra.flagged == rb.flagged
        np.testing.assert_array_equal(ra.smoothed, rb.smoothed)
        np.testing.assert_array_equal(ra.zscores, rb.zscores)


def test_detector_hysteresis_no_flap_in_dead_band():
    """A group hovering between clear_factor and flag_factor must hold
    its current state — neither flag nor clear churn."""
    det = StragglerDetector(8, ewma_alpha=1.0)
    _stream(det, slow_group=1, factor=3.0, steps=6)
    assert det.flagged == (1,)
    x = np.full(8, 64.0)
    x[1] *= 1.35        # inside (clear_factor=1.2, flag_factor=1.5)
    for _ in range(6):
        hr = det.observe(x)
        assert hr.flagged == (1,), "dead band must hold the flag"
    x[1] = 64.0                           # fully healed
    cleared_at = None
    for i in range(6):
        hr = det.observe(x)
        if not hr.flagged:
            cleared_at = i
            break
    assert cleared_at is not None and cleared_at + 1 >= det.clear_dwell
    assert hr.newly_cleared == (1,)


def test_detector_warmup_suppresses_flags():
    det = StragglerDetector(8, warmup=4, min_dwell=1)
    for i in range(4):
        x = np.full(8, 64.0)
        x[0] *= 5.0
        hr = det.observe(x)
        assert not hr.flagged, f"flagged during warmup at obs {i}"
    hr = det.observe(x)
    assert hr.flagged == (0,)


def test_detector_dead_group_unflags_immediately():
    det = StragglerDetector(8, ewma_alpha=1.0)
    _stream(det, slow_group=3, factor=3.0, steps=6)
    assert det.flagged == (3,)
    alive = np.ones(8, bool)
    alive[3] = False                       # fail-stop took it
    hr = det.observe(np.full(8, 64.0), alive=alive)
    assert hr.flagged == () and hr.newly_cleared == (3,)


def test_detector_rejects_bad_shapes_and_params():
    det = StragglerDetector(4)
    with pytest.raises(ValueError):
        det.observe(np.ones(5))
    with pytest.raises(ValueError):
        StragglerDetector(4, ewma_alpha=0.0)
    with pytest.raises(ValueError):
        StragglerDetector(4, flag_z=2.0, clear_z=3.0)
    with pytest.raises(ValueError):
        StragglerDetector(4, min_dwell=0)


def test_detector_reset_forgets_history():
    det = StragglerDetector(8, ewma_alpha=1.0)
    _stream(det, slow_group=0, factor=3.0, steps=6)
    assert det.flagged
    det.reset()
    assert det.flagged == () and det.observations == 0 and not det.reports


# ------------------------------------------------------------------ #
# degraded-TTT policy                                                #
# ------------------------------------------------------------------ #
def _factors(n=8, slow=None, factor=3.0):
    f = np.ones(n)
    if slow is not None:
        f[slow] = factor
    return f


def test_policy_demote_wins_on_maskable_straggler():
    est = degraded_ttt_estimates(
        factors=_factors(slow=0), candidates=[0], remaining_steps=100,
        seconds_per_step=64.0, dp_full=8, maskable=True,
        t_restart=3600.0, t_reshape=60.0)
    assert est["action"] == "demote"
    assert est["tolerate_ttt"] == pytest.approx(100 * 64.0 * 3.0)
    assert est["demote_ttt"] == pytest.approx(100 * 64.0)
    assert est["max_factor"] == 3.0 and est["surviving_factor"] == 1.0


def test_policy_tolerate_wins_when_barely_slow():
    # 1.05x slowdown: tolerate == R*s*1.05; demote pays nothing less
    # than R*s but tolerate's TTT ties demote only if factor == 1.0 —
    # make demote cost a nonzero t_demote so tolerate wins outright
    est = degraded_ttt_estimates(
        factors=_factors(slow=0, factor=1.05), candidates=[0],
        remaining_steps=10, seconds_per_step=1.0, dp_full=8,
        maskable=True, t_demote=5.0, t_restart=3600.0, t_reshape=60.0)
    assert est["action"] == "tolerate"


def test_policy_tiebreak_is_least_disruptive():
    # factor exactly 1.0 everywhere: tolerate and demote TTTs tie;
    # the tie must break toward tolerate (tolerate > demote > ...)
    est = degraded_ttt_estimates(
        factors=_factors(), candidates=[0], remaining_steps=10,
        seconds_per_step=1.0, dp_full=8, maskable=True,
        t_restart=1e9, t_reshape=1e9)
    assert est["tolerate_ttt"] == est["demote_ttt"]
    assert est["action"] == "tolerate"


def test_policy_restart_when_unmaskable_and_no_reshape():
    est = degraded_ttt_estimates(
        factors=_factors(slow=0, factor=100.0), candidates=[0],
        remaining_steps=100, seconds_per_step=64.0, dp_full=8,
        dp_new=0, maskable=False, rollback_steps=5,
        t_restart=600.0, t_reshape=60.0)
    assert est["demote_ttt"] == math.inf
    assert est["reshape_ttt"] == math.inf
    assert est["action"] == "restart"
    assert est["restart_ttt"] == pytest.approx(600.0 + 105 * 64.0)


def test_policy_reshape_when_unmaskable_but_shrinkable():
    est = degraded_ttt_estimates(
        factors=_factors(slow=0, factor=100.0), candidates=[0],
        remaining_steps=100, seconds_per_step=64.0, dp_full=8,
        dp_new=4, maskable=False, t_restart=1e9, t_reshape=60.0)
    assert est["action"] == "reshape"
    assert est["reshape_ttt"] == pytest.approx(60.0 + 100 * 64.0 * 2.0)


def test_policy_demote_respects_demoted_barrier():
    # group 1 already demoted: its factor must not count toward the
    # barrier pace, and demoting 0 leaves survivors at 1.0
    f = _factors(slow=0, factor=3.0)
    f[1] = 10.0
    est = degraded_ttt_estimates(
        factors=f, candidates=[0], remaining_steps=10,
        seconds_per_step=1.0, dp_full=8, demoted=[1], maskable=True,
        t_restart=3600.0, t_reshape=60.0)
    assert est["max_factor"] == 3.0
    assert est["surviving_factor"] == 1.0
    assert est["action"] == "demote"


def test_adaptive_scheme_decide_degraded_logs():
    scheme = get_scheme("adaptive", r=2, initial="spare")
    scheme.prepare(DESParams(n=8))
    action = scheme.decide_degraded(
        factors=_factors(slow=0), candidates=[0], remaining_steps=100,
        seconds_per_step=64.0, dp_full=8, maskable=True,
        t_restart=3600.0)
    assert action == "demote"
    assert scheme.degraded_decisions
    assert scheme.degraded_decisions[-1]["action"] == "demote"


# ------------------------------------------------------------------ #
# trainer integration                                                #
# ------------------------------------------------------------------ #
def test_trainer_demote_and_bit_identical_readmit():
    """The full gray round trip on the emulation trainer: detector
    flags the scripted 3x straggler, the policy demotes it (SPARe
    weight-table edit), and on heal the group is re-admitted with the
    weight table bit-identical to a never-demoted run."""
    cfg = smoke_config("qwen2.5-3b").scaled(grad_accum=1)
    det = StragglerDetector(8)
    tr = SpareTrainer(cfg, n_groups=8, redundancy=2, seq=32,
                      per_type_batch=1, total_steps=64, detector=det)
    inj = ScriptedInjector({}, seconds_per_step=64.0,
                           slow_schedule={4: [(0, 3.0, 16)]}, n_groups=8)
    rep = tr.run(32, injector=inj)

    assert rep.steps_done == 32 and rep.wipeouts == 0
    assert rep.demotes == 1 and rep.readmits == 1
    dem = next(e for e in rep.events if e.demote)
    adm = next(e for e in rep.events if e.readmit)
    assert dem.victims == [0] and dem.slow_factor > 2.0
    assert dem.s_a_after > dem.s_a_before     # masking went one deeper
    assert adm.victims == [0] and adm.step > dem.step
    assert adm.s_a_after == 1
    # detection latency: slow onset at poll 4, warmup 2 + dwell 3
    assert dem.step <= 4 + det.warmup + det.min_dwell + 1
    assert tr.health_log and tr.health_log[0]["action"] == "demote"
    assert not tr._demoted and tr._demote_snapshot is None

    # bit-identical re-admission (stronger than schedule equality)
    ref = SpareState(8, 2)
    np.testing.assert_array_equal(tr.state.stacks, ref.stacks)
    np.testing.assert_array_equal(tr.state.alive, ref.alive)
    np.testing.assert_array_equal(tr.state.supplier, ref.supplier)
    assert int(tr.state.s_a) == 1
    ref_types, ref_w = ref.device_schedule()
    got_types, got_w = tr.state.device_schedule()
    np.testing.assert_array_equal(got_types, ref_types)
    np.testing.assert_array_equal(got_w, ref_w)

    # the model clock reflects the buy-back: only pre-demotion windows
    # ran at the straggler's pace
    slow_windows = sum(1 for w in inj.window_log if w > 64.0)
    assert slow_windows < 12               # tolerate would pay all 12


def test_trainer_tolerates_when_policy_says_so():
    """An unmaskable straggler set (every group slow) must not demote:
    the policy tolerates and training continues at the degraded pace."""
    cfg = smoke_config("qwen2.5-3b").scaled(grad_accum=1)
    det = StragglerDetector(4, ewma_alpha=1.0, warmup=1, min_dwell=1,
                            clear_dwell=1)
    tr = SpareTrainer(cfg, n_groups=4, redundancy=2, seq=32,
                      per_type_batch=1, total_steps=32, detector=det)
    # wipe-out set: masking all four groups is infeasible
    inj = ScriptedInjector(
        {}, seconds_per_step=64.0,
        slow_schedule={2: [(g, 3.0, None) for g in range(4)]}, n_groups=4)
    rep = tr.run(8, injector=inj)
    assert rep.demotes == 0 and rep.wipeouts == 0
    assert rep.steps_done == 8
    # uniform slowdown shifts the median: nobody stands out to flag
    assert all(h["action"] == "tolerate" for h in tr.health_log)


def test_trainer_global_restart_clears_gray_state():
    cfg = smoke_config("qwen2.5-3b").scaled(grad_accum=1)
    det = StragglerDetector(8, ewma_alpha=1.0)
    tr = SpareTrainer(cfg, n_groups=8, redundancy=2, seq=32,
                      per_type_batch=1, total_steps=64, detector=det)
    inj = ScriptedInjector({}, seconds_per_step=64.0, n_groups=8)
    _stream(det, slow_group=0, factor=3.0, steps=6)
    hr = det.reports[-1]
    tr._demote([0], hr, inj, TrainReport())
    assert tr._demoted == {0} and not tr.state.alive[0]
    ver = tr._schedule_version
    tr._global_restart()
    assert not tr._demoted and tr._demote_snapshot is None
    assert tr.state.alive.all() and int(tr.state.s_a) == 1
    assert det.observations == 0           # detector history reset
    assert tr._schedule_version > ver


def test_trainer_stale_snapshot_rebuilds_on_readmit():
    """If another recovery touches the schedule while a group is
    demoted, the snapshot is stale: re-admission must rebuild from a
    clean reset and replay the still-dead set, not restore the stale
    bytes."""
    cfg = smoke_config("qwen2.5-3b").scaled(grad_accum=1)
    det = StragglerDetector(8, ewma_alpha=1.0)
    tr = SpareTrainer(cfg, n_groups=8, redundancy=2, seq=32,
                      per_type_batch=1, total_steps=64, detector=det)
    inj = ScriptedInjector({}, seconds_per_step=64.0, n_groups=8)
    _stream(det, slow_group=2, factor=3.0, steps=6)
    hr = det.reports[-1]
    tr._demote([2], hr, inj, TrainReport())
    # a real failure lands while 2 is demoted
    tr.scheme.recover(tr.state, [5], step=0)
    tr._schedule_version += 1
    tr._readmit([2], hr, inj, TrainReport())
    st = tr.state
    st.assert_invariants()
    assert bool(st.alive[2]) and not bool(st.alive[5])
    # equivalent to masking 5 on a fresh state
    ref = SpareState(8, 2)
    tr.scheme.recover(ref, [5], step=0)
    np.testing.assert_array_equal(st.stacks, ref.stacks)
    np.testing.assert_array_equal(st.alive, ref.alive)
    np.testing.assert_array_equal(st.supplier, ref.supplier)
    assert int(st.s_a) == int(ref.s_a)


# ------------------------------------------------------------------ #
# serving: detector-weighted routing                                 #
# ------------------------------------------------------------------ #
def test_serve_routes_around_flagged_replica():
    from repro.data import RequestStream
    from repro.models.model import build_model
    from repro.serve import ReplicaServer, pool_pages_for

    cfg = smoke_config("qwen2.5-3b")
    model = build_model(cfg)
    import jax
    params = model.init(jax.random.key(0))
    det = StragglerDetector(3, ewma_alpha=1.0, warmup=1, min_dwell=1,
                            clear_dwell=1)
    inj = ScriptedInjector({}, seconds_per_step=1.0,
                           slow_schedule={0: [(1, 4.0, None)]}, n_groups=3)
    srv = ReplicaServer(
        model, params, n_replicas=3, injector=inj, detector=det,
        engine_kwargs=dict(n_slots=2, page_size=4, max_new=4, buckets=(8,),
                           n_pages=pool_pages_for(2, 8 + 4, 4)))
    srv.warmup()
    for _ in range(3):                     # let the detector flag
        srv.step()
    assert det.flagged == (1,)
    assert srv.weights[1] == 0.0
    assert srv.weights[0] > 0 and srv.weights[2] > 0
    assert any(e.kind == "slow" and e.victims == [1] for e in srv.events)

    stream = RequestStream(cfg, buckets=(8,), max_new=4, seed=3)
    for r in stream.requests(6):
        srv.submit(r)
    assert srv.engines[1].pending + srv.engines[1].in_flight == 0, \
        "requests were routed onto the flagged-slow replica"
    done = srv.run()
    assert len(done) == 6 and srv.dropped == 0
    rep = srv.report()
    assert rep["flagged_slow"] == [1]
    assert rep["health_factors"][1] > 2.0


# ------------------------------------------------------------------ #
# spmd: demote round trip on the live mesh, recompiles frozen        #
# ------------------------------------------------------------------ #
@pytest.mark.spmd
def test_mesh_demote_roundtrip_zero_recompiles():
    """On the 8-device emulated mesh: pre-warm both stacking depths,
    then run a scripted fail-slow episode through detect -> demote ->
    re-admit. The entire round trip must be weight-table data — zero
    run-attributed recompiles — and end bit-identical to healthy."""
    from repro.exec import MeshExecutor

    cfg = smoke_config("qwen2.5-3b").scaled(grad_accum=1)
    det = StragglerDetector(8, ewma_alpha=1.0, warmup=1, min_dwell=1,
                            clear_dwell=1)
    ex = MeshExecutor(cfg, n_groups=8, redundancy=2, model_degree=1,
                      seq=32, per_type_batch=2, total_steps=16,
                      scheme=get_scheme("adaptive", r=2, initial="spare"),
                      detector=det)
    ex.prewarm_depths([1, 2])
    warmed = ex.total_recompiles
    inj = ScriptedInjector({}, seconds_per_step=64.0,
                           slow_schedule={2: [(0, 3.0, 7)]}, n_groups=8)
    rep = ex.run(12, injector=inj, snapshot_every=10)
    assert rep.steps_done == 12
    assert rep.demotes == 1 and rep.readmits == 1
    assert rep.recompiles == 0, "demote round trip recompiled"
    assert ex.total_recompiles == warmed, "a cache miss slipped through"
    ref = SpareState(8, 2)
    np.testing.assert_array_equal(ex.state.stacks, ref.stacks)
    np.testing.assert_array_equal(ex.state.alive, ref.alive)
    np.testing.assert_array_equal(ex.state.supplier, ref.supplier)
    assert int(ex.state.s_a) == 1
    ex.close()
