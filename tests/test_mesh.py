"""launch/mesh.py unit tests: production mesh geometry + emulated meshes.

The production builders need 256/512 devices, and jax pins the device
count at first init — so those run in a subprocess with the dry-run's
``XLA_FLAGS`` trick. The emulated-mesh API and error paths run in
process with however many devices the suite sees.
"""
import os
import subprocess
import sys
import textwrap

import pytest

import jax

from repro.launch.mesh import dp_axes, dp_degree, make_emulated_mesh


def test_dp_axes():
    assert dp_axes(False) == ("data",)
    assert dp_axes(True) == ("pod", "data")


def test_emulated_mesh_axes_and_degree():
    mesh = make_emulated_mesh(1, 1)
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["data"] == 1 and mesh.shape["model"] == 1
    assert dp_degree(mesh, multi_pod=False) == 1


def test_emulated_mesh_uses_device_budget():
    n = jax.device_count()
    mesh = make_emulated_mesh(n, 1)
    assert mesh.size == n
    assert dp_degree(mesh, multi_pod=False) == n


def test_emulated_mesh_too_large_names_the_fix():
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        make_emulated_mesh(jax.device_count() + 1, 2)


def test_production_mesh_geometry_subprocess():
    """Real ``make_production_mesh`` construction at 512 forced host
    devices: shapes, axis names, and DP degrees of both launch targets.

    Also guards the jax-version compat shim — ``axis_types`` /
    ``jax.sharding.AxisType`` only exist on newer jax, and the builder
    must work either way.
    """
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=512"
        from repro.launch.mesh import (dp_axes, dp_degree,
                                       make_production_mesh)

        single = make_production_mesh()
        assert single.axis_names == ("data", "model")
        assert dict(single.shape) == {"data": 16, "model": 16}
        assert single.size == 256
        assert dp_degree(single, multi_pod=False) == 16

        multi = make_production_mesh(multi_pod=True)
        assert multi.axis_names == ("pod", "data", "model")
        assert dict(multi.shape) == {"pod": 2, "data": 16, "model": 16}
        assert multi.size == 512
        assert dp_degree(multi, multi_pod=True) == 32
        print("MESH-GEOMETRY-OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), os.pardir,
                                      "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "MESH-GEOMETRY-OK" in out.stdout
