"""Trip-count-aware HLO analyzer: exactness on known programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo import analyze_hlo, parse_module, wire_byte_ratio


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_exact():
    W = jnp.zeros((8, 256, 256), jnp.float32)
    x = jnp.zeros((32, 256), jnp.float32)

    def f(x, W):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, W)[0]

    c = analyze_hlo(_compile(f, x, W))
    # 8 iterations x 2*32*256*256 matmul flops
    assert c.flops == pytest.approx(8 * 2 * 32 * 256 * 256, rel=0.02)
    assert c.unknown_trip_loops == 0


def test_nested_scan_flops_exact():
    W = jnp.zeros((4, 128, 128), jnp.float32)
    x = jnp.zeros((16, 128), jnp.float32)

    def f(x, W):
        def outer(c, _):
            return jax.lax.scan(lambda ci, w: (ci @ w, None), c, W)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    c = analyze_hlo(_compile(f, x, W))
    assert c.flops == pytest.approx(3 * 4 * 2 * 16 * 128 * 128, rel=0.02)


@pytest.mark.tpu
def test_xla_cost_analysis_undercounts_loops():
    """The reason this module exists: XLA's own cost analysis visits while
    bodies once. Keep this regression so nobody 'simplifies' back.
    (``tpu``-marked: the CPU backend's cost analysis reports different
    per-op counts, so the undercount assertion only holds as lowered for
    the TPU toolchain.)"""
    W = jnp.zeros((8, 256, 256), jnp.float32)
    x = jnp.zeros((32, 256), jnp.float32)

    def f(x, W):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, W)[0]

    compiled = jax.jit(f).lower(x, W).compile()
    xla_flops = compiled.cost_analysis().get("flops", 0.0)
    ours = analyze_hlo(compiled.as_text()).flops
    assert ours > 4 * xla_flops  # 8 iterations vs 1


def test_parse_module_structure():
    x = jnp.zeros((8, 8), jnp.float32)
    txt = _compile(lambda a: a @ a + 1.0, x)
    comps, entry = parse_module(txt)
    assert entry in comps
    assert any(i.op == "dot" for c in comps.values() for i in c.instrs)


def test_bytes_written_leq_accessed():
    x = jnp.zeros((64, 64), jnp.float32)
    c = analyze_hlo(_compile(lambda a: jnp.tanh(a @ a).sum(), x))
    assert 0 < c.bytes_written <= c.bytes_accessed


def _module(body: str, params: str = "p0: f32[1024]",
            ret: str = "f32[1024]") -> str:
    """Minimal hand-written HLO module around ``body`` instructions."""
    return (f"HloModule handwritten\n\n"
            f"ENTRY %main ({params}) -> {ret} {{\n{body}\n}}\n")


def test_collective_dtype_bytes_handwritten():
    txt = _module(
        "  %p0 = f32[1024] parameter(0)\n"
        "  ROOT %ar = f32[1024] all-reduce(%p0), "
        "replica_groups={{0,1,2,3}}, to_apply=%add")
    c = analyze_hlo(txt)
    # ring all-reduce moves ~2x the buffer
    assert c.collective_dtype_bytes == {("all-reduce", "f32"): 8192.0}
    assert c.collective_bytes == {"all-reduce": 8192.0}
    assert c.collective_counts == {"all-reduce": 1}


def test_collective_dtype_bytes_tuple_shaped():
    """A multi-operand collective has a TUPLE output; each element's
    bytes must land under its own dtype (the compressed sync's int8
    payload + fp32 scales pattern), not all under the first element."""
    txt = _module(
        "  %q = s8[1024] parameter(0)\n"
        "  %s = f32[8] parameter(1)\n"
        "  ROOT %ar = (s8[1024], f32[8]) all-reduce(%q, %s), "
        "replica_groups={{0,1}}, to_apply=%add",
        params="q: s8[1024], s: f32[8]", ret="(s8[1024], f32[8])")
    c = analyze_hlo(txt)
    assert c.collective_dtype_bytes == {("all-reduce", "s8"): 2048.0,
                                        ("all-reduce", "f32"): 64.0}
    assert c.collective_bytes == {"all-reduce": 2112.0}


def test_collective_async_start_halves_each_dtype():
    txt = _module(
        "  %p0 = f32[256] parameter(0)\n"
        "  %ars = (f32[256], f32[256]) all-reduce-start(%p0), "
        "replica_groups={{0,1}}, to_apply=%add\n"
        "  ROOT %ard = f32[256] all-reduce-done(%ars)",
        params="p0: f32[256]", ret="f32[256]")
    c = analyze_hlo(txt)
    # start tuple carries operand+result: one logical 1024 B buffer, 2x ring
    assert c.collective_dtype_bytes == {("all-reduce", "f32"): 2048.0}
    assert c.collective_counts == {"all-reduce": 1}   # -done not re-counted


def test_reduce_scatter_scales_with_group_size():
    txt = _module(
        "  %p0 = f32[1024] parameter(0)\n"
        "  ROOT %rs = f32[256] reduce-scatter(%p0), "
        "replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add")
    c = analyze_hlo(txt)
    assert c.collective_dtype_bytes == {("reduce-scatter", "f32"): 4096.0}


def test_promoted_bf16_halves_only_f32_share():
    """XLA:CPU promotes bf16 collectives to f32 via a hoisted convert;
    the wire moves the logical bf16 width. An int element riding the
    same tuple keeps its own width — it must NOT be halved."""
    txt = _module(
        "  %pb = bf16[512] parameter(0)\n"
        "  %q = s8[64] parameter(1)\n"
        "  %cvt = f32[512] convert(%pb)\n"
        "  ROOT %ar = (f32[512], s8[64]) all-reduce(%cvt, %q), "
        "replica_groups={{0,1}}, to_apply=%add",
        params="pb: bf16[512], q: s8[64]", ret="(f32[512], s8[64])")
    c = analyze_hlo(txt)
    assert c.collective_dtype_bytes == {("all-reduce", "bf16"): 2048.0,
                                        ("all-reduce", "s8"): 128.0}


def test_wire_byte_ratio_handwritten():
    baseline = _module(
        "  %p0 = f32[1024] parameter(0)\n"
        "  ROOT %ar = f32[1024] all-reduce(%p0), "
        "replica_groups={{0,1}}, to_apply=%add")
    compressed = _module(
        "  %q = s8[1024] parameter(0)\n"
        "  ROOT %a2a = s8[1024] all-to-all(%q), replica_groups={{0,1}}, "
        "dimensions={0}", params="q: s8[1024]", ret="s8[1024]")
    # 1024 B one-shot vs 2 * 4096 B ring all-reduce
    assert wire_byte_ratio(compressed, baseline) == pytest.approx(0.125)
    assert wire_byte_ratio(baseline, baseline) == pytest.approx(1.0)


def test_collective_detection_spmd():
    import os
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (dry-run process sets 512)")
    mesh = jax.make_mesh((jax.device_count(),), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = NamedSharding(mesh, P("d"))

    def f(a):
        return a.sum()  # reduce over sharded axis -> all-reduce

    x = jnp.zeros((jax.device_count() * 4,), jnp.float32)
    txt = jax.jit(f, in_shardings=sh).lower(x).compile().as_text()
    c = analyze_hlo(txt)
    assert sum(c.collective_counts.values()) >= 1
