"""Trip-count-aware HLO analyzer: exactness on known programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo import analyze_hlo, parse_module


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_exact():
    W = jnp.zeros((8, 256, 256), jnp.float32)
    x = jnp.zeros((32, 256), jnp.float32)

    def f(x, W):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, W)[0]

    c = analyze_hlo(_compile(f, x, W))
    # 8 iterations x 2*32*256*256 matmul flops
    assert c.flops == pytest.approx(8 * 2 * 32 * 256 * 256, rel=0.02)
    assert c.unknown_trip_loops == 0


def test_nested_scan_flops_exact():
    W = jnp.zeros((4, 128, 128), jnp.float32)
    x = jnp.zeros((16, 128), jnp.float32)

    def f(x, W):
        def outer(c, _):
            return jax.lax.scan(lambda ci, w: (ci @ w, None), c, W)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    c = analyze_hlo(_compile(f, x, W))
    assert c.flops == pytest.approx(3 * 4 * 2 * 16 * 128 * 128, rel=0.02)


@pytest.mark.tpu
def test_xla_cost_analysis_undercounts_loops():
    """The reason this module exists: XLA's own cost analysis visits while
    bodies once. Keep this regression so nobody 'simplifies' back.
    (``tpu``-marked: the CPU backend's cost analysis reports different
    per-op counts, so the undercount assertion only holds as lowered for
    the TPU toolchain.)"""
    W = jnp.zeros((8, 256, 256), jnp.float32)
    x = jnp.zeros((32, 256), jnp.float32)

    def f(x, W):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, W)[0]

    compiled = jax.jit(f).lower(x, W).compile()
    xla_flops = compiled.cost_analysis().get("flops", 0.0)
    ours = analyze_hlo(compiled.as_text()).flops
    assert ours > 4 * xla_flops  # 8 iterations vs 1


def test_parse_module_structure():
    x = jnp.zeros((8, 8), jnp.float32)
    txt = _compile(lambda a: a @ a + 1.0, x)
    comps, entry = parse_module(txt)
    assert entry in comps
    assert any(i.op == "dot" for c in comps.values() for i in c.instrs)


def test_bytes_written_leq_accessed():
    x = jnp.zeros((64, 64), jnp.float32)
    c = analyze_hlo(_compile(lambda a: jnp.tanh(a @ a).sum(), x))
    assert 0 < c.bytes_written <= c.bytes_accessed


def test_collective_detection_spmd():
    import os
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (dry-run process sets 512)")
    mesh = jax.make_mesh((jax.device_count(),), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = NamedSharding(mesh, P("d"))

    def f(a):
        return a.sum()  # reduce over sharded axis -> all-reduce

    x = jnp.zeros((jax.device_count() * 4,), jnp.float32)
    txt = jax.jit(f, in_shardings=sh).lower(x).compile().as_text()
    c = analyze_hlo(txt)
    assert sum(c.collective_counts.values()) >= 1
