"""Scenario engine: topology, failure models, engine integration.

Covers the ISSUE-2 acceptance points: seed-determinism of every
``FailureModel``, bit-for-bit Poisson/Weibull parity with the legacy
``FailureProcess`` stream, blast-radius victim selection, and
multi-group simultaneous failures reaching the schemes.
"""
import math

import numpy as np
import pytest

from repro.core.montecarlo import run_montecarlo, run_trial
from repro.des import DESParams, get_scheme
from repro.des.failures import FailureProcess
from repro.scenarios import (
    ClusterTopology,
    CorrelatedModel,
    RenewalModel,
    bundled_traces,
    get_failure_model,
    list_failure_models,
    load_trace,
    model_from_spec,
    sample_kill_batches,
    topology_from_spec,
)


# ------------------------------------------------------------------ #
# topology                                                            #
# ------------------------------------------------------------------ #
def test_topology_hierarchy_sizes():
    topo = ClusterTopology(n_groups=64, hosts_per_group=2, hosts_per_rack=8,
                           racks_per_pod=4, pods_per_dci=2)
    assert topo.n_hosts == 128
    assert topo.n_racks == 16
    assert topo.n_pods == 4
    assert topo.n_dcis == 2
    assert topo.total_gpus == 128 * 8


def test_topology_blast_radius_nested():
    topo = ClusterTopology(n_groups=64, hosts_per_group=1, hosts_per_rack=4,
                           racks_per_pod=4, pods_per_dci=2)
    g = 5
    rack = topo.blast_radius(g, "rack")
    pod = topo.blast_radius(g, "pod")
    dci = topo.blast_radius(g, "dci")
    assert topo.blast_radius(g, "group") == [g]
    assert g in rack and set(rack) <= set(pod) <= set(dci)
    assert len(rack) == 4 and len(pod) == 16 and len(dci) == 32


def test_topology_resolve_maps_locations_to_groups():
    topo = ClusterTopology(n_groups=16, hosts_per_group=2, hosts_per_rack=4)
    # host 5 belongs to group 2 (hosts 4,5)
    assert topo.resolve("host", 5) == [2]
    # rack 0 = hosts 0..3 = groups 0,1
    assert topo.resolve("rack", 0) == [0, 1]
    # locations wrap modulo the domain count (trace portability)
    assert topo.resolve("rack", topo.n_racks) == topo.resolve("rack", 0)
    with pytest.raises(ValueError):
        topo.resolve("continent", 0)


def test_topology_paper_scale_presets():
    topo = topology_from_spec("600k")
    assert topo.n_groups == 600
    assert topo.total_gpus == pytest.approx(600_000, rel=0.01)
    # Table 1: 1000 GPUs per group => 125 hosts per group at 8 GPUs/host
    assert topo.hosts_per_group == 125
    small = topology_from_spec(None, n_groups=32)
    assert small.n_groups == 32
    with pytest.raises(KeyError):
        topology_from_spec("3m")


def test_topology_group_spanning_racks():
    # a group wider than one rack blasts every rack it touches
    topo = ClusterTopology(n_groups=8, hosts_per_group=8, hosts_per_rack=4)
    assert list(topo.racks_of_group(0)) == [0, 1]
    assert set(topo.blast_radius(0, "rack")) == {0}
    # rack 1 holds hosts 4..7, all of group 0
    assert topo.groups_in_rack(1) == [0]


# ------------------------------------------------------------------ #
# model registry                                                      #
# ------------------------------------------------------------------ #
def test_model_registry_lists_builtins():
    names = list_failure_models()
    for k in ("weibull", "poisson", "correlated", "diurnal", "trace",
              "superposed"):
        assert k in names
    with pytest.raises(KeyError, match="correlated"):
        get_failure_model("nope")


def test_model_from_spec_forms():
    assert model_from_spec(None).name == "weibull"
    assert model_from_spec("poisson").name == "poisson"
    m = model_from_spec({"kind": "correlated", "label": "x",
                         "burst_prob": 0.3})
    assert m.name == "correlated" and m.scope_probs == {"rack": 0.3}


# ------------------------------------------------------------------ #
# seed determinism of every model                                     #
# ------------------------------------------------------------------ #
ALL_MODEL_SPECS = [
    {"kind": "weibull"},
    {"kind": "poisson"},
    {"kind": "correlated", "burst_prob": 0.4},
    {"kind": "diurnal", "period": 2000.0, "amplitude": 0.8,
     "maintenance_start": 100.0, "maintenance_len": 400.0},
    {"kind": "trace", "trace": "meta_hsdp_rackstorm", "time_scale": 0.05},
    {"kind": "superposed", "components": [
        {"kind": "poisson", "mtbf": 500.0},
        {"kind": "correlated", "scope": "pod", "burst_prob": 1.0,
         "mtbf": 2000.0}]},
]


def _event_stream(spec, seed, n=40, events=25):
    """Drain (time, victims) tuples from a freshly-bound model."""
    p = DESParams(n=n, mtbf=300.0)
    model = model_from_spec(spec)
    rng = np.random.default_rng(seed)
    model.bind(p, rng, ClusterTopology(n_groups=n))
    dead: set[int] = set()
    out = []
    t = model.next_arrival(0.0, n, n)
    while len(out) < events and len(dead) < n and t != math.inf:
        victims = [v for v in model.draw_victims(t, dead) if v not in dead]
        dead.update(victims)
        out.append((t, tuple(victims)))
        t = model.next_arrival(t, max(n - len(dead), 1), n)
    return out


@pytest.mark.parametrize("spec", ALL_MODEL_SPECS,
                         ids=lambda s: s["kind"])
def test_model_event_stream_deterministic_by_seed(spec):
    a = _event_stream(spec, seed=7)
    b = _event_stream(spec, seed=7)
    c = _event_stream(spec, seed=8)
    assert a == b
    assert len(a) > 0
    if spec["kind"] != "trace":        # trace times are seed-independent
        assert a != c


@pytest.mark.parametrize("spec", ALL_MODEL_SPECS,
                         ids=lambda s: s["kind"])
def test_model_rebind_resets_state(spec):
    """bind() must fully reset: the same instance drained twice gives
    the same stream (campaign cells reuse model objects)."""
    model = model_from_spec(spec)
    p = DESParams(n=40, mtbf=300.0)

    def drain():
        rng = np.random.default_rng(3)
        model.bind(p, rng, ClusterTopology(n_groups=40))
        dead: set[int] = set()
        out = []
        t = model.next_arrival(0.0, 40, 40)
        for _ in range(15):
            if t == math.inf or len(dead) >= 40:
                break
            v = [x for x in model.draw_victims(t, dead) if x not in dead]
            dead.update(v)
            out.append((t, tuple(v)))
            t = model.next_arrival(t, max(40 - len(dead), 1), 40)
        return out

    assert drain() == drain()


# ------------------------------------------------------------------ #
# legacy parity                                                       #
# ------------------------------------------------------------------ #
def test_renewal_model_bitwise_parity_with_failure_process():
    """The weibull RenewalModel must draw the exact legacy sequence:
    one interval draw per event, one uniform victim choice."""
    p = DESParams(n=50)
    m = RenewalModel()
    rng_model = np.random.default_rng(11)
    rng_ref = np.random.default_rng(11)
    m.bind(p, rng_model)
    proc = FailureProcess(p.mtbf, p.weibull_shape, rng_ref,
                          law=p.failure_law,
                          scale_with_survivors=p.scale_rate_with_survivors)
    dead: set[int] = set()
    t_m = m.next_arrival(0.0, 50, 50)
    t_r = proc.next_arrival(0.0, 50, 50)
    for _ in range(30):
        assert t_m == t_r
        victims = m.draw_victims(t_m, dead)
        cands = [w for w in range(50) if w not in dead]
        assert victims == [int(rng_ref.choice(cands))]
        dead.update(victims)
        alive = 50 - len(dead)
        t_m = m.next_arrival(t_m, alive, 50)
        t_r = proc.next_arrival(t_r, alive, 50)


@pytest.mark.parametrize("law,kind", [("weibull", "weibull"),
                                      ("exponential", "poisson")])
def test_engine_default_equals_explicit_renewal_model(law, kind):
    """Poisson/Weibull parity at the engine level: injecting the model
    explicitly reproduces the default stream bit-for-bit."""
    p = DESParams(n=200, steps=150, failure_law=law)
    a = get_scheme("spare", r=9).simulate(p, seed=3)
    b = get_scheme("spare", r=9).simulate(p, seed=3,
                                          failure_model=model_from_spec(kind))
    for f in ("wall", "committed", "steps_done", "node_failures",
              "wipeouts", "ckpt_count", "total_stacks", "patches"):
        assert getattr(a, f) == getattr(b, f), f


# ------------------------------------------------------------------ #
# engine integration: correlated + multi-group failures               #
# ------------------------------------------------------------------ #
def test_correlated_bursts_reach_scheme_as_simultaneous_failures():
    """A guaranteed-burst model must surface multi-group failure sets in
    one on_failure call (blast-radius wipe-out accounting)."""
    seen: list[int] = []
    base = get_scheme("spare", r=4)
    orig = base.on_failure

    def spy(sim, failed, work):
        seen.append(len(failed))
        return orig(sim, failed, work)

    base.on_failure = spy
    topo = ClusterTopology(n_groups=200, hosts_per_rack=8)
    model = CorrelatedModel(burst_prob=1.0, scope="rack")
    p = DESParams(n=200, steps=120)
    res = base.simulate(p, seed=0, failure_model=model, topology=topo)
    assert res.node_failures > 0
    assert max(seen, default=0) > 1, "rack bursts must batch failures"


def test_correlated_regime_degrades_spare_vs_renewal():
    """Spatial correlation at equal system MTBF must not *improve* SPARe:
    burst kills concentrate failures inside one checkpoint interval."""
    p = DESParams(n=200, steps=250)
    topo = ClusterTopology(n_groups=200)
    quiet = get_scheme("spare", r=9).simulate(
        p, seed=5, failure_model=model_from_spec({"kind": "weibull"}))
    burst = get_scheme("spare", r=9).simulate(
        p, seed=5, failure_model=model_from_spec(
            {"kind": "correlated", "burst_prob": 0.5}),
        topology=topo)
    assert burst.ttt_norm >= quiet.ttt_norm * 0.95


def test_trace_replay_drives_engine():
    p = DESParams(n=200, steps=100)
    model = model_from_spec({"kind": "trace", "trace": "quiet_poisson",
                             "time_scale": 0.2})
    res = get_scheme("spare", r=9).simulate(p, seed=0, failure_model=model)
    assert res.steps_done == 100
    assert res.node_failures > 0


def test_trace_loader_and_bundled_traces():
    names = bundled_traces()
    assert {"meta_hsdp_rackstorm", "quiet_poisson",
            "diurnal_maintenance"} <= set(names)
    ev = load_trace("meta_hsdp_rackstorm")
    assert len(ev) > 100
    assert all(e["t"] >= p["t"] for p, e in zip(ev, ev[1:]))
    scopes = {e["scope"] for e in ev}
    assert "rack" in scopes and "host" in scopes
    with pytest.raises(FileNotFoundError):
        load_trace("no_such_trace")


def test_diurnal_rate_factor_modulates():
    m = model_from_spec({"kind": "diurnal", "period": 1000.0,
                         "amplitude": 0.5, "peak": 0.5,
                         "maintenance_start": 0.0,
                         "maintenance_len": 100.0,
                         "maintenance_factor": 4.0})
    m.bind(DESParams(n=20), np.random.default_rng(0))
    assert m.rate_factor(500.0) == pytest.approx(1.5)   # peak
    off_peak = 1.0 + 0.5 * math.cos(2 * math.pi * (50.0 / 1000.0 - 0.5))
    assert m.rate_factor(50.0) == pytest.approx(off_peak * 4.0)
    assert m.rate_factor(150.0) < m.rate_factor(50.0)   # window ended
    # higher rate => stochastically earlier arrivals at the peak
    quiet = _event_stream({"kind": "poisson"}, seed=1)
    assert len(quiet) > 0


# ------------------------------------------------------------------ #
# Monte-Carlo integration                                             #
# ------------------------------------------------------------------ #
def test_sample_kill_batches_covers_all_groups():
    batches = sample_kill_batches({"kind": "correlated", "burst_prob": 0.5},
                                  40, np.random.default_rng(2),
                                  topology=ClusterTopology(n_groups=40))
    flat = [w for b in batches for w in b]
    assert sorted(flat) == list(range(40))      # each group exactly once
    assert max(len(b) for b in batches) > 1     # with bursts


def test_run_trial_accepts_batches_and_flags_censoring():
    rng = np.random.default_rng(0)
    f, depths = run_trial(30, 4, rng)
    assert f is not None and 1 <= f <= 30
    assert len(depths) == f - 1
    # multi-kill batches: depths recorded per event, not per failure
    rng = np.random.default_rng(0)
    batches = [[0, 1], [2, 3], [4]]
    f2, depths2 = run_trial(30, 4, rng, kill_batches=batches)
    if f2 is None:
        assert len(depths2) == len(batches)


def test_montecarlo_blast_radius_lowers_failure_tolerance():
    base = run_montecarlo(200, 9, trials=25, seed=1)
    corr = run_montecarlo(
        200, 9, trials=25, seed=1,
        failure_model={"kind": "correlated", "burst_prob": 0.5},
        topology=ClusterTopology(n_groups=200))
    assert corr.mean_failures < base.mean_failures
    assert base.censored == 0 and corr.censored == 0


def test_montecarlo_terminates_on_partial_coverage_trace():
    """Regression: a looping trace whose locations never cover all N
    groups must not spin forever in sample_kill_batches — the uniform
    fallback finishes the kill order."""
    res = run_montecarlo(
        200, 9, trials=2, seed=0,
        failure_model={"kind": "trace", "trace": "quiet_poisson"})
    assert res.censored == 0
    assert res.mean_failures == res.mean_failures   # not NaN


def test_montecarlo_deterministic_with_model():
    kw = dict(trials=10, seed=9,
              failure_model={"kind": "correlated", "burst_prob": 0.3})
    a = run_montecarlo(100, 6, **kw)
    b = run_montecarlo(100, 6, **kw)
    assert a.failures == b.failures
    assert a.stacks_per_k == b.stacks_per_k
