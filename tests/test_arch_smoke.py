"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced same-family config runs one forward + one train step + one decode
step on CPU with shape and finiteness assertions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models import build_model
from repro.models.model import segments_of
from repro.optim import adamw_init
from repro.train import make_train_step

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, b=2, s=64, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)))}
    if cfg.frontend:
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)) * 0.02, jnp.bfloat16)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finiteness(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = model.forward(params, tokens=batch.get("tokens"),
                           embeds=batch.get("embeds"))
    assert logits.shape == (2, 64, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))
    # CE at init should be ~ln(vocab)
    assert float(loss) == pytest.approx(np.log(cfg.vocab), rel=0.15)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_one_train_step(arch):
    cfg = smoke_config(arch).scaled(grad_accum=1)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params, moment_dtype=cfg.moment_dtype)
    # short warmup + healthy lr so one bf16 update is visibly nonzero
    step = make_train_step(model, base_lr=0.05, warmup=1)
    batch = _batch(cfg)
    stacked = {k: v[None] for k, v in batch.items()}
    stacked["weights"] = jnp.full((1, 2), 0.5, jnp.float32)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, stacked)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_opt.step) == 1
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda acc, pq: acc or bool(jnp.any(pq)),
        jax.tree.map(lambda a, b: jnp.any(a.astype(jnp.float32)
                                          != b.astype(jnp.float32)),
                     params, new_params), False)
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = model.init_decode_state(batch=2, s_max=32)
    batch = _batch(cfg, s=1)
    logits, new_state = model.decode_step(
        params, state, jnp.int32(0),
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"))
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # cache structure preserved
    assert jax.tree.structure(state) == jax.tree.structure(new_state)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward_prefix(arch):
    """Greedy decode consistency: feeding tokens one by one through
    decode_step must reproduce the teacher-forced forward logits."""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 1, 8
    batch = _batch(cfg, b=b, s=s, seed=3)
    full = model.forward(params, tokens=batch.get("tokens"),
                         embeds=batch.get("embeds"))
    state = model.init_decode_state(batch=b, s_max=s)
    outs = []
    for t in range(s):
        tok = (batch["tokens"][:, t:t + 1] if "tokens" in batch else None)
        emb = (batch["embeds"][:, t:t + 1] if "embeds" in batch else None)
        logits, state = model.decode_step(params, state, jnp.int32(t),
                                          tokens=tok, embeds=emb)
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    # bf16 consistency bound: the decode path accumulates rounding per
    # token while the forward batches — deepest stacks (jamba's 8-layer
    # period) reach ~0.2 logit drift on O(1) logits
    np.testing.assert_allclose(dec.astype(jnp.float32),
                               full.astype(jnp.float32), atol=0.3, rtol=0.3)


def test_exact_published_configs_construct():
    """Full-size configs must at least build their segment plans and count
    parameters (no allocation)."""
    expected_params = {
        "deepseek-v3-671b": (665e9, 677e9),
        "jamba-v0.1-52b": (50e9, 53e9),
        "glm4-9b": (9.0e9, 9.8e9),
        "qwen2.5-3b": (2.9e9, 3.2e9),
    }
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        segs = segments_of(cfg)
        assert sum(len(p) * n for p, n in segs) == cfg.n_layers
        n = cfg.param_count()
        if arch in expected_params:
            lo, hi = expected_params[arch]
            assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo},{hi}]"


def test_long_context_applicability():
    from repro.configs import SHAPES, applicable
    long = SHAPES["long_500k"]
    runs = {a for a in ARCH_IDS if applicable(get_config(a), long)[0]}
    assert runs == {"mamba2-1.3b", "jamba-v0.1-52b"}
