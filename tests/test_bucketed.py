"""Bucketed flat gradient sync + per-host feeding, host-side (tier-1).

The mesh spelling is covered by the ``spmd``-marked tests in
``test_exec.py``; everything here runs on one CPU device:

* deterministic first-fit bucket packing (size cap, oversize leaves,
  ``pad_to`` padding);
* bit-transparent flatten/unflatten round trip for every gradient dtype
  the accumulator can carry (fp32 exact by identity, bf16/fp16 exact by
  lossless widening);
* the EF cumulative invariant *through the bucketed compressor* across
  ragged leaf sizes and bucket padding — the padded tail must stay
  exactly zero so it never leaks signal into the wire scales;
* ``spare_batch_rows`` (the per-host feeding cut) is row-for-row
  byte-identical to the global ``spare_batch``, including cuts that
  split a group's per-type batch.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.collectives import (bucket_layout, compress_grad_int8,
                                    decompress_grad_int8, flatten_grads,
                                    unflatten_grads)

RNG = np.random.default_rng(11)


def _ragged_tree():
    return {
        "a": jnp.asarray(RNG.normal(size=(33, 7)), jnp.float32),
        "b": jnp.asarray(RNG.normal(size=(129,)), jnp.bfloat16),
        "nest": {"c": jnp.asarray(RNG.normal(size=(5, 3, 2)), jnp.float16),
                 "d": jnp.asarray(RNG.normal(), jnp.float32)},   # scalar
        "e": jnp.asarray(RNG.normal(size=(999,)), jnp.float32),
    }


# ------------------------------------------------------------------ #
# layout packing                                                     #
# ------------------------------------------------------------------ #
def test_layout_packs_first_fit_with_cap_and_padding():
    tree = _ragged_tree()
    lay = bucket_layout(tree, max_bucket_elems=300, pad_to=8)
    # leaf order is jax.tree order (dict keys sorted: a, b, e, nest.c,
    # nest.d); each bucket respects the cap unless a single leaf alone
    # exceeds it (999 gets a bucket of its own)
    sizes = [int(np.prod(l.shape)) if l.shape else 1
             for l in jax.tree.leaves(tree)]
    assert sizes == [231, 129, 999, 5 * 3 * 2, 1]
    assert lay.n_buckets == 4
    assert lay.bucket_of == (0, 1, 2, 3, 3)
    # padded up to pad_to multiples; unpadded fills are 231/129/999/31
    assert lay.bucket_sizes == (232, 136, 1000, 32)
    assert all(s % 8 == 0 for s in lay.bucket_sizes)
    # deterministic: same tree -> same layout
    assert bucket_layout(tree, max_bucket_elems=300, pad_to=8) == lay


def test_layout_is_constant_collective_count():
    """O(1) property: 100 leaves under one cap -> few buckets, and the
    bucket count depends on total elements, never on leaf count."""
    many = {f"w{i}": jnp.zeros((37,), jnp.float32) for i in range(100)}
    lay = bucket_layout(many, max_bucket_elems=1 << 20)
    assert lay.n_buckets == 1
    split = bucket_layout(many, max_bucket_elems=1000)
    assert split.n_buckets == int(np.ceil(100 * 37 / (27 * 37))) or \
        split.n_buckets < 100 // 2   # far fewer buckets than leaves
    assert split.n_buckets <= 4


# ------------------------------------------------------------------ #
# bit transparency (the uncompressed path)                           #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("pad_to", [1, 4, 128])
def test_flatten_unflatten_bit_transparent(pad_to):
    tree = _ragged_tree()
    lay = bucket_layout(tree, max_bucket_elems=500, pad_to=pad_to)
    bufs = flatten_grads(lay, tree)
    assert all(b.dtype == jnp.float32 for b in bufs)
    assert [b.size for b in bufs] == list(lay.bucket_sizes)
    back = unflatten_grads(lay, bufs)
    flat_a, flat_b = jax.tree.leaves(tree), jax.tree.leaves(back)
    for a, b in zip(flat_a, flat_b):
        assert a.dtype == b.dtype and a.shape == b.shape
        # widening to fp32 is injective for bf16/fp16, so fp32 equality
        # IS bit equality of the narrow values
        np.testing.assert_array_equal(np.asarray(a.astype(jnp.float32)),
                                      np.asarray(b.astype(jnp.float32)))


def test_padding_is_zero_and_ignored():
    tree = {"a": jnp.asarray(RNG.normal(size=(13,)), jnp.float32)}
    lay = bucket_layout(tree, pad_to=8)
    (buf,) = flatten_grads(lay, tree)
    assert buf.size == 16
    assert not np.asarray(buf[13:]).any()
    # corrupt the pad: unflatten must not see it
    poisoned = buf.at[13:].set(1e9)
    np.testing.assert_array_equal(
        np.asarray(unflatten_grads(lay, [poisoned])["a"]),
        np.asarray(tree["a"]))


# ------------------------------------------------------------------ #
# EF cumulative invariant through the bucketed compressor            #
# ------------------------------------------------------------------ #
def test_ef_cumulative_invariant_ragged_buckets():
    """k compressed steps of a fixed ragged gradient tree: per bucket,
    cumulative transmitted == k * bucket - final residual (exactly the
    single-tensor EF invariant, surviving concatenation + padding), and
    the padded tail transmits exactly zero forever."""
    tree = _ragged_tree()
    lay = bucket_layout(tree, max_bucket_elems=300, pad_to=8)
    bufs = flatten_grads(lay, tree)
    errs = [jnp.zeros_like(b) for b in bufs]
    sent = [jnp.zeros_like(b) for b in bufs]
    k = 12
    for _ in range(k):
        out, new_errs = [], []
        for buf, err in zip(bufs, errs):
            q, s, err = jax.jit(compress_grad_int8)(buf, err)
            out.append(decompress_grad_int8(q, s))
            new_errs.append(err)
        sent = [a + b for a, b in zip(sent, out)]
        errs = new_errs
    fills = [0] * lay.n_buckets         # unpadded fill per bucket
    for i, shape in enumerate(lay.shapes):
        n = int(np.prod(shape)) if shape else 1
        fills[lay.bucket_of[i]] = max(fills[lay.bucket_of[i]],
                                      lay.offsets[i] + n)
    pads = [s - f for s, f in zip(lay.bucket_sizes, fills)]
    assert any(pads), "padding must actually be exercised"
    for buf, tot, err, n_pad in zip(bufs, sent, errs, pads):
        scale = float(jnp.max(jnp.abs(buf))) / 127.0
        resid = np.abs(np.asarray(k * buf - tot))
        # the final residual is the only untransmitted signal
        np.testing.assert_allclose(resid, np.abs(np.asarray(err)),
                                   atol=1e-4)
        assert resid.max() <= scale / 2 + 1e-4
        if n_pad:
            assert not np.asarray(tot[-n_pad:]).any()
            assert not np.asarray(err[-n_pad:]).any()


def test_unflatten_after_compress_respects_dtypes():
    tree = _ragged_tree()
    lay = bucket_layout(tree, max_bucket_elems=1 << 20, pad_to=4)
    (buf,) = flatten_grads(lay, tree)
    q, s, _ = compress_grad_int8(buf, jnp.zeros_like(buf))
    back = unflatten_grads(lay, [decompress_grad_int8(q, s)])
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        # max error of one uncompensated step is scale/2
        err = np.abs(np.asarray(a.astype(jnp.float32))
                     - np.asarray(b.astype(jnp.float32)))
        tol = float(s) / 2 + float(jnp.max(jnp.abs(
            a.astype(jnp.float32)))) * 8e-3   # + bf16 leaf rounding
        assert err.max() <= tol


# ------------------------------------------------------------------ #
# per-host feeding rows                                              #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("arch", ["qwen2.5-3b", "musicgen-medium"])
def test_spare_batch_rows_matches_global(arch):
    from repro.configs import smoke_config
    from repro.core import Rectlr, SpareState
    from repro.data import ShardedTokenPipeline, spare_batch, spare_batch_rows

    cfg = smoke_config(arch)
    pipe = ShardedTokenPipeline(cfg, seq=16, per_type_batch=2, seed=3)
    state = SpareState(4, 2)
    Rectlr().on_failures(state, [1])          # masked schedule, S_A == 2
    full = spare_batch(pipe, state, step=5)
    sched = state.device_schedule()
    n_rows = 4 * 2
    # every cut, including ones that split a group's 2-example shard
    for lo, hi in [(0, n_rows), (0, 3), (3, 8), (2, 4), (5, 6)]:
        cut = spare_batch_rows(pipe, sched, state.s_a, 5, lo, hi)
        assert set(cut) == set(full)
        for k in full:
            np.testing.assert_array_equal(cut[k], full[k][:, lo:hi],
                                          err_msg=f"{k} rows [{lo},{hi})")
