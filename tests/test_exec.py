"""repro.exec: the SPARe protocol executed on a real SPMD mesh.

All tests are ``spmd``-marked: they need >= 8 devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``; see
tests/conftest.py). What they prove, per the ISSUE-4 acceptance points:

* the ``shard_map`` / gspmd mesh step's gradients match the host-side
  emulated trainer within fp32-reduction tolerance — for the healthy
  schedule and for EVERY recoverable survivor set;
* failure masking is pure weight-table data: a rack burst re-weights
  the live mesh run with no recompile at constant ``S_A``, and the
  masked step's compiled HLO carries exactly the same all-reduce count
  as the unmasked step (zero extra collectives);
* a wipe-out on the mesh rolls back to correctly re-sharded params.
"""
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.scenarios import ClusterTopology

pytestmark = pytest.mark.spmd

# fp32 summation-order noise across psum trees, amplified by bf16
# activations in the backward — same scale the emulated trainer allows
# for reorder noise (tests/test_trainer.py)
TOL = 5e-3


@pytest.fixture(scope="module")
def cfg():
    return smoke_config("qwen2.5-3b").scaled(grad_accum=1)


@pytest.fixture(scope="module")
def host_trainer(cfg):
    from repro.train.trainer import SpareTrainer
    return SpareTrainer(cfg, n_groups=4, redundancy=2, seq=32,
                        per_type_batch=2, total_steps=50)


def _executor(cfg, sync, **kw):
    from repro.exec import MeshExecutor
    kw.setdefault("n_groups", 4)
    kw.setdefault("redundancy", 2)
    kw.setdefault("model_degree", 2)
    kw.setdefault("seq", 32)
    kw.setdefault("per_type_batch", 2)
    kw.setdefault("total_steps", 50)
    return MeshExecutor(cfg, sync=sync, **kw)


@pytest.fixture(scope="module")
def executors(cfg):
    return {sync: _executor(cfg, sync) for sync in ("shard_map", "gspmd")}


@pytest.fixture(scope="module")
def compressed(cfg):
    return _executor(cfg, "shard_map", grad_compress="int8_ef")


# ------------------------------------------------------------------ #
# mesh-vs-host §3.1 equivalence                                      #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("sync", ["shard_map", "gspmd"])
def test_mesh_matches_host_healthy(executors, host_trainer, sync):
    from repro.exec import tree_max_rel_err
    ex = executors[sync]
    mesh = ex.mesh_grads(0)
    host = host_trainer.spare_grads(0)
    assert tree_max_rel_err(mesh, host) < TOL


@pytest.mark.parametrize("sync", ["shard_map", "gspmd"])
def test_params_placed_as_declared(executors, sync):
    ex = executors[sync]
    embed = ex.params["embed"]
    assert embed.sharding.mesh.shape == {"data": 4, "model": 2}
    spec = tuple(embed.sharding.spec)
    if sync == "gspmd":   # vocab table column-sharded on the model axis
        assert spec[-1] == "model"
    else:                 # manual program: per-device replicas
        assert all(s is None for s in spec) or spec == ()


def test_survivor_set_enumeration_matches_host(executors, host_trainer):
    """The full §3.1 sweep: every recoverable failure set's mesh gradient
    equals both the host gradient under the same schedule and the
    vanilla-DP oracle."""
    from repro.exec import survivor_set_sweep
    checks = survivor_set_sweep(executors["shard_map"], host_trainer)
    assert checks, "n=4, r=2 must have recoverable failure sets"
    # n=4, r=2 (cyclic Golomb): all 4 singles recover; doubles survive
    # only when no type loses both hosts
    assert len([c for c in checks if len(c.victims) == 1]) == 4
    assert any(c.s_a == 2 for c in checks), \
        "recovery at n=4,r=2 must raise the committed stack depth"
    bad = [c for c in checks if not c.ok(TOL)]
    assert not bad, f"survivor sets violating §3.1 on the mesh: {bad}"


# ------------------------------------------------------------------ #
# zero extra collectives + no recompile on re-weight                 #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("sync", ["shard_map", "gspmd"])
def test_masked_step_has_identical_collectives(cfg, executors, sync):
    """Masking a failure changes the weight *data*, never the program:
    compiled HLO of the masked step carries exactly the collectives of
    the unmasked step at the same S_A."""
    from repro.core import Rectlr, SpareState
    from repro.launch.hlo import collective_report

    ex = executors[sync]
    masked = SpareState(4, 2)
    outcome = Rectlr().on_failures(masked, [0])
    assert not outcome.wipeout
    healthy = SpareState(4, 2)
    healthy.s_a = masked.s_a          # same depth => same batch shapes

    rep_healthy = collective_report(ex.compiled_step_text(state=healthy))
    rep_masked = collective_report(ex.compiled_step_text(state=masked))
    assert rep_healthy["counts"] == rep_masked["counts"]
    assert rep_healthy["bytes"] == rep_masked["bytes"]
    assert rep_healthy["counts"].get("all-reduce", 0) >= 1, \
        "the step must actually sync gradients over the wire"


def test_failure_reweights_live_run_without_recompile(cfg):
    """Rack-burst events from the scenario engine re-weight the live
    mesh step; executables are cached per S_A only."""
    from repro.train.injection import ScenarioInjector

    # n=8 groups on an (8, 1) mesh: r=3 needs the wider Golomb ruler,
    # and racks of 2 groups make every burst a genuine multi-group kill
    ex = _executor(cfg, "shard_map", n_groups=8, redundancy=3,
                   model_degree=1, per_type_batch=1)
    topo = ClusterTopology(n_groups=8, hosts_per_group=2,
                           hosts_per_rack=4)   # 2 DP groups per rack
    inj = ScenarioInjector(
        {"kind": "correlated", "scope": "rack", "burst_prob": 1.0,
         "mtbf": 600.0}, topo, n_groups=8, seconds_per_step=100.0, seed=3)
    rep = ex.run(12, injector=inj, verify_equivalence=True)
    assert rep.steps_done == 12
    assert rep.failures >= 1, "hot regime must hit inside 12 steps"
    assert rep.max_grad_check_err < 1e-2
    assert all(np.isfinite(rep.losses))
    # every compiled executable corresponds to a distinct S_A depth the
    # run actually visited — re-weights alone never recompile
    depths = {e.s_a_after for e in rep.events} | {1}
    assert set(ex.compiled_depths) <= depths
    assert rep.recompiles == len(ex.compiled_depths)


def test_dryrun_production_shardings_compile(cfg):
    """The launch/dryrun.py production cell wiring — FSDP x TP
    ``param_specs``, ``opt_specs``, ``batch_spec``, and the
    ``constrain_grad`` gradient pinning inside the layer scan — lowers,
    SPMD-partitions, and compiles on an emulated (4, 2) mesh. (This path
    imported modules that did not exist before repro.exec; keep it
    compiling.)"""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import batch_spec, opt_specs, param_specs
    from repro.launch.hlo import collective_report
    from repro.launch.mesh import dp_axes, make_emulated_mesh
    from repro.models import build_model
    from repro.optim import adamw_init
    from repro.train import make_train_step

    mesh = make_emulated_mesh(4, 2)
    model = build_model(cfg, mesh=mesh, dp_axes=dp_axes(False))
    p_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_spec = param_specs(p_shapes, cfg, multi_pod=False)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_spec)
    opt_shapes = jax.eval_shape(
        lambda p: adamw_init(p, moment_dtype=cfg.moment_dtype), p_shapes)
    o_shard = jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        opt_specs(opt_shapes, p_spec), is_leaf=lambda x: isinstance(x, P))
    bspec = batch_spec(8, mesh, False)
    batch = {"tokens": jax.ShapeDtypeStruct((1, 8, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((1, 8, 32), jnp.int32),
             "weights": jax.ShapeDtypeStruct((1, 8), jnp.float32)}
    b_shard = {"tokens": NamedSharding(mesh, P(None, bspec, None)),
               "labels": NamedSharding(mesh, P(None, bspec, None)),
               "weights": NamedSharding(mesh, P(None, bspec))}
    with mesh:
        step = make_train_step(model, grad_shardings=p_shard)
        compiled = jax.jit(
            step, in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        ).lower(p_shapes, opt_shapes, batch).compile()
    counts = collective_report(compiled.as_text())["counts"]
    assert counts.get("all-reduce", 0) >= 1
    # FSDP is live: weight grads reduce-scatter/all-gather, not only AR
    assert counts.get("all-gather", 0) >= 1


def test_per_host_feeding_matches_global_batch(executors):
    """``jax.make_array_from_callback`` feeding (each host materializes
    only its addressable rows, double-buffered) assembles byte-for-byte
    the global stacked batch — healthy and masked schedules alike."""
    from repro.core import Rectlr, SpareState
    from repro.data import spare_batch

    ex = executors["shard_map"]
    masked = SpareState(4, 2)
    Rectlr().on_failures(masked, [2])
    for state in (ex.state, masked):
        batch = ex._device_batch(step=3, state=state)
        full = spare_batch(ex.pipeline, state, 3)
        assert set(batch) == set(full)
        for k in full:
            np.testing.assert_array_equal(np.asarray(batch[k]), full[k],
                                          err_msg=k)


def test_bucketed_sync_collectives_independent_of_leaf_count(cfg):
    """The flat-bucket sync issues O(n_buckets) gradient all-reduces —
    a function of total gradient bytes and the bucket cap, never of how
    many parameter leaves the model has."""
    import jax

    from repro.launch.hlo import collective_report

    big = _executor(cfg, "shard_map")                  # one bucket
    small = _executor(cfg, "shard_map", bucket_mb=0.125)   # ~32k elems/bkt
    assert big._layout.n_buckets == 1
    assert small._layout.n_buckets > 1
    n_leaves = len(jax.tree.leaves(big.params))
    assert small._layout.n_buckets < n_leaves
    ar_big = collective_report(big.compiled_step_text())["counts"][
        "all-reduce"]
    ar_small = collective_report(small.compiled_step_text())["counts"][
        "all-reduce"]
    # same program otherwise (loss psums etc.); the only delta is the
    # extra bucket psums
    assert ar_small - ar_big == small._layout.n_buckets - 1
    # HLO inspection warmed the cache outside any run: the executor-level
    # counter sees it, and a later run at the same S_A will not recompile
    assert big.total_recompiles == 1
    assert big.compiled_depths == [1]


# ------------------------------------------------------------------ #
# compressed sync (grad_compress="int8_ef")                          #
# ------------------------------------------------------------------ #
def test_compressed_rejected_under_gspmd(cfg):
    with pytest.raises(ValueError, match="shard_map"):
        _executor(cfg, "gspmd", grad_compress="int8_ef")


def test_compressed_mesh_matches_host_within_quantization(
        compressed, host_trainer):
    from repro.exec import int8_sweep_tolerance, tree_max_rel_err
    err = tree_max_rel_err(compressed.mesh_grads(0),
                           host_trainer.spare_grads(0))
    assert err < int8_sweep_tolerance(4)
    assert err > 0, "compression must actually have happened"


def test_compressed_survivor_set_sweep(compressed, host_trainer):
    """§3.1 under compression: every recoverable survivor set's
    compressed mesh gradient equals the host/vanilla oracles within the
    quantization-tolerance oracle (single step, zero EF residuals)."""
    from repro.exec import int8_sweep_tolerance, survivor_set_sweep
    checks = survivor_set_sweep(compressed, host_trainer)
    assert len([c for c in checks if len(c.victims) == 1]) == 4
    tol = int8_sweep_tolerance(4)
    bad = [c for c in checks if not c.ok(tol)]
    assert not bad, f"survivor sets violating §3.1 under int8-EF: {bad}"


def test_compressed_masked_step_schedule_and_wire_ratio(cfg, executors,
                                                        compressed):
    """The two ISSUE-5 HLO gates at once: (a) masked and unmasked
    compressed steps carry the identical collective schedule (masking
    stays weight data under compression); (b) the compressed step's
    gradient-sync wire bytes come in at <= 0.3x of the fp32 bucketed
    baseline, with the payload actually int8 on the wire."""
    from repro.core import Rectlr, SpareState
    from repro.launch.hlo import (collective_report, same_collective_schedule,
                                  wire_byte_ratio)

    masked = SpareState(4, 2)
    outcome = Rectlr().on_failures(masked, [0])
    assert not outcome.wipeout
    healthy = SpareState(4, 2)
    healthy.s_a = masked.s_a

    t_healthy = compressed.compiled_step_text(state=healthy)
    t_masked = compressed.compiled_step_text(state=masked)
    assert same_collective_schedule(t_healthy, t_masked)

    rep = collective_report(t_healthy)
    int8_bytes = sum(v for k, v in rep["by_dtype"].items()
                     if k.endswith("/s8"))
    assert int8_bytes > 0.5 * rep["total_bytes"], \
        f"int8 payload should dominate the wire: {rep['by_dtype']}"

    t_base = executors["shard_map"].compiled_step_text(state=healthy)
    ratio = wire_byte_ratio(t_healthy, t_base)
    assert ratio <= 0.3, \
        f"compressed sync wire bytes {ratio:.3f}x of fp32 (> 0.3x)"


def test_compressed_run_recompiles_only_on_depth_and_keeps_ef(cfg):
    """Live compressed run: EF residuals are real device-local state
    (threaded, donated, nonzero after a step), snapshot/rollback
    restores them with shardings intact, and failure re-weights still
    never recompile at constant S_A."""
    import jax

    ex = _executor(cfg, "shard_map", grad_compress="int8_ef")
    rep = ex.run(3)
    assert all(np.isfinite(rep.losses))
    assert rep.recompiles == 1
    flat_ef = jax.tree.leaves(ex._ef_state)
    assert any(np.asarray(e).any() for e in flat_ef), \
        "EF residuals should be nonzero after real steps"

    ex._snapshot_now()
    saved = jax.tree.map(np.asarray, ex._ef_state)
    ex.run(2)
    changed = jax.tree.leaves(jax.tree.map(
        lambda a, b: not np.array_equal(np.asarray(a), b),
        ex._ef_state, saved))
    assert any(changed), "EF residuals should evolve step to step"

    step, (params, opt) = ex._rollback()
    for leaf, ref, shard in zip(jax.tree.leaves(ex._ef_state),
                                jax.tree.leaves(saved),
                                jax.tree.leaves(ex._ef_shard)):
        np.testing.assert_array_equal(np.asarray(leaf), ref)
        assert leaf.sharding == shard

    # wipe-out through the real loop: rollback + continue, EF intact
    fired = []

    def kill_adjacent(state):
        if not fired and state is ex.state:
            fired.append(True)
            return [0, 1]
        return []

    rep2 = ex.run(4, injector=kill_adjacent)
    assert rep2.wipeouts == 1
    assert all(np.isfinite(rep2.losses))
    assert jax.tree.leaves(ex._ef_state)[0].sharding == \
        jax.tree.leaves(ex._ef_shard)[0]


def test_wipeout_rolls_back_resharded_params(cfg):
    """A wipe-out mid-run restores snapshot params/opt with the mesh
    shardings intact and keeps training."""
    ex = _executor(cfg, "shard_map", n_groups=4, redundancy=2)

    fired = []

    def kill_adjacent(state):
        # groups 0 and 1 are both hosts of type 0 at r=2 -> wipe-out
        if not fired and state is ex.state:
            fired.append(True)
            return [0, 1]
        return []

    rep = ex.run(6, injector=lambda st: kill_adjacent(st))
    assert rep.wipeouts == 1
    assert rep.steps_done >= 6
    assert ex.params["embed"].sharding == ex._pshard["embed"]
    assert all(np.isfinite(rep.losses))
