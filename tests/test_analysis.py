"""repro.analysis: every pass must fire on a violating fixture and stay
quiet on a known-good one — a lint that can't fail proves nothing.

AST fixtures are inline sources through :func:`lint_source`; HLO
fixtures are hand-written module texts (no jax compile needed), plus a
fake executor that exercises the survivor-sweep driver logic.
"""
import json
import textwrap

import pytest

from repro.analysis import (Report, Violation, lint_source,
                            run_ast_passes)
from repro.analysis.core import iter_source_files, suppressed_lines
from repro.analysis.hlo_passes import (donation_audit, ef_state_policy,
                                       entry_param_shapes, hot_path_purity,
                                       parse_input_output_alias,
                                       schedule_determinism_cell,
                                       schedule_determinism_executor,
                                       wire_dtype_policy)


def _rules(src: str) -> set[str]:
    kept, _ = lint_source("fixture.py", textwrap.dedent(src))
    return {v.rule for v in kept}


# ------------------------------------------------------------------ #
# determinism lint                                                   #
# ------------------------------------------------------------------ #
def test_wall_clock_fires_and_good_is_quiet():
    assert "wall-clock" in _rules("""
        import time
        t0 = time.time()
    """)
    assert "wall-clock" in _rules("""
        from datetime import datetime
        stamp = datetime.now()
    """)
    assert _rules("""
        import time
        t0 = time.perf_counter()
        t1 = time.monotonic()
    """) == set()


def test_unseeded_random_fires_and_generator_is_quiet():
    assert "unseeded-random" in _rules("""
        import random
        x = random.choice([1, 2])
    """)
    assert "unseeded-random" in _rules("""
        import numpy as np
        x = np.random.rand(3)
    """)
    assert _rules("""
        import numpy as np
        rng = np.random.default_rng(7)
        x = rng.normal(size=3)
    """) == set()


def test_set_iteration_and_builtin_hash():
    assert "set-iteration" in _rules("""
        for x in {1, 2, 3}:
            print(x)
    """)
    assert "builtin-hash" in _rules("""
        key = hash("name")
    """)
    assert _rules("""
        for x in sorted({1, 2, 3}):
            print(x)
    """) == set()


def test_mutable_default_function_and_dataclass():
    assert "mutable-default" in _rules("""
        def f(xs=[]):
            return xs
    """)
    assert "mutable-default" in _rules("""
        from dataclasses import dataclass
        @dataclass
        class C:
            xs: list = []
    """)
    assert _rules("""
        from dataclasses import dataclass, field
        @dataclass
        class C:
            xs: list = field(default_factory=list)
    """) == set()


# ------------------------------------------------------------------ #
# thread-sharing audit                                               #
# ------------------------------------------------------------------ #
def test_thread_target_writing_self_attr_fires():
    assert "thread-shared-state" in _rules("""
        import threading
        class W:
            def start(self):
                self._t = threading.Thread(target=self._work)
            def _work(self):
                self.result = 1
    """)


def test_thread_closure_nonlocal_rebind_fires():
    assert "thread-shared-state" in _rules("""
        def run(pool):
            done = False
            def work():
                nonlocal done
                done = True
            pool.submit(work)
    """)


def test_late_binding_capture_fires():
    assert "thread-shared-state" in _rules("""
        def run(pool):
            item = 1
            def work():
                return item
            pool.submit(work)
            item = 2
    """)


def test_snapshot_at_submit_is_quiet():
    # the sanctioned pattern: pass state by argument at submit time
    assert _rules("""
        def run(pool, items):
            snapshot = list(items)
            def work(data):
                return sum(data)
            pool.submit(work, snapshot)
    """) == set()


# ------------------------------------------------------------------ #
# suppression + robustness                                           #
# ------------------------------------------------------------------ #
def test_inline_suppression_diverts_finding():
    src = textwrap.dedent("""
        import time
        t0 = time.time()  # lint: ignore[wall-clock] -- provenance stamp
    """)
    kept, quiet = lint_source("fixture.py", src)
    assert [v.rule for v in kept] == []
    assert [v.rule for v in quiet] == ["wall-clock"]


def test_suppression_is_rule_scoped():
    src = textwrap.dedent("""
        import time
        t0 = time.time()  # lint: ignore[unseeded-random]
    """)
    kept, quiet = lint_source("fixture.py", src)
    assert [v.rule for v in kept] == ["wall-clock"]   # wrong rule named
    assert quiet == []


def test_skip_file_exempts_everything():
    src = "# lint: skip-file\nimport time\nt0 = time.time()\n"
    assert lint_source("vendored.py", src) == ([], [])


def test_syntax_error_is_a_finding_not_a_crash():
    kept, _ = lint_source("broken.py", "def f(:\n")
    assert [v.rule for v in kept] == ["parse-error"]


def test_suppressed_lines_parses_multi_rule():
    src = "x = 1  # lint: ignore[wall-clock, builtin-hash]\n"
    assert suppressed_lines(src) == {1: {"wall-clock", "builtin-hash"}}


# ------------------------------------------------------------------ #
# HLO passes on hand-written programs                                #
# ------------------------------------------------------------------ #
_ALIASED_HEADER = (
    'HloModule jit_step, input_output_alias={ {0}: (0, {}, may-alias), '
    '{1}: (1, {}, may-alias) }, entry_computation_layout='
    '{(f32[4,4], f32[4,4], f32[2,4])->(f32[4,4], f32[4,4])}\n')


def _hlo(header: str, body: str = "") -> str:
    return (header + "\nENTRY %main (p0: f32[4,4]) -> f32[4,4] {\n"
            "  %p0 = f32[4,4] parameter(0)\n" + body +
            "  ROOT %r = f32[4,4] add(%p0, %p0)\n}\n")


def test_alias_header_parsing():
    assert parse_input_output_alias(_ALIASED_HEADER) == [0, 1]
    assert entry_param_shapes(_ALIASED_HEADER) == \
        ["f32[4,4]", "f32[4,4]", "f32[2,4]"]


def test_donation_audit_fires_on_unaliased_and_passes_on_aliased():
    text = _hlo(_ALIASED_HEADER)
    assert donation_audit(text, 2, "prog") == []          # 2 donated, 2 aliased
    found = donation_audit(text, 3, "prog", donated_range=(0, 3))
    assert [v.rule for v in found] == ["donation-audit"]
    assert "f32[2,4]" in found[0].message                 # names the gap


def test_hot_path_purity_fires_on_host_ops_and_f64():
    clean = _hlo(_ALIASED_HEADER)
    assert hot_path_purity(clean, "prog") == []
    outfeed = _hlo(_ALIASED_HEADER,
                   "  %of = token[] outfeed(%p0, %p0)\n")
    assert any(v.rule == "hot-path-purity" for v in
               hot_path_purity(outfeed, "prog"))
    callback = _hlo(_ALIASED_HEADER,
                    '  %cb = f32[4,4] custom-call(%p0), '
                    'custom_call_target="xla_python_cpu_callback"\n')
    assert any("callback" in v.message for v in
               hot_path_purity(callback, "prog"))
    wide = _hlo(_ALIASED_HEADER, "  %w = f64[4,4] convert(%p0)\n")
    assert any("fp64" in v.message for v in hot_path_purity(wide, "prog"))


def test_wire_dtype_policy_fires_on_int_reduction_only():
    bad = _hlo(_ALIASED_HEADER,
               "  %q = s8[64] convert(%p0)\n"
               "  %ar = s8[64] all-reduce(%q), replica_groups={{0,1}}, "
               "to_apply=%add\n")
    assert [v.rule for v in wire_dtype_policy(bad, "prog")] == \
        ["wire-dtype-policy"]
    ok = _hlo(_ALIASED_HEADER,
              "  %q = s8[64] convert(%p0)\n"
              "  %a2a = s8[64] all-to-all(%q), replica_groups={{0,1}}, "
              "dimensions={0}\n")
    assert wire_dtype_policy(ok, "prog") == []


def test_ef_state_policy_on_fake_executor():
    import numpy as np

    class Fake:
        _grad_sync = object()
        _ef_state = {"bucket0": np.zeros(4, np.float32)}

    assert ef_state_policy(Fake(), "ex") == []
    Fake._ef_state = {"bucket0": np.zeros(4, np.float16)}
    assert [v.rule for v in ef_state_policy(Fake(), "ex")] == \
        ["wire-dtype-policy"]


def _ar(dtype: str, dims: str) -> str:
    return (f"  %ar = {dtype}[{dims}] all-reduce(%p0), "
            "replica_groups={{0,1}}, to_apply=%add\n")


def test_schedule_determinism_cell_double_compile_and_liveness():
    a = _hlo(_ALIASED_HEADER, _ar("f32", "4,4"))
    b = _hlo(_ALIASED_HEADER, _ar("f32", "4,4") + _ar("f32", "4,4"))
    assert schedule_determinism_cell(a, a, "cell") == []
    assert any("different" in v.message or "disagree" in v.message
               for v in schedule_determinism_cell(a, b, "cell"))
    # weight-table liveness: f32[2,4] is an entry param, f32[9,9] is not
    assert schedule_determinism_cell(a, a, "cell",
                                     weights_shape="f32[2,4]") == []
    found = schedule_determinism_cell(a, a, "cell",
                                      weights_shape="f32[9,9]")
    assert any("live entry parameter" in v.message for v in found)


def test_schedule_determinism_executor_sweep():
    """The survivor-sweep driver on a fake executor: a schedule that
    depends on WHICH group failed (not just S_A) must be caught."""
    from repro.core import SpareState

    class FakeExec:
        def __init__(self, poisoned_victim=None):
            self.state = SpareState(4, 2)
            self.poisoned = poisoned_victim

        def compiled_step_text(self, state=None):
            dead = sorted(set(range(4)) - set(state.survivors))
            if self.poisoned is not None and self.poisoned in dead:
                return _hlo(_ALIASED_HEADER, _ar("f32", "4,4") * 2)
            return _hlo(_ALIASED_HEADER, _ar("f32", "4,4"))

    clean, n = schedule_determinism_executor(FakeExec(), "ex")
    assert clean == [] and n > 0
    dirty, _ = schedule_determinism_executor(FakeExec(poisoned_victim=2),
                                             "ex")
    assert any(v.rule == "collective-schedule-determinism" for v in dirty)


# ------------------------------------------------------------------ #
# report plumbing                                                    #
# ------------------------------------------------------------------ #
def test_repo_walk_and_json_report_are_deterministic(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "mod.py").write_text(
        "import time\nt0 = time.time()\n")
    (tmp_path / "src" / "ok.py").write_text("x = 1\n")
    assert [p.name for p in iter_source_files(tmp_path)] == \
        ["mod.py", "ok.py"]

    r1 = run_ast_passes(tmp_path)
    r2 = run_ast_passes(tmp_path)
    assert not r1.clean
    assert r1.to_json() == r2.to_json()          # byte-identical reports
    payload = json.loads(r1.to_json())
    assert payload["violations"][0]["rule"] == "wall-clock"
    assert payload["summary"]["ast"]["files_scanned"] == 2


def test_report_merge_json_roundtrip():
    child = Report()
    child.extend([Violation("prog", 0, "donation-audit", "boom")])
    child.note("donation-audit", donated_leaves_audited=5)
    parent = Report()
    parent.merge_json(child.to_json())
    parent.merge_json(child.to_json())
    assert len(parent.violations) == 2
    assert parent.summary["donation-audit"]["donated_leaves_audited"] == 10
