"""Telemetry substrate tests: span recorder, metrics registry, and the
instrumented trainer/executor paths.

The gates ISSUE 7 promises:

* span nesting survives a Chrome-trace export/load round trip;
* histogram quantiles are numpy-exact (no sketch drift under p99.9);
* the telemetry-off path allocates NOTHING in ``repro.obs`` across a
  multi-step trainer run (tracemalloc-audited);
* two seeded runs under a deterministic clock export byte-identical
  traces and metrics snapshots;
* the obs CLI attributes >= 95% of a real (wall-clock) run into named
  phases and exits 0 under its own assert flags;
* masked and unmasked schedules at equal ``S_A`` publish identical
  wire-traffic metrics on the 8-device mesh (``spmd``-marked).
"""
import json
import os
import tracemalloc
import types

import numpy as np
import pytest

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               latency_stats, quantile_key)
from repro.obs.trace import (NULL_SPAN, Telemetry, TraceRecorder,
                             load_trace, maybe_span, tick)


@pytest.fixture(scope="module")
def cfg():
    from repro.configs import smoke_config
    return smoke_config("qwen2.5-3b").scaled(grad_accum=1)


def _trainer(cfg, tel=None, *, n=6, r=2):
    from repro.train.trainer import SpareTrainer
    return SpareTrainer(cfg, n_groups=n, redundancy=r, seq=32,
                        per_type_batch=1, total_steps=100, telemetry=tel)


# ------------------------------------------------------------------ #
# trace recorder: nesting + export round trip                        #
# ------------------------------------------------------------------ #
def test_span_nesting_export_round_trip(tmp_path):
    rec = TraceRecorder(clock=tick())
    with rec.span("step", args={"step": 0}):
        with rec.span("compute"):
            with rec.span("feed"):
                pass
    rec.instant("failure", track="dp/1", args={"step": 0})
    with rec.span("recover", args={"victims": [1], "wipeout": False}):
        pass
    with rec.span("step", args={"step": 1}):
        pass

    path = tmp_path / "t.json"
    rec.dump(path)
    for view in (load_trace(str(path)), load_trace(rec.dumps()),
                 load_trace(rec.to_chrome())):
        assert view.tracks == ["dp/1", "main"]
        steps = view.named("step")
        assert [s.depth for s in steps] == [0, 0]
        assert [s.args["step"] for s in steps] == [0, 1]
        (compute,) = view.named("compute")
        (feed,) = view.named("feed")
        assert (compute.depth, feed.depth) == (1, 2)
        # containment: child strictly inside parent
        assert steps[0].ts <= compute.ts and compute.end <= steps[0].end
        assert compute.ts <= feed.ts and feed.end <= compute.end
        (rc,) = view.named("recover")
        assert rc.depth == 0 and rc.args["victims"] == [1]
        (inst,) = view.instants
        assert (inst.name, inst.track) == ("failure", "dp/1")
        assert view.wall_us("main") > 0


def test_trace_is_valid_chrome_format():
    rec = TraceRecorder(clock=tick())
    with rec.span("step"):
        pass
    rec.instant("failure", track="dp/0")
    doc = json.loads(rec.dumps())
    evs = doc["traceEvents"]
    assert {e["ph"] for e in evs} == {"M", "X", "i"}
    (x,) = [e for e in evs if e["ph"] == "X"]
    assert x["tid"] == 0 and "dur" in x and "ts" in x
    (i,) = [e for e in evs if e["ph"] == "i"]
    assert i["s"] == "t"
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert names == {"main", "dp/0"}


# ------------------------------------------------------------------ #
# metrics: exact quantiles, registry, latency stats                  #
# ------------------------------------------------------------------ #
def test_histogram_quantiles_numpy_exact():
    rng = np.random.default_rng(0)
    vals = np.concatenate([rng.normal(10.0, 3.0, 997),
                           rng.exponential(50.0, 211)])
    h = Histogram()
    h.observe_many(vals[:500])
    for v in vals[500:]:
        h.observe(float(v))
    assert h.count == len(vals)
    assert h.sum == pytest.approx(float(vals.sum()))
    for q in (0.0, 1.0, 25.0, 50.0, 90.0, 99.0, 99.9, 100.0):
        assert h.quantile(q) == float(np.percentile(vals, q))
    s = h.summary(quantiles=(50.0, 99.9))
    assert s["p99_9"] == float(np.percentile(vals, 99.9))
    assert s["count"] == len(vals)


def test_histogram_empty():
    h = Histogram()
    assert h.summary() == {"count": 0}
    with pytest.raises(ValueError):
        h.quantile(50.0)


def test_quantile_key():
    assert quantile_key(50) == "p50"
    assert quantile_key(99.9) == "p99_9"
    assert quantile_key(99.0) == "p99"


def test_registry_get_or_create_and_collisions():
    reg = MetricsRegistry()
    c = reg.counter("train.steps")
    assert reg.counter("train.steps") is c
    c.inc()
    c.inc(4)
    reg.gauge("train.s_a").set(2)
    reg.histogram("lat").observe_many([1.0, 2.0, 3.0])
    with pytest.raises(TypeError):
        reg.gauge("train.steps")
    snap = reg.snapshot()
    assert snap["counters"]["train.steps"] == 5
    assert snap["gauges"]["train.s_a"] == 2
    assert snap["histograms"]["lat"]["count"] == 3
    assert "train.steps" in reg and "nope" not in reg
    # identical observation sequences snapshot byte-identically
    reg2 = MetricsRegistry()
    reg2.counter("train.steps").inc(5)
    reg2.gauge("train.s_a").set(2)
    reg2.histogram("lat").observe_many([1.0, 2.0, 3.0])
    assert reg.dumps() == reg2.dumps()


def test_latency_stats_p999():
    rng = np.random.default_rng(1)
    lats = [rng.exponential(0.01, 40) for _ in range(25)]
    done = [types.SimpleNamespace(latencies=l) for l in lats]
    out = latency_stats(done)
    allv = np.concatenate(lats)
    assert out["tokens"] == allv.size
    for q, key in ((50.0, "p50_ms"), (99.0, "p99_ms"), (99.9, "p99_9_ms")):
        assert out[key] == round(float(np.percentile(allv, q)) * 1e3, 3)
    empty = latency_stats([])
    assert empty == {"tokens": 0, "p50_ms": None, "p99_ms": None,
                     "p99_9_ms": None}


def test_exec_cache_counters_are_registry_entries():
    """Satellite gate: the serving ExecutableCache's miss/hit counters
    ARE the metrics registry's — snapshot and cache cannot diverge."""
    from repro.serve.engine import ExecutableCache
    reg = MetricsRegistry()
    cache = ExecutableCache(reg)
    assert cache.get(("decode", 8), lambda: "exe-a") == "exe-a"
    assert cache.get(("decode", 8), lambda: "never") == "exe-a"
    assert cache.get(("prefill", 8), lambda: "exe-b") == "exe-b"
    snap = reg.snapshot()["counters"]
    assert (cache.misses, cache.hits) == (2, 1)
    assert snap["serve.exec_cache.misses"] == 2
    assert snap["serve.exec_cache.hits"] == 1
    # standalone cache still counts, just privately
    solo = ExecutableCache()
    solo.get(("k",), lambda: 1)
    assert (solo.misses, solo.hits) == (1, 0)


# ------------------------------------------------------------------ #
# the telemetry-off hot path is allocation-free                      #
# ------------------------------------------------------------------ #
def test_null_span_is_a_singleton():
    assert maybe_span(None, "step") is NULL_SPAN
    assert maybe_span(None, "x", "dp/0", None) is NULL_SPAN
    with maybe_span(None, "step") as s:
        assert s is None
    # metrics-only telemetry still measures durations (no recording)
    tel_off = Telemetry(trace=False, clock=tick())
    with tel_off.span("step") as sp:
        pass
    assert sp.dur > 0 and tel_off.tracer is None


def test_telemetry_off_trainer_run_allocates_nothing_in_obs(cfg):
    """Run the real train loop (make_train_step dispatch included) with
    telemetry=None under tracemalloc: zero bytes may be attributed to
    any file in ``repro/obs``."""
    import repro.obs.trace as trace_mod
    tr = _trainer(cfg, None, n=4, r=2)
    tr.run(1)                      # compile outside the audited window
    obs_glob = os.path.join(os.path.dirname(trace_mod.__file__), "*")
    tracemalloc.start()
    try:
        tr.run(3)
        snap = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    obs_allocs = snap.filter_traces([tracemalloc.Filter(True, obs_glob)])
    leaked = sum(st.size for st in obs_allocs.statistics("filename"))
    assert leaked == 0, f"telemetry-off path allocated {leaked}B in obs"


# ------------------------------------------------------------------ #
# instrumented trainer: determinism + recovery accounting            #
# ------------------------------------------------------------------ #
def _seeded_traced_run(cfg):
    from repro.train.trainer import PoissonInjector
    tel = Telemetry(clock=tick())
    tr = _trainer(cfg, tel)
    rep = tr.run(8, injector=PoissonInjector(2.0, seed=7))
    return tel, rep


def test_trace_and_metrics_byte_determinism(cfg):
    tel_a, rep_a = _seeded_traced_run(cfg)
    tel_b, rep_b = _seeded_traced_run(cfg)
    assert rep_a.failures == rep_b.failures > 0
    assert tel_a.tracer.dumps() == tel_b.tracer.dumps()
    assert tel_a.metrics.dumps() == tel_b.metrics.dumps()
    snap = tel_a.snapshot()
    # wipe-outs roll the step counter back, so executed steps >= asked
    assert snap["counters"]["train.steps"] == rep_a.steps_done >= 8
    assert snap["counters"]["train.failures"] == rep_a.failures
    assert snap["histograms"]["train.step_seconds"]["count"] == \
        rep_a.steps_done
    assert snap["gauges"]["train.s_a"] is not None


def test_recovery_events_carry_durations(cfg):
    from repro.train.trainer import PoissonInjector
    tr = _trainer(cfg)
    # n=6, r=2, mtbf 1.5 steps: masks AND at least one wipe-out
    rep = tr.run(25, injector=PoissonInjector(1.5, seed=0),
                 snapshot_every=5)
    masks = [e for e in rep.events if not e.wipeout]
    wipes = [e for e in rep.events if e.wipeout]
    assert masks and wipes
    for e in rep.events:
        assert e.wall_seconds > 0           # measured host wall-clock
    for e in masks:
        assert e.step_seconds >= 0          # controller step-clock cost
        assert e.restart_seconds == 0.0 and e.rollback_depth == 0
    for e in wipes:
        assert e.restart_seconds == tr._t_restart > 0
        assert e.rollback_depth >= 0


def test_obs_cli_attribution_on_real_run(cfg, tmp_path, capsys):
    """Acceptance: a real (wall-clock) traced run analyzed by the obs
    CLI attributes >= 95% of main-track wall into named phases and
    carries failure markers + recovery spans."""
    from repro.launch import obs as obs_cli
    from repro.train.trainer import PoissonInjector
    tel = Telemetry()
    tr = _trainer(cfg, tel)
    rep = tr.run(6, injector=PoissonInjector(1.5, seed=3))
    assert rep.failures > 0
    path = tmp_path / "run.trace.json"
    tel.dump_trace(path)

    view = load_trace(str(path))
    ana = obs_cli.analyze(view)
    assert ana["coverage"] >= 0.95
    assert ana["failure_markers"] == rep.failures
    assert all(t.startswith("dp/") for t in ana["failure_tracks"])
    assert len(ana["recovery_events"]) == len(rep.events)
    kinds = {r["kind"] for r in ana["recovery_events"]}
    assert kinds <= {"mask", "restart"}
    phases = {p["phase"] for p in ana["phases"]}
    assert {"step", "compute"} <= phases

    rc = obs_cli.main([str(path), "--assert-coverage", "0.95",
                       "--assert-recovery-markers",
                       "--json", str(tmp_path / "rep.json")])
    assert rc == 0
    assert json.load(open(tmp_path / "rep.json"))["coverage"] >= 0.95
    # a trace with no failures must fail --assert-recovery-markers
    quiet = Telemetry(clock=tick())
    with quiet.span("step"):
        pass
    quiet.dump_trace(tmp_path / "quiet.json")
    capsys.readouterr()
    assert obs_cli.main([str(tmp_path / "quiet.json"),
                         "--assert-recovery-markers"]) == 1


# ------------------------------------------------------------------ #
# mesh executor: masked vs unmasked wire metrics (spmd)              #
# ------------------------------------------------------------------ #
@pytest.mark.spmd
def test_masked_vs_unmasked_wire_metrics_parity(cfg):
    """SPARe's no-recompile thesis through the metrics lens: a masked
    schedule at the same S_A publishes byte-identical wire-traffic
    gauges (the HLO-derived collective accounting) as the healthy one."""
    from repro.core import Rectlr, SpareState
    from repro.exec import MeshExecutor
    tel = Telemetry(trace=False)
    ex = MeshExecutor(cfg, n_groups=4, redundancy=2, model_degree=2,
                      seq=32, per_type_batch=2, total_steps=50,
                      sync="shard_map", telemetry=tel)
    masked = SpareState(4, 2)
    Rectlr().on_failures(masked, [0])
    healthy = SpareState(4, 2)
    healthy.s_a = masked.s_a          # same depth => same batch shapes

    readings = {}
    for label, st in (("masked", masked), ("healthy", healthy)):
        ex.state = st
        ex._wire_info.clear()         # force fresh HLO accounting
        ex.run(1)
        snap = tel.snapshot()["gauges"]
        readings[label] = (snap["sync.wire_bytes_per_step"],
                           snap["sync.collectives_per_step"])
    assert readings["masked"] == readings["healthy"]
    assert readings["healthy"][0] > 0 and readings["healthy"][1] > 0
    assert tel.snapshot()["counters"]["sync.wire_bytes_total"] == \
        readings["healthy"][0] * 2
