"""FaultToleranceScheme API: registry, engine parity, adaptive policy.

Parity tests prove the schemes ported onto the shared engine
(:mod:`repro.des.engine`) reproduce the frozen pre-refactor loops
(:mod:`repro.des._legacy`) bit-for-bit: same RNG-draw order, hence equal
walls, committed work, and event counts at every fixed seed.
"""
import numpy as np
import pytest

from repro.des import (
    AdaptiveScheme,
    DESParams,
    FaultToleranceScheme,
    get_scheme,
    list_schemes,
    register_scheme,
    run_scheme,
    simulate_spare,
)
from repro.des._legacy import (
    legacy_ckpt_only,
    legacy_replication,
    legacy_spare,
)

# controller_seconds is wall-clock-measured (perf_counter) inside RECTLR,
# so it is excluded from bit-for-bit comparison
_EXACT_FIELDS = ("scheme", "n", "r", "wall", "committed", "t0", "steps_done",
                 "node_failures", "wipeouts", "ckpt_count", "total_stacks",
                 "patches")


def assert_bitwise_equal(a, b):
    for f in _EXACT_FIELDS:
        assert getattr(a, f) == getattr(b, f), (
            f"{f}: engine={getattr(a, f)!r} legacy={getattr(b, f)!r}")


def short(n=200, steps=200, **kw):
    return DESParams(n=n, steps=steps).with_(**kw)


# ------------------------------------------------------------------ #
# registry round-trip                                                 #
# ------------------------------------------------------------------ #
def test_registry_lists_all_builtin_schemes():
    assert list_schemes() == ["adaptive", "ckpt_only", "replication", "spare"]


@pytest.mark.parametrize("name,kwargs", [
    ("ckpt_only", {}),
    ("replication", {"r": 3}),
    ("spare", {"r": 9}),
    ("adaptive", {"r": 9}),
])
def test_registry_round_trip(name, kwargs):
    scheme = get_scheme(name, **kwargs)
    assert isinstance(scheme, FaultToleranceScheme)
    assert scheme.name == name
    res = scheme.simulate(short(steps=50), seed=0)
    assert res.scheme == name
    assert res.steps_done > 0


def test_unknown_scheme_raises_with_candidates():
    with pytest.raises(KeyError, match="spare"):
        get_scheme("does_not_exist")


def test_register_scheme_extends_registry():
    @register_scheme
    class NullScheme(get_scheme("ckpt_only").__class__):
        name = "null_test_scheme"

    try:
        assert "null_test_scheme" in list_schemes()
        assert isinstance(get_scheme("null_test_scheme"), NullScheme)
    finally:
        from repro.des.schemes import _REGISTRY
        _REGISTRY.pop("null_test_scheme")


def test_predicted_overhead_delegates_to_theory():
    p = short()
    j_ckpt = get_scheme("ckpt_only").predicted_overhead(p)
    j_spare = get_scheme("spare", r=9).predicted_overhead(p)
    j_rep = get_scheme("replication", r=2).predicted_overhead(p)
    # restart-dominant Table-1 regime: SPARe's closed form must win
    assert j_spare < j_rep < j_ckpt
    # adaptive predicts the envelope
    j_ad = get_scheme("adaptive", r=9).predicted_overhead(p)
    assert j_ad == min(j_ckpt, j_spare, j_rep)


# ------------------------------------------------------------------ #
# bit-for-bit parity with the legacy loops                            #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("seed", [0, 1, 7, 123])
def test_parity_ckpt_only(seed):
    p = short()
    assert_bitwise_equal(get_scheme("ckpt_only").simulate(p, seed=seed),
                         legacy_ckpt_only(p, seed=seed))


@pytest.mark.parametrize("seed", [0, 1, 7])
@pytest.mark.parametrize("r", [2, 3, 4])
def test_parity_replication(r, seed):
    p = short()
    assert_bitwise_equal(get_scheme("replication", r=r).simulate(p, seed=seed),
                         legacy_replication(p, r=r, seed=seed))


@pytest.mark.parametrize("seed", [0, 1, 7])
@pytest.mark.parametrize("r", [2, 6, 9])
def test_parity_spare(r, seed):
    p = short()
    assert_bitwise_equal(get_scheme("spare", r=r).simulate(p, seed=seed),
                         legacy_spare(p, r=r, seed=seed))


@pytest.mark.parametrize("kwargs", [
    {"dynamic_ckpt": True},
    {"binary_search": True},
    {"straggler_frac": 0.05, "straggler_slowdown": 5.0},
    {"dynamic_ckpt": True, "straggler_frac": 0.05},
])
def test_parity_spare_feature_flags(kwargs):
    p = short()
    assert_bitwise_equal(
        get_scheme("spare", r=9, **kwargs).simulate(p, seed=3),
        legacy_spare(p, r=9, seed=3, **kwargs))


def test_parity_exponential_law_and_explicit_tc():
    p = short(failure_law="exponential")
    assert_bitwise_equal(
        get_scheme("spare", r=9).simulate(p, seed=0, t_c=500.0),
        legacy_spare(p, r=9, seed=0, t_c=500.0))
    assert_bitwise_equal(
        get_scheme("ckpt_only").simulate(p, seed=0, max_wall=1e5),
        legacy_ckpt_only(p, seed=0, max_wall=1e5))


def test_deprecated_aliases_still_work_and_warn():
    p = short(steps=50)
    with pytest.deprecated_call():
        res = simulate_spare(p, r=9, seed=0)
    assert_bitwise_equal(res, legacy_spare(p, r=9, seed=0))


def test_scheme_instance_is_reusable_across_runs():
    """bind() must fully reset per-run state: back-to-back simulate calls
    at the same seed give identical results."""
    p = short(steps=150)
    scheme = get_scheme("spare", r=6)
    a = scheme.simulate(p, seed=5)
    b = scheme.simulate(p, seed=5)
    assert_bitwise_equal(a, b)


# ------------------------------------------------------------------ #
# adaptive scheme                                                     #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("mtbf", [1e9, 1000.0, 300.0])
def test_adaptive_tracks_best_fixed_scheme(mtbf):
    """Acceptance criterion: on a mixed-MTBF sweep (quiet / moderate /
    restart-dominant) the adaptive selector's wall-clock is within 5% of
    the best single fixed scheme."""
    p = short(steps=250, mtbf=mtbf)
    ad = get_scheme("adaptive", r=9).simulate(p, seed=0)
    fixed = [
        get_scheme("ckpt_only").simulate(p, seed=0).wall,
        get_scheme("replication", r=2).simulate(p, seed=0).wall,
        get_scheme("spare", r=9).simulate(p, seed=0).wall,
    ]
    assert ad.steps_done == p.steps
    assert ad.wall <= min(fixed) * 1.05


def test_adaptive_switches_out_of_wrong_initial_mode():
    """Forced to start as vanilla ckpt-only in the restart-dominant
    regime, the selector must learn the observed rate and move to SPARe,
    landing near the pure-SPARe wall instead of the ckpt-only disaster."""
    p = short(steps=250)   # MTBF 300 s — Table-1 storm
    ad_scheme = AdaptiveScheme(r=9, initial="ckpt_only")
    ad = run_scheme(ad_scheme, p, seed=0)
    spare = get_scheme("spare", r=9).simulate(p, seed=0)
    ckpt = get_scheme("ckpt_only").simulate(p, seed=0)
    assert ad.mode_switches >= 1
    assert ad_scheme.mode_name == "spare"
    assert ad.wall < ckpt.wall * 0.25          # escaped the disaster
    assert ad.wall <= spare.wall * 1.25        # close to the oracle policy
    # the history log records the trajectory
    assert [m for _, m in ad_scheme.history][0] == "ckpt_only"
    assert [m for _, m in ad_scheme.history][-1] == "spare"


def test_adaptive_stays_cheap_on_quiet_cluster():
    p = short(steps=200, mtbf=1e12, jitter_std=0.0)
    ad_scheme = AdaptiveScheme(r=9)
    res = run_scheme(ad_scheme, p, seed=0)
    assert ad_scheme.mode_name in ("ckpt_only", "spare")  # 1-stack policies
    assert res.mode_switches == 0
    assert res.ttt_norm == pytest.approx(1.0, abs=0.05)


def test_adaptive_result_metadata():
    res = get_scheme("adaptive", r=9).simulate(short(steps=80), seed=1)
    assert res.scheme == "adaptive"
    assert res.r == 9
    assert res.mode_switches >= 0


# ------------------------------------------------------------------ #
# engine/result invariants                                            #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("name,kwargs", [
    ("ckpt_only", {}),
    ("replication", {"r": 3}),
    ("spare", {"r": 9}),
    ("adaptive", {"r": 9}),
])
def test_availability_bounded(name, kwargs):
    res = get_scheme(name, **kwargs).simulate(short(steps=120), seed=2)
    assert 0.0 < res.availability <= 1.0
    assert res.wall >= res.committed


def test_trainer_consumes_scheme_object():
    """The trainer's recovery decisions go through the same scheme API the
    DES runs on (ckpt_only scheme => every failure is a wipe-out)."""
    from repro.core import SpareState

    state = SpareState(8, 3)
    ck = get_scheme("ckpt_only")
    out = ck.recover(state, [1])
    assert out.wipeout

    sp = get_scheme("spare", r=3)
    state2 = SpareState(8, 3)
    out2 = sp.recover(state2, [1])
    assert not out2.wipeout
    assert state2.prefix_coverage().all()


def test_adaptive_live_protocol_switches_on_observed_storm():
    """Trainer-facing adaptation (prepare/recover, no DES clock): forced
    to start as ckpt-only, the selector must re-evaluate at the wipe-out
    boundary from the step-time failure rate and move to SPARe."""
    from repro.core import SpareState

    ad = AdaptiveScheme(r=4, initial="ckpt_only")
    ad.prepare(DESParams(n=16, mtbf=300.0))
    assert ad.mode_name == "ckpt_only"
    state = SpareState(16, 4)
    out = ad.recover(state, [3], step=5)     # ckpt_only: instant wipe-out
    assert out.wipeout
    assert ad.mode_name == "spare"           # observed storm => SPARe
    assert ad.mode_switches == 1
    state.reset()
    out2 = ad.recover(state, [3], step=10)   # now masked, not wiped
    assert not out2.wipeout
    assert state.prefix_coverage().all()


def test_adaptive_live_prepare_picks_prior_best_mode():
    quiet = AdaptiveScheme(r=4)
    quiet.prepare(DESParams(n=16, mtbf=1e12))
    assert quiet.mode_name == "ckpt_only"    # no failures => cheapest

    storm = AdaptiveScheme(r=4)
    storm.prepare(DESParams(n=16, mtbf=300.0))
    assert storm.mode_name == "spare"


def test_poisson_injector_scales_rate_with_n_groups():
    """Regression: n_groups used to be silently ignored."""
    from repro.train.trainer import PoissonInjector

    per_group = PoissonInjector(40.0, seed=0, n_groups=8)
    system = PoissonInjector(40.0, seed=0, n_groups=0)
    assert per_group.mean == pytest.approx(5.0)
    assert system.mean == pytest.approx(40.0)

    # rate actually applies to the arrivals: ~n/5 failures in n steps
    class _State:
        survivors = np.arange(8)

    hits = sum(len(per_group(_State())) for _ in range(400))
    assert 50 <= hits <= 110   # mean 80
