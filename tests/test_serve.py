"""Serving-tier tests: fused prefill, paged KV cache, continuous
batching, SPARe replica masking.

Layers, bottom-up:

* decode-vs-forward parity for EVERY archetype — the oracle the engine
  rides on (token-by-token ``make_serve_step`` vs one ``model.forward``);
* fused prefill: logits equal the forward's, the returned cache is
  leaf-compatible with ``init_decode_state`` and hands off to decode
  bit-exactly (including the SSM exact-length subtlety);
* paged pools: block alloc/free determinism, paged decode == dense
  decode, no cross-sequence leakage even from a fully dirtied pool;
* engine: continuous batching completes everything and matches the
  dense-decode oracle, with the executable cache frozen after warmup;
* replicas: a rack-burst campaign drops zero requests, produces
  bit-identical outputs to the healthy run, and never recompiles;
  wipe-out reloads from the checkpoint tier;
* (spmd) masked-vs-unmasked replica decode programs share one
  collective schedule on the 8-device emulated mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.data import RequestStream
from repro.models.model import build_model
from repro.serve import (BlockAllocator, ServeEngine, ReplicaServer,
                         pages_needed, pool_pages_for)
from repro.train import make_prefill, make_serve_step

ARCH_IDS = sorted(ARCHS)
# one per attention/mixer archetype: GQA, MLA, pure SSM, hybrid
CORE_IDS = ["qwen2.5-3b", "deepseek-v2-lite-16b", "mamba2-1.3b",
            "jamba-v0.1-52b"]

_MODELS: dict[str, tuple] = {}


def _model(arch):
    """Module-level cache: params init and jit warmup dominate runtime."""
    if arch not in _MODELS:
        cfg = smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        _MODELS[arch] = (cfg, model, params)
    return _MODELS[arch]


def _f32(x):
    return np.asarray(x, np.float32)


# ------------------------------------------------------------------ #
# decode-vs-forward parity (the serving oracle)                      #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Token-by-token serve_step reproduces the train forward's logits
    at every position, for every model archetype."""
    cfg, model, params = _model(arch)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    ref = _f32(model.forward(params, tokens=toks))

    serve = jax.jit(make_serve_step(model))
    state = model.init_decode_state(B, S)
    for t in range(S):
        logits, state = serve(params, state, jnp.int32(t),
                              tokens=toks[:, t:t + 1])
        np.testing.assert_allclose(
            _f32(logits), ref[:, t], atol=2e-2, rtol=2e-2,
            err_msg=f"{arch}: decode diverges from forward at pos {t}")


# ------------------------------------------------------------------ #
# fused cache-filling prefill                                        #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("arch", CORE_IDS)
def test_prefill_logits_and_cache_layout(arch):
    cfg, model, params = _model(arch)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab)

    ref = model.forward(params, tokens=toks)
    logits, state = make_prefill(model, return_cache=True)(
        params, tokens=toks)
    # same computation, same rounding: exactly the forward's logits
    np.testing.assert_array_equal(_f32(logits), _f32(ref))

    ref_state = model.init_decode_state(B, S)
    assert (jax.tree.structure(state) == jax.tree.structure(ref_state))
    for got, want in zip(jax.tree.leaves(state), jax.tree.leaves(ref_state)):
        assert got.shape == want.shape and got.dtype == want.dtype


def test_prefill_default_stays_logits_only():
    """dryrun/analyze compatibility: the no-kwargs path returns only
    last-position logits, and they agree with the cached variant."""
    cfg, model, params = _model("qwen2.5-3b")
    toks = jax.random.randint(jax.random.key(3), (2, 8), 0, cfg.vocab)
    last = make_prefill(model)(params, tokens=toks)
    full, _ = make_prefill(model, return_cache=True)(params, tokens=toks)
    assert last.shape == (2, cfg.padded_vocab)
    np.testing.assert_array_equal(_f32(last), _f32(full[:, -1]))


@pytest.mark.parametrize("arch", CORE_IDS)
def test_prefill_decode_handoff(arch):
    """Prefill S-1 tokens, decode token S-1: logits match the full
    forward bit for bit (the cache holds exactly what token-by-token
    decode would have written)."""
    cfg, model, params = _model(arch)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(4), (B, S), 0, cfg.vocab)
    ref = _f32(model.forward(params, tokens=toks))[:, -1]

    _, state = model.prefill(params, tokens=toks[:, :S - 1])
    grown = jax.tree.map(
        lambda t, r: jax.lax.dynamic_update_slice(
            jnp.zeros(r.shape, t.dtype), t, (0,) * t.ndim),
        state, model.init_decode_state(B, S))
    logits, _ = model.decode_step(params, grown, jnp.int32(S - 1),
                                  tokens=toks[:, -1:])
    np.testing.assert_array_equal(_f32(logits[:, -1]), ref)


# ------------------------------------------------------------------ #
# block allocator                                                    #
# ------------------------------------------------------------------ #
def test_allocator_determinism_and_reuse():
    def run():
        a = BlockAllocator(n_pages=9, page_size=4)
        s1 = a.alloc(10)               # 3 pages
        s2 = a.alloc(5)                # 2 pages
        a.free(s1)
        s3 = a.alloc(12)               # reuses s1's pages, LIFO order
        return s1, s2, s3

    assert run() == run()              # same call sequence -> same pages
    s1, s2, s3 = run()
    assert 0 not in s1 + s2 + s3       # trash page never handed out
    assert len(set(s2) & set(s3)) == 0  # live pages never shared
    assert set(s3) == set(s1)          # freed pages get reused


def test_allocator_errors():
    a = BlockAllocator(n_pages=5, page_size=4)
    pages = a.alloc(16)                # all 4 allocatable pages
    assert not a.can_alloc(1)
    with pytest.raises(MemoryError):
        a.alloc(1)
    with pytest.raises(ValueError):
        a.free([0])                    # trash page is not allocatable
    a.free(pages)
    with pytest.raises(ValueError):
        a.free([pages[0]])             # double free
    assert pages_needed(1, 4) == 1 and pages_needed(9, 4) == 3


# ------------------------------------------------------------------ #
# paged decode vs dense decode                                       #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("arch", CORE_IDS)
def test_paged_decode_matches_dense(arch):
    """Same tokens through dense scalar-pos decode and paged per-row
    decode (non-trivial page table): identical logits."""
    cfg, model, params = _model(arch)
    B, T, PS = 2, 6, 4
    toks = jax.random.randint(jax.random.key(5), (B, T), 0, cfg.vocab)

    dense = model.init_decode_state(B, T)
    paged = model.init_paged_state(B, 9, PS)
    table = jnp.asarray([[3, 1], [4, 2]], jnp.int32)   # scrambled pages
    dstep = jax.jit(model.decode_step)
    pstep = jax.jit(model.decode_step_paged)
    for t in range(T):
        dl, dense = dstep(params, dense, jnp.int32(t), tokens=toks[:, t:t + 1])
        pl, paged = pstep(params, paged, table,
                          jnp.full((B,), t, jnp.int32),
                          tokens=toks[:, t:t + 1])
        np.testing.assert_array_equal(_f32(dl), _f32(pl),
                                      err_msg=f"{arch} pos {t}")


def _engine(model, params, *, n_slots=2, buckets=(8,), max_new=4,
            page_size=4, exec_cache=None, n_pages=None):
    if n_pages is None:
        n_pages = pool_pages_for(n_slots, max(buckets) + max_new, page_size)
    return ServeEngine(model, params, n_slots=n_slots, n_pages=n_pages,
                       page_size=page_size, max_new=max_new,
                       buckets=buckets, exec_cache=exec_cache)


def _dense_oracle(model, params, req, max_new):
    """Reference generation: fused prefill + dense decode loop."""
    cfg = model.cfg
    L = req.prompt_len
    logits, state = model.prefill(params, tokens=jnp.asarray(req.tokens[None]))
    state = jax.tree.map(
        lambda t, r: jax.lax.dynamic_update_slice(
            jnp.zeros(r.shape, t.dtype), t, (0,) * t.ndim),
        state, model.init_decode_state(1, L + max_new))
    out = [int(jnp.argmax(logits[0, -1, :cfg.vocab]))]
    step = jax.jit(make_serve_step(model))
    for t in range(L, L + max_new - 1):
        lg, state = step(params, state, jnp.int32(t),
                         tokens=jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(lg[0, :cfg.vocab])))
    return np.asarray(out, np.int32)


def test_no_cross_sequence_leakage():
    """Fill every pool page with garbage (as if dirtied by evicted
    sequences) — outputs must not change: stale pages are masked to
    exactly zero probability."""
    cfg, model, params = _model("jamba-v0.1-52b")   # attn + ssm + moe
    stream = RequestStream(cfg, buckets=(8,), max_new=4, seed=11)
    req = stream.request(0)

    clean = _engine(model, params)
    clean.submit(stream.request(0))
    ref = clean.run()[0].tokens

    dirty = _engine(model, params)
    key = jax.random.key(99)
    dirty.pools = jax.tree.map(
        lambda t: (jax.random.normal(key, t.shape, jnp.float32) * 10
                   ).astype(t.dtype),
        dirty.pools)
    dirty.submit(req)
    got = dirty.run()[0].tokens
    np.testing.assert_array_equal(got, ref)


def test_engine_continuous_batching_matches_oracle():
    """More requests than slots: admissions ride evictions, everything
    completes, outputs equal the dense per-request oracle, and nothing
    compiles after warmup."""
    cfg, model, params = _model("qwen2.5-3b")
    max_new = 4
    eng = _engine(model, params, n_slots=2, buckets=(8, 16),
                  max_new=max_new)
    eng.warmup()
    frozen = eng.cache.misses

    stream = RequestStream(cfg, buckets=(8, 16), max_new=max_new, seed=7)
    reqs = stream.requests(5)
    for r in reqs:
        eng.submit(r)
    done = {d.req_id: d for d in eng.run()}

    assert len(done) == len(reqs)
    assert eng.cache.misses == frozen, "engine recompiled mid-run"
    assert eng.alloc.free_pages == eng.alloc.n_pages - 1  # all freed
    for r in reqs:
        np.testing.assert_array_equal(
            done[r.req_id].tokens, _dense_oracle(model, params, r, max_new),
            err_msg=f"req {r.req_id}")
        assert done[r.req_id].latencies.shape == (max_new,)


# ------------------------------------------------------------------ #
# replica layer: SPARe masking, zero drops, wipe-out reload          #
# ------------------------------------------------------------------ #
def _burst_injector(n_replicas, *, hosts_per_rack, seed=3,
                    seconds_per_step=100.0):
    from repro.des.params import DESParams
    from repro.scenarios.topology import ClusterTopology
    from repro.train import ScenarioInjector
    topo = ClusterTopology(n_groups=n_replicas, hosts_per_group=1,
                           hosts_per_rack=hosts_per_rack)
    return ScenarioInjector(
        {"kind": "correlated", "scope": "rack", "burst_prob": 1.0,
         "mtbf": 400.0},
        topo, n_groups=n_replicas, seconds_per_step=seconds_per_step,
        params=DESParams(n=n_replicas, mtbf=400.0), seed=seed)


def _server(model, params, n_replicas, injector=None, ckpt=None):
    kwargs = dict(n_slots=2, page_size=4, max_new=4, buckets=(8,),
                  n_pages=pool_pages_for(2, 8 + 4, 4))
    srv = ReplicaServer(model, params, n_replicas=n_replicas,
                        injector=injector, ckpt=ckpt, engine_kwargs=kwargs)
    srv.warmup()
    return srv


def test_replica_burst_zero_drops_no_recompile():
    """Rack bursts kill replicas mid-serving: every admitted request
    still completes, outputs are bit-identical to the healthy run, and
    the shared executable cache never misses again (SPARe masking is
    weight-table data, not a program change)."""
    cfg, model, params = _model("qwen2.5-3b")
    stream = RequestStream(cfg, buckets=(8,), max_new=4, seed=7)

    healthy = _server(model, params, n_replicas=3)
    for r in stream.requests(8):
        healthy.submit(r)
    want = {d.req_id: d.tokens for d in healthy.run()}

    srv = _server(model, params, n_replicas=3,
                  injector=_burst_injector(3, hosts_per_rack=1))
    frozen = srv.recompiles
    for r in stream.requests(8):
        srv.submit(r)
    done = srv.run()

    kills = [e for e in srv.events if e.kind == "kill"]
    assert kills, "campaign produced no failures — gate is vacuous"
    got = {d.req_id: d.tokens for d in done}
    assert got.keys() == want.keys(), "requests were dropped"
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])
    assert srv.recompiles == frozen, "replica masking caused a recompile"
    assert srv.dropped == 0


def test_wipeout_reloads_from_checkpoint(tmp_path):
    """All replicas in one rack: the first burst is a wipe-out. The
    server reloads params via CheckpointManager, requeues everything,
    and still completes every request with the same outputs."""
    from repro.ckpt import CheckpointManager
    cfg, model, params = _model("qwen2.5-3b")
    stream = RequestStream(cfg, buckets=(8,), max_new=4, seed=13)

    healthy = _server(model, params, n_replicas=2)
    for r in stream.requests(4):
        healthy.submit(r)
    want = {d.req_id: d.tokens for d in healthy.run()}

    ckpt = CheckpointManager(tmp_path, n_groups=2, redundancy=1,
                             mtbf=1e6, t_save=1.0, t_restart=1.0)
    srv = _server(model, params, n_replicas=2,
                  injector=_burst_injector(2, hosts_per_rack=2),
                  ckpt=ckpt)
    frozen = srv.recompiles
    for r in stream.requests(4):
        srv.submit(r)
    done = srv.run()

    assert any(e.kind == "wipeout" for e in srv.events), \
        "no wipe-out happened — reload path untested"
    got = {d.req_id: d.tokens for d in done}
    assert got.keys() == want.keys()
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])
    assert srv.recompiles == frozen, "wipe-out reload recompiled"


# ------------------------------------------------------------------ #
# spmd: masked vs unmasked replica programs                          #
# ------------------------------------------------------------------ #
@pytest.mark.spmd
def test_masked_replica_schedule_equality():
    """On the emulated 8-device mesh, the paged decode step compiled for
    a healthy replica and for a masked (post-failure, re-weighted)
    replica lowers to the same collective schedule — SPARe's §3.1
    property carried over to serving."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import paged_cache_specs
    from repro.launch.hlo import same_collective_schedule
    from repro.launch.mesh import make_emulated_mesh

    mesh = make_emulated_mesh(8)
    cfg = smoke_config("qwen2.5-3b")
    model = build_model(cfg, mesh=mesh)
    params = model.init(jax.random.key(0))
    params = jax.device_put(params, NamedSharding(mesh, P()))

    n_slots, n_pages, ps = 8, 16, 4
    pools = model.init_paged_state(n_slots, n_pages, ps)
    specs = paged_cache_specs(
        jax.tree.map(lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype),
                     pools), cfg, mesh, multi_pod=False)
    pools = jax.tree.map(
        lambda t, s: jax.device_put(t, NamedSharding(mesh, s)),
        pools, specs)

    fn = make_serve_step(model, paged=True)

    def lower_for(table, pos, toks):
        return jax.jit(
            lambda p, s, t, q, k: fn(p, s, t, q, tokens=k)).lower(
                params, pools, table, pos, toks).compile().as_text()

    # healthy: all 8 slots active; masked: half the slots parked on the
    # trash page after their replica died — pure data, same program
    full = jnp.arange(1, 17, dtype=jnp.int32).reshape(8, 2)
    healthy = lower_for(full, jnp.full((8,), 5, jnp.int32),
                        jnp.ones((8, 1), jnp.int32))
    masked_table = full.at[4:].set(0)
    masked = lower_for(masked_table, jnp.zeros((8,), jnp.int32)
                       .at[:4].set(5), jnp.zeros((8, 1), jnp.int32))
    assert same_collective_schedule(healthy, masked)
