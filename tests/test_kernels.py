"""Per-kernel validation: shape/dtype sweeps against the ref.py oracles,
executed with interpret=True (kernel bodies run in Python on CPU).

The whole module is ``tpu``-marked: even in interpret mode the kernels
use TPU-toolchain namings/primitives that the CPU-only jax wheel lacks,
so without a TPU backend these are known environment failures (see
tests/conftest.py), not regressions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.tpu

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ops import flash_attention, rmsnorm, ssd_scan
from repro.kernels.ref import flash_attention_ref, rmsnorm_ref, ssd_scan_ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
           dict(atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------------ #
# flash attention                                                     #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 2, 2, 128, 64),     # MHA
    (2, 4, 2, 256, 64),     # GQA 2:1
    (1, 8, 2, 256, 128),    # GQA 4:1, wide head
    (1, 3, 1, 128, 64),     # MQA, odd head count
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, h, kv, s, d, dtype):
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, kv, s, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, kv, s, d)), dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), **_tol(dtype))


@pytest.mark.parametrize("bq,bk", [(32, 64), (64, 32), (128, 128)])
def test_flash_attention_block_shape_invariance(bq, bk):
    q = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.float32)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_attention_non_causal():
    q = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_attention_matches_model_reference():
    """The kernel and the model's chunked-attention train path agree."""
    from repro.models.attention import attend_chunked
    b, h, s, d = 1, 4, 128, 64
    q = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    model_out = attend_chunked(q, k, v, chunk=64)            # (B,S,H,D)
    kern_out = flash_attention(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3),
                               block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(kern_out.transpose(0, 2, 1, 3), model_out,
                               atol=2e-3, rtol=2e-3)


# ------------------------------------------------------------------ #
# SSD scan                                                            #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("b,h,g,s,p,n,chunk", [
    (1, 2, 1, 128, 32, 64, 64),
    (2, 4, 2, 256, 64, 128, 128),
    (1, 4, 4, 128, 32, 16, 32),    # jamba-like small d_state
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_ref(b, h, g, s, p, n, chunk, dtype):
    x = jnp.asarray(RNG.normal(size=(b, h, s, p)), dtype)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, h, s)), jnp.float32)
    a_log = jnp.asarray(np.log(np.arange(1, h + 1)), jnp.float32)
    bb = jnp.asarray(RNG.normal(size=(b, g, s, n)), dtype)
    cc = jnp.asarray(RNG.normal(size=(b, g, s, n)), dtype)
    y, st = ssd_scan(x, dt, a_log, bb, cc, chunk=chunk, interpret=True)
    rep = h // g
    yr, str_ = ssd_scan_ref(x, dt, -jnp.exp(a_log),
                            jnp.repeat(bb, rep, axis=1),
                            jnp.repeat(cc, rep, axis=1))
    np.testing.assert_allclose(y.astype(jnp.float32),
                               yr.astype(jnp.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 2e-4,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 2e-4)
    np.testing.assert_allclose(st, str_, atol=1e-2, rtol=1e-2)


def test_ssd_scan_chunk_invariance():
    """Different chunk sizes give the same answer (the recurrence is
    chunking-independent) — guards the cross-chunk state handoff."""
    b, h, s, p, n = 1, 2, 256, 32, 64
    x = jnp.asarray(RNG.normal(size=(b, h, s, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, h, s)), jnp.float32)
    a_log = jnp.zeros((h,), jnp.float32)
    bb = jnp.asarray(RNG.normal(size=(b, h, s, n)), jnp.float32)
    cc = jnp.asarray(RNG.normal(size=(b, h, s, n)), jnp.float32)
    y1, s1 = ssd_scan(x, dt, a_log, bb, cc, chunk=32, interpret=True)
    y2, s2 = ssd_scan(x, dt, a_log, bb, cc, chunk=128, interpret=True)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s1, s2, atol=1e-4, rtol=1e-4)


def test_ssd_scan_matches_model_layer():
    """Kernel agrees with the model's ssd_chunked (different layout)."""
    from repro.models.ssm import ssd_chunked
    b, h, s, p, n = 1, 2, 128, 16, 32
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    a_log = jnp.asarray(np.log(np.arange(1, h + 1)), jnp.float32)
    bb = jnp.asarray(RNG.normal(size=(b, s, h, n)), jnp.float32)
    cc = jnp.asarray(RNG.normal(size=(b, s, h, n)), jnp.float32)
    y_model, st_model = ssd_chunked(x, dt, a_log, bb, cc, chunk=64)
    y_kern, st_kern = ssd_scan(
        x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1),
        a_log, bb.transpose(0, 2, 1, 3), cc.transpose(0, 2, 1, 3),
        chunk=64, interpret=True)
    np.testing.assert_allclose(y_kern.transpose(0, 2, 1, 3), y_model,
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(st_kern, st_model, atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------------ #
# rmsnorm                                                             #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("shape", [(4, 128), (2, 50, 256), (3, 7, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    x = jnp.asarray(RNG.normal(size=shape), dtype)
    w = jnp.asarray(RNG.normal(size=shape[-1:]), jnp.float32)
    out = rmsnorm(x, w, block_rows=16, interpret=True)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), **_tol(dtype))


def test_rmsnorm_row_padding():
    # rows not divisible by block_rows exercises the pad/unpad path
    x = jnp.asarray(RNG.normal(size=(37, 128)), jnp.float32)
    w = jnp.ones((128,), jnp.float32)
    out = rmsnorm(x, w, block_rows=16, interpret=True)
    np.testing.assert_allclose(out, rmsnorm_ref(x, w), atol=1e-5, rtol=1e-5)
