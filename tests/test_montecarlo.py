"""App. C Monte-Carlo validation at reduced trial counts (CI-friendly).

The paper reports <= 1.13 % MAPE on mu and 0.60 % on the mean all-reduce
stack over 1000 trials; with 60-150 trials we allow ~5 % tolerance.
"""
import pytest

from repro.core import theory
from repro.core.montecarlo import run_montecarlo


@pytest.mark.parametrize("n,r,trials,tol", [
    (200, 3, 150, 0.10),
    (200, 9, 100, 0.06),
    (200, 12, 100, 0.06),
])
def test_mc_failure_count_matches_thm41(n, r, trials, tol):
    res = run_montecarlo(n, r, trials=trials, seed=42)
    expected = theory.mu(n, r)
    assert abs(res.mean_failures - expected) / expected < tol


@pytest.mark.parametrize("n,r,expected", [
    (200, 9, 2.03),   # paper Table 4 theory column
    (200, 12, 2.17),
])
def test_mc_stack_depth_matches_eq6(n, r, expected):
    res = run_montecarlo(n, r, trials=100, seed=7)
    assert res.mean_stack == pytest.approx(expected, rel=0.05)


def test_mc_larger_r_endures_more_failures():
    r_small = run_montecarlo(200, 3, trials=60, seed=0).mean_failures
    r_large = run_montecarlo(200, 9, trials=60, seed=0).mean_failures
    assert r_large > 2.5 * r_small
