"""Live-failure bridge: repro.scenarios driving the real SpareTrainer.

Covers the ISSUE-3 acceptance points: the trainer completes CPU-scale
runs under each PR-2 regime (weibull / rack-burst / trace replay),
multi-group batch kills reach ``scheme.recover`` in one call, the §3.1
gradient-equivalence invariant holds after every recovery, and a
wipe-out without a checkpoint directory genuinely rolls params back.
"""
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.des.params import DESParams
from repro.scenarios import ClusterTopology, to_step_events
from repro.train.injection import ScenarioInjector, StepEvent
from repro.train.trainer import SpareTrainer

#: 2 hosts/group, 4 hosts/rack => every rack holds exactly 2 DP groups,
#: so a rack kill is always a genuine multi-group batch
RACKY_TOPO = ClusterTopology(n_groups=8, hosts_per_group=2,
                             hosts_per_rack=4)

RACK_BURST = {"kind": "correlated", "scope": "rack", "burst_prob": 1.0,
              "mtbf": 400.0}


def _tiny_trainer(**kw):
    cfg = smoke_config("qwen2.5-3b").scaled(grad_accum=1)
    kw.setdefault("n_groups", 8)
    kw.setdefault("redundancy", 3)
    kw.setdefault("seq", 32)
    kw.setdefault("per_type_batch", 1)
    kw.setdefault("total_steps", 200)
    return SpareTrainer(cfg, **kw)


# ------------------------------------------------------------------ #
# the step-clock adapter                                             #
# ------------------------------------------------------------------ #
def test_to_step_events_deterministic_and_multi_group():
    spec = {"kind": "correlated", "scope": "rack", "burst_prob": 0.8,
            "mtbf": 200.0}
    a = to_step_events(spec, 8, seconds_per_step=64.0, max_steps=100,
                       rng=np.random.default_rng(7), topology=RACKY_TOPO)
    b = to_step_events(spec, 8, seconds_per_step=64.0, max_steps=100,
                       rng=np.random.default_rng(7), topology=RACKY_TOPO)
    assert a == b                       # seed-deterministic
    assert a, "hot regime must produce events inside the horizon"
    assert all(0 <= s < 100 for s, _ in a)
    assert any(len(v) > 1 for _, v in a), "rack kills must batch groups"
    # victims resolve through the topology: each batch within one rack
    for _, victims in a:
        racks = {k for w in victims for k in RACKY_TOPO.racks_of_group(w)}
        assert len(racks) == 1 or len(victims) == 1


def test_to_step_events_open_loop_keeps_groups_dead():
    evs = to_step_events({"kind": "poisson", "mtbf": 50.0}, 4,
                         seconds_per_step=64.0, max_steps=500,
                         rng=np.random.default_rng(0))
    all_victims = [w for _, v in evs for w in v]
    assert len(all_victims) == len(set(all_victims)) <= 4


# ------------------------------------------------------------------ #
# the bridge itself                                                  #
# ------------------------------------------------------------------ #
def test_bridge_poll_protocol_and_clock():
    from repro.core import SpareState
    inj = ScenarioInjector(RACK_BURST, RACKY_TOPO, n_groups=8,
                           seconds_per_step=100.0, seed=1)
    st = SpareState(8, 3)
    events = []
    for _ in range(30):
        events += inj.poll(st)
        for ev in events:
            for w in ev.victims:
                st.alive[w] = False     # emulate un-recovered deaths
    assert inj.clock == pytest.approx(3000.0)
    assert inj.step == 30
    assert all(isinstance(e, StepEvent) for e in events)
    assert inj.events_delivered == len(events)
    # victims never include already-dead groups and stay in range
    seen = set()
    for ev in events:
        assert not (set(ev.victims) & seen)
        seen |= set(ev.victims)
        assert all(0 <= w < 8 for w in ev.victims)


def test_bridge_call_flattens_to_plain_injector_protocol():
    from repro.core import SpareState
    inj = ScenarioInjector(RACK_BURST, RACKY_TOPO, n_groups=8,
                           seconds_per_step=500.0, seed=1)
    st = SpareState(8, 3)
    for _ in range(20):
        failed = inj(st)
        assert isinstance(failed, list)
        for w in failed:
            st.alive[w] = False
        if failed:
            return
    pytest.fail("hot regime delivered nothing in 20 windows")


def test_bridge_rejects_mismatched_topology():
    with pytest.raises(ValueError, match="n_groups=16"):
        ScenarioInjector(RACK_BURST,
                         ClusterTopology(n_groups=16), n_groups=8)
    with pytest.raises(ValueError, match="n_groups=8"):
        to_step_events(RACK_BURST, 4, seconds_per_step=64.0, max_steps=10,
                       rng=np.random.default_rng(0),
                       topology=ClusterTopology(n_groups=8))


def test_notify_wipeout_rearms_past_the_outage():
    inj = ScenarioInjector({"kind": "poisson", "mtbf": 100.0},
                           n_groups=8, seconds_per_step=64.0, seed=0)
    inj.clock = 640.0
    inj.notify_wipeout()
    assert inj.clock == pytest.approx(640.0 + inj.p.t_restart)
    assert inj._next_fail >= inj.clock


# ------------------------------------------------------------------ #
# trainer under the three PR-2 regimes (acceptance)                  #
# ------------------------------------------------------------------ #
def test_trainer_rack_burst_multi_group_kills_and_equivalence():
    """Rack bursts deliver simultaneous multi-group batches to
    scheme.recover, and §3.1 holds after every recovery."""
    tr = _tiny_trainer()
    inj = ScenarioInjector(RACK_BURST, RACKY_TOPO, n_groups=8,
                           params=DESParams(n=8, t_comp=64.0), seed=3)
    rep = tr.run(25, injector=inj, verify_equivalence=True)
    assert tr.step >= 25
    assert rep.failures > 0
    assert rep.multi_group_events >= 1, \
        "a rack kill must reach recover as one multi-group batch"
    assert rep.max_grad_check_err < 1e-2
    assert all(np.isfinite(rep.losses))
    assert tr.state.prefix_coverage().all()
    # every multi-group event recorded >= 2 victims in one recover call
    big = [e for e in rep.events if e.multi_group]
    assert all(len(e.victims) >= 2 for e in big)


@pytest.mark.parametrize("model", [
    {"kind": "weibull", "mtbf": 400.0},
    {"kind": "trace", "trace": "meta_hsdp_rackstorm", "time_scale": 0.2},
], ids=["weibull", "trace_replay"])
def test_trainer_completes_under_regime(model):
    tr = _tiny_trainer(n_groups=8, redundancy=3)
    inj = ScenarioInjector(model, RACKY_TOPO, n_groups=8,
                           params=DESParams(n=8, t_comp=64.0), seed=11)
    rep = tr.run(15, injector=inj, verify_equivalence=True)
    assert tr.step >= 15
    assert rep.max_grad_check_err < 1e-2
    assert all(np.isfinite(rep.losses))
    assert tr.state.prefix_coverage().all()


def test_trace_replay_resolves_rack_events_to_batches():
    tr = _tiny_trainer()
    # compressed trace: plenty of rack/pod-scope events in the horizon
    inj = ScenarioInjector({"kind": "trace", "trace":
                            "meta_hsdp_rackstorm", "time_scale": 0.05},
                           RACKY_TOPO, n_groups=8,
                           params=DESParams(n=8, t_comp=64.0), seed=0)
    rep = tr.run(20, injector=inj)
    assert rep.multi_group_events >= 1
    assert tr.step >= 20


# ------------------------------------------------------------------ #
# wipe-out durability (the ckpt-is-None bug)                         #
# ------------------------------------------------------------------ #
class _KillAllAt:
    """Plain injector: kills every group once, at call K."""

    def __init__(self, n: int, at_call: int):
        self.n = n
        self.at = at_call
        self.calls = 0

    def __call__(self, state):
        self.calls += 1
        return list(range(self.n)) if self.calls == self.at else []


def test_wipeout_without_ckpt_dir_rolls_back_params_and_step():
    """A wipe-out with no checkpoint directory must roll back to the
    free in-memory snapshot — the post-rollback loss trajectory replays
    the clean run exactly (the old code silently kept post-failure
    params and the step counter)."""
    clean = _tiny_trainer(n_groups=6, redundancy=2, seed=4)
    ref = clean.run(5)

    tr = _tiny_trainer(n_groups=6, redundancy=2, seed=4)
    rep = tr.run(5, injector=_KillAllAt(6, at_call=3),
                 snapshot_every=100)    # only the run-start snapshot
    assert rep.wipeouts == 1
    assert tr.step == 5
    ev = [e for e in rep.events if e.wipeout][0]
    assert ev.rollback_depth == 2      # died at step 2, back to step 0
    assert ev.victims and len(ev.victims) == 6
    # 2 pre-wipeout steps, then the full 5 replayed from step 0 with
    # the rolled-back params: trajectories must match bit-for-bit
    assert rep.losses[:2] == ref.losses[:2]
    assert rep.losses[2:] == ref.losses


def test_wipeout_rollback_respects_snapshot_cadence():
    clean = _tiny_trainer(n_groups=6, redundancy=2, seed=9)
    ref = clean.run(8)

    tr = _tiny_trainer(n_groups=6, redundancy=2, seed=9)
    rep = tr.run(8, injector=_KillAllAt(6, at_call=6), snapshot_every=4)
    assert rep.wipeouts == 1
    ev = [e for e in rep.events if e.wipeout][0]
    assert ev.rollback_depth == 1      # died at step 5, snapshot at 4
    assert rep.losses[:5] == ref.losses[:5]
    assert rep.losses[5:] == ref.losses[4:]
