"""DES scheme tests (paper Sec. 5) at reduced horizons."""
import pytest

from repro.des import (
    DESParams,
    simulate_ckpt_only,
    simulate_replication,
    simulate_spare,
)


def short(n=200, steps=400):
    return DESParams(n=n, steps=steps)


def test_no_failure_baseline_is_t0():
    # with failures disabled (huge MTBF) every scheme hits ~T0 (+ckpt saves)
    p = short().with_(mtbf=1e12, jitter_std=0.0)
    r = simulate_ckpt_only(p, seed=0)
    assert r.steps_done == p.steps
    assert r.ttt_norm == pytest.approx(1.0, abs=0.05)
    assert r.availability > 0.95

    rs = simulate_spare(p, r=4, seed=0)
    assert rs.steps_done == p.steps
    # SPARe with no failures: S_A stays 1 => ttt ~ T0
    assert rs.ttt_norm == pytest.approx(1.0, abs=0.05)
    assert rs.avg_stacks == pytest.approx(1.0, abs=0.01)


def test_replication_overhead_is_r_times():
    p = short().with_(mtbf=1e12, jitter_std=0.0)
    for r in (2, 3):
        res = simulate_replication(p, r=r, seed=0)
        # step time = r*T_comp + T_a  =>  ttt/T0 = (r*64+2)/(64+2)
        expected = (r * p.t_comp + p.t_allreduce) / (p.t_comp + p.t_allreduce)
        assert res.ttt_norm == pytest.approx(expected, rel=0.05)


def test_ckpt_only_collapses_in_restart_dominant_regime():
    """Paper Sec. 5.2.1: CKPT-only barely proceeds. With MTBF=300s and
    T_r=3600s a step takes 66s; P(failure-free step) is high but every
    failure costs > 54 steps of restart + rework."""
    p = short(steps=200)
    r = simulate_ckpt_only(p, seed=1, max_wall=100 * 200 * 66.0)
    rs = simulate_spare(p.with_(steps=200), r=9, seed=1)
    assert rs.wall < r.wall * 0.5, "SPARe must dominate CKPT-only"


def test_spare_beats_replication_at_optimal_r():
    p = short(steps=600)
    best_spare = min(
        simulate_spare(p, r=r, seed=3).ttt_norm for r in (8, 9, 10)
    )
    best_rep = min(
        simulate_replication(p, r=r, seed=3).ttt_norm for r in (2, 3, 4)
    )
    # paper Table 2: 40-52 % gain; at short horizons allow >= 20 %
    assert best_spare < best_rep * 0.8


def test_spare_availability_above_90_at_high_r():
    p = DESParams(n=600, steps=800)
    res = simulate_spare(p, r=12, seed=0)
    assert res.availability > 0.85
    assert res.avg_stacks < 3.0  # near-constant overhead (Fig. 5)


def test_spare_masks_failures_without_restart():
    p = short(steps=300)
    res = simulate_spare(p, r=9, seed=5)
    assert res.node_failures > 50
    # wipe-outs must be far rarer than failures (mu(200,9) ~ 105)
    assert res.wipeouts <= res.node_failures / 40


def test_exponential_failure_law_supported():
    p = short(steps=200).with_(failure_law="exponential")
    res = simulate_spare(p, r=9, seed=0)
    assert res.steps_done == 200


def test_dynamic_ckpt_no_worse_at_low_r():
    """Beyond-paper Weibull-aware checkpointing: at low r (frequent
    wipe-outs under k<1 burstiness) the dynamic interval should not lose
    to the static one."""
    p = short(steps=500)
    static = simulate_spare(p, r=2, seed=11, dynamic_ckpt=False)
    dynamic = simulate_spare(p, r=2, seed=11, dynamic_ckpt=True)
    assert dynamic.ttt_norm <= static.ttt_norm * 1.10


def test_results_reproducible_by_seed():
    p = short(steps=150)
    a = simulate_spare(p, r=6, seed=123)
    b = simulate_spare(p, r=6, seed=123)
    assert a.wall == b.wall and a.node_failures == b.node_failures
