"""Integration tests: the full SPARe training loop (Alg. 1) end to end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import Rectlr, SpareState
from repro.data import ShardedTokenPipeline, spare_batch
from repro.train.trainer import PoissonInjector, SpareTrainer


@pytest.fixture(scope="module")
def trainer(tmp_path_factory):
    cfg = smoke_config("qwen2.5-3b").scaled(grad_accum=1)
    return SpareTrainer(cfg, n_groups=8, redundancy=3, seq=64,
                        per_type_batch=2,
                        ckpt_dir=str(tmp_path_factory.mktemp("ckpt")),
                        total_steps=200)


def _tree_max_diff(a, b):
    return jax.tree.reduce(max, jax.tree.map(
        lambda x, y: float(jnp.abs(x.astype(jnp.float32)
                                   - y.astype(jnp.float32)).max()), a, b))


def test_gradient_equivalence_no_failures(trainer):
    """§3.1 invariant, healthy system: SPARe schedule == vanilla DP."""
    assert _tree_max_diff(trainer.vanilla_reference_grads(0),
                          trainer.spare_grads(0)) == 0.0


def test_gradient_equivalence_under_failures_and_reorder(trainer):
    """§3.1 invariant after failures: reordering changes only *which group
    supplies which shard*; the collected gradient is numerically the
    vanilla-DP gradient (fp32 summation-order noise only)."""
    st = SpareState(8, 3)
    ctl = Rectlr()
    ctl.on_failures(st, [1])
    ctl.on_failures(st, [4])
    assert st.s_a >= 2
    saved_state = trainer.state
    trainer.state = st
    try:
        ref = trainer.vanilla_reference_grads(0)
        got = trainer.spare_grads(0)
    finally:
        trainer.state = saved_state
    # magnitude-relative bound: reordering only permutes the summation
    ref_scale = jax.tree.reduce(max, jax.tree.map(
        lambda x: float(jnp.abs(x.astype(jnp.float32)).max()), ref))
    assert _tree_max_diff(ref, got) < 1e-2 * max(ref_scale, 1.0)


def test_training_loop_survives_failures():
    cfg = smoke_config("qwen2.5-3b").scaled(grad_accum=1)
    tr = SpareTrainer(cfg, n_groups=8, redundancy=3, seq=64,
                      per_type_batch=2, ckpt_dir=None, total_steps=100)
    rep = tr.run(20, injector=PoissonInjector(3.0, seed=7))
    assert rep.steps_done >= 20
    assert rep.failures > 0
    assert all(np.isfinite(rep.losses))


def test_wipeout_rolls_back_to_snapshot(tmp_path):
    cfg = smoke_config("qwen2.5-3b").scaled(grad_accum=1)
    tr = SpareTrainer(cfg, n_groups=6, redundancy=2, seq=32,
                      per_type_batch=1, ckpt_dir=str(tmp_path),
                      total_steps=100)
    # r=2, N=6: mu ~ 3.4 failures — hammer it until a wipe-out happens
    rep = tr.run(30, injector=PoissonInjector(1.5, seed=0),
                 snapshot_every=5)
    assert rep.wipeouts >= 1
    assert tr.step >= 30  # training completed despite global restarts
    # post-restart failures may leave dead groups; the schedule must still
    # cover all shard types
    assert tr.state.prefix_coverage().all()


def test_loss_decreases_on_learnable_data():
    """End-to-end sanity: constant-token data must be learnable fast."""
    cfg = smoke_config("minitron-4b").scaled(grad_accum=1)
    tr = SpareTrainer(cfg, n_groups=4, redundancy=2, seq=32,
                      per_type_batch=2, total_steps=60, base_lr=3e-3)

    class ConstPipeline(ShardedTokenPipeline):
        def shard(self, shard_type, step):
            return np.full((self.per_type_batch, self.seq + 1), 7, np.int32)

    tr.pipeline = ConstPipeline(cfg, 32, 2)
    rep = tr.run(40)
    assert rep.losses[-1] < rep.losses[0] * 0.2, (
        f"loss {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f}")


def test_recompile_only_on_sa_change():
    cfg = smoke_config("qwen2.5-3b").scaled(grad_accum=1)
    tr = SpareTrainer(cfg, n_groups=8, redundancy=3, seq=32,
                      per_type_batch=1, total_steps=100)
    rep = tr.run(5)                       # healthy: S_A=1, one compile
    assert rep.recompiles == 1
    tr.ctl.on_failures(tr.state, [0])     # S_A -> 2
    rep2 = tr.run(3)
    assert rep2.recompiles == 1           # exactly one more


def test_spare_batch_weights_sum_to_one():
    cfg = smoke_config("glm4-9b")
    pipe = ShardedTokenPipeline(cfg, seq=16, per_type_batch=3)
    st = SpareState(8, 3)
    ctl = Rectlr()
    ctl.on_failures(st, [2])
    batch = spare_batch(pipe, st, step=0)
    assert batch["weights"].sum() == pytest.approx(1.0)
    assert batch["tokens"].shape == (st.s_a, 8 * 3, 16)
    # dead group's rows carry zero weight
    dead_rows = batch["weights"][:, 2 * 3:3 * 3]
    assert (dead_rows == 0).all()


def test_pipeline_determinism():
    cfg = smoke_config("glm4-9b")
    p1 = ShardedTokenPipeline(cfg, seq=16, per_type_batch=2, seed=5)
    p2 = ShardedTokenPipeline(cfg, seq=16, per_type_batch=2, seed=5)
    np.testing.assert_array_equal(p1.shard(3, 11), p2.shard(3, 11))
    assert not np.array_equal(p1.shard(3, 11), p1.shard(4, 11))
    assert not np.array_equal(p1.shard(3, 11), p1.shard(3, 12))
