"""Shared pytest plumbing.

Registers the ``tpu`` marker and auto-skips marked tests when no TPU
backend is attached: the Pallas kernel bodies and the lowered-HLO
comparisons need the real TPU toolchain (Mosaic), so on CPU-only hosts
they are *known* failures, not regressions. Run them on a TPU VM with
``pytest -m tpu`` (they un-skip automatically once ``jax.devices("tpu")``
resolves).
"""
import functools

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "tpu: needs the Pallas TPU toolchain (Mosaic); auto-skipped when "
        "no TPU backend is present")


@functools.lru_cache(maxsize=1)
def _tpu_available() -> bool:
    try:
        import jax
        return len(jax.devices("tpu")) > 0
    except Exception:
        return False


def pytest_collection_modifyitems(config, items):
    if any("tpu" in item.keywords for item in items) and _tpu_available():
        return
    skip_tpu = pytest.mark.skip(
        reason="no TPU backend; Pallas TPU kernels/HLO cannot run here")
    for item in items:
        if "tpu" in item.keywords:
            item.add_marker(skip_tpu)
