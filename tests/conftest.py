"""Shared pytest plumbing.

Registers two environment markers and auto-skips them when their
backend is absent — known environment gaps, not regressions:

* ``tpu`` — the Pallas kernel bodies and the lowered-HLO comparisons
  need the real TPU toolchain (Mosaic). Run them on a TPU VM with
  ``pytest -m tpu`` (they un-skip once ``jax.devices("tpu")``
  resolves).
* ``spmd`` — the ``repro.exec`` mesh tests need >= 8 devices. On any
  CPU host, fan the host platform out before the first jax import::

      XLA_FLAGS=--xla_force_host_platform_device_count=8 \
          PYTHONPATH=src python -m pytest -m spmd

  (CI runs these in a dedicated job; the plain tier-1 invocation sees
  one device and skips them.)
"""
import functools

import pytest

SPMD_MIN_DEVICES = 8


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "tpu: needs the Pallas TPU toolchain (Mosaic); auto-skipped when "
        "no TPU backend is present")
    config.addinivalue_line(
        "markers",
        f"spmd: needs >= {SPMD_MIN_DEVICES} devices (XLA_FLAGS="
        f"--xla_force_host_platform_device_count={SPMD_MIN_DEVICES}); "
        "auto-skipped otherwise")


@functools.lru_cache(maxsize=1)
def _tpu_available() -> bool:
    try:
        import jax
        return len(jax.devices("tpu")) > 0
    except Exception:
        return False


@functools.lru_cache(maxsize=1)
def _spmd_available() -> bool:
    try:
        import jax
        return jax.device_count() >= SPMD_MIN_DEVICES
    except Exception:
        return False


def pytest_collection_modifyitems(config, items):
    marked = {m for item in items for m in ("tpu", "spmd")
              if m in item.keywords}
    skips = {}
    if "tpu" in marked and not _tpu_available():
        skips["tpu"] = pytest.mark.skip(
            reason="no TPU backend; Pallas TPU kernels/HLO cannot run here")
    if "spmd" in marked and not _spmd_available():
        skips["spmd"] = pytest.mark.skip(
            reason=f"needs >= {SPMD_MIN_DEVICES} devices; set XLA_FLAGS="
                   f"--xla_force_host_platform_device_count="
                   f"{SPMD_MIN_DEVICES}")
    if not skips:
        return
    for item in items:
        for mark, skip in skips.items():
            if mark in item.keywords:
                item.add_marker(skip)
