"""Matching primitives: Hopcroft-Karp, incremental matcher, MCMF.

Property tests (hypothesis) check the from-scratch implementations against
brute-force oracles on small random instances.
"""
import itertools

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # pragma: no cover
    from _hypothesis_compat import given, settings, st

from repro.core.golomb import host_sets
from repro.core.matching import IncrementalMatcher, hopcroft_karp, min_cost_assignment


# ------------------------------------------------------------------ #
# oracles                                                             #
# ------------------------------------------------------------------ #
def brute_max_matching(adj, n_left, n_right):
    """Exponential-ish Kuhn oracle (fine at the sizes hypothesis draws)."""
    match_r = [-1] * n_right

    def try_kuhn(u, seen):
        for v in adj[u]:
            if v in seen:
                continue
            seen.add(v)
            if match_r[v] == -1 or try_kuhn(match_r[v], seen):
                match_r[v] = u
                return True
        return False

    size = 0
    for u in range(n_left):
        if try_kuhn(u, set()):
            size += 1
    return size


def brute_min_cost_perfect(adj_cost, n_left, n_right):
    """Exhaustive min-cost perfect matching (n_left <= 7)."""
    best = None
    edges = [dict(row) for row in adj_cost]
    for perm in itertools.permutations(range(n_right), n_left):
        cost = 0
        ok = True
        for u, v in enumerate(perm):
            if v not in edges[u]:
                ok = False
                break
            cost += edges[u][v]
        if ok and (best is None or cost < best):
            best = cost
    return best


# ------------------------------------------------------------------ #
# Hopcroft-Karp                                                       #
# ------------------------------------------------------------------ #
@st.composite
def bipartite(draw):
    n_left = draw(st.integers(1, 8))
    n_right = draw(st.integers(1, 8))
    adj = []
    for _ in range(n_left):
        nbrs = draw(st.lists(st.integers(0, n_right - 1), max_size=n_right,
                             unique=True))
        adj.append(nbrs)
    return adj, n_left, n_right


@given(bipartite())
@settings(max_examples=200, deadline=None)
def test_hopcroft_karp_matches_oracle(case):
    adj, nl, nr = case
    size, ml, mr = hopcroft_karp(adj, nl, nr)
    assert size == brute_max_matching(adj, nl, nr)
    # validity: matched pairs are edges and mutual
    for u, v in enumerate(ml):
        if v != -1:
            assert v in adj[u]
            assert mr[v] == u


def test_hopcroft_karp_perfect_on_identity():
    n = 50
    adj = [[i] for i in range(n)]
    size, _, _ = hopcroft_karp(adj, n, n)
    assert size == n


# ------------------------------------------------------------------ #
# IncrementalMatcher vs full HK                                       #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("n,r,seed", [(30, 4, 0), (60, 5, 1), (100, 6, 2)])
def test_incremental_matcher_equals_full_hk(n, r, seed):
    """After each failure, the incremental min-feasible depth equals the
    depth found by exhaustive HK feasibility from scratch."""
    rng = np.random.default_rng(seed)
    hosts = host_sets(n, r)
    matcher = IncrementalMatcher(hosts, n, depth=1)
    assert matcher.initialise()
    alive = np.ones(n, dtype=bool)

    def full_hk_min_depth():
        survivors = np.flatnonzero(alive)
        pos = {int(w): k for k, w in enumerate(survivors)}
        for s in range(1, r + 1):
            adj = []
            for i in range(n):
                row = []
                for w in hosts[i]:
                    if alive[w]:
                        row.extend(range(pos[int(w)] * s, pos[int(w)] * s + s))
                adj.append(row)
            size, _, _ = hopcroft_karp(adj, n, survivors.size * s)
            if size == n:
                return s
        return None

    for w in rng.permutation(n)[: n // 2]:
        w = int(w)
        alive[w] = False
        displaced = matcher.fail_group(w)
        inc = matcher.min_feasible_depth(displaced, r)
        ref = full_hk_min_depth()
        if ref is None:
            assert inc is None
            break
        # incremental depth is sticky (never decreases) => inc >= ref, and
        # both must be feasible; equality holds while depth never overshoots
        assert inc is not None and inc >= ref
        if inc > ref:
            # overshoot allowed only transiently; rebuilding at ref must work
            fresh = IncrementalMatcher(hosts, n, depth=ref)
            fresh.alive = [bool(a) for a in alive]
            fresh.cap = [ref if a else 0 for a in alive]
            assert fresh.initialise()


# ------------------------------------------------------------------ #
# MCMF                                                                #
# ------------------------------------------------------------------ #
@st.composite
def assignment_instance(draw):
    n_left = draw(st.integers(1, 6))
    n_right = draw(st.integers(n_left, 7))
    adj_cost = []
    for _ in range(n_left):
        vs = draw(st.lists(st.integers(0, n_right - 1), min_size=1,
                           max_size=n_right, unique=True))
        adj_cost.append([(v, draw(st.integers(0, 1))) for v in vs])
    return adj_cost, n_left, n_right


@given(assignment_instance())
@settings(max_examples=150, deadline=None)
def test_min_cost_assignment_optimal_when_perfect(case):
    adj_cost, nl, nr = case
    matched, cost, ml = min_cost_assignment(adj_cost, nl, nr)
    # cardinality must match HK
    adj = [[v for v, _ in row] for row in adj_cost]
    hk_size, _, _ = hopcroft_karp(adj, nl, nr)
    assert matched == hk_size
    if matched == nl:
        oracle = brute_min_cost_perfect(adj_cost, nl, nr)
        assert oracle is not None
        assert cost == oracle
    # validity
    used = set()
    for u, v in enumerate(ml):
        if v != -1:
            assert v not in used
            used.add(v)
            assert v in dict(adj_cost[u])


@given(assignment_instance())
@settings(max_examples=100, deadline=None)
def test_min_cost_assignment_jump_start_equivalent(case):
    """Seeding with a zero-cost partial matching must not change the
    optimal cost (extremality argument in the docstring)."""
    adj_cost, nl, nr = case
    m0, c0, _ = min_cost_assignment(adj_cost, nl, nr)
    # build a greedy zero-cost seed
    seed = [-1] * nl
    taken = set()
    for u, row in enumerate(adj_cost):
        for v, c in row:
            if c == 0 and v not in taken:
                seed[u] = v
                taken.add(v)
                break
    m1, c1, _ = min_cost_assignment(adj_cost, nl, nr, initial_match_l=seed)
    assert m1 == m0
    if m0 == nl:
        assert c1 == c0
