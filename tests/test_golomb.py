"""Placement-layer tests: cyclic Golomb rulers and Lemma B.2 invariant."""
import numpy as np
import pytest

from repro.core.golomb import (
    OPTIMAL_RULERS,
    golomb_ruler,
    host_sets,
    is_cyclic_golomb,
    max_redundancy,
    type_sets,
    validate_placement,
)


def test_optimal_rulers_are_golomb_as_integers():
    # every table entry: all pairwise differences distinct over Z (N = inf)
    for r, marks in OPTIMAL_RULERS.items():
        assert len(marks) == r
        assert marks[0] == 0
        diffs = set()
        for a in range(r):
            for b in range(r):
                if a == b:
                    continue
                d = marks[a] - marks[b]
                assert d not in diffs, f"r={r}: repeated difference {d}"
                diffs.add(d)


@pytest.mark.parametrize("n,r", [(9, 3), (64, 6), (200, 9), (200, 12),
                                 (600, 8), (600, 20), (1000, 9), (1000, 26)])
def test_lemma_b2_no_two_types_share_two_hosts(n, r):
    validate_placement(n, r)


@pytest.mark.parametrize("n,r", [(9, 3), (200, 9), (600, 8), (1000, 10)])
def test_host_and_type_sets_are_duals(n, r):
    h = host_sets(n, r)
    t = type_sets(n, r)
    # w hosts i  <=>  i in T_w  <=>  w in H_i
    for i in range(0, n, max(1, n // 17)):
        for w in h[i]:
            assert i in t[w]
    # every group hosts exactly r types; every type has exactly r hosts
    assert h.shape == (n, r) and t.shape == (n, r)
    assert len(set(map(int, h[0]))) == r


def test_stack0_covers_all_types():
    # cyclic rotation guarantees stack 0 across groups covers all N types
    for n, r in [(9, 3), (200, 9), (600, 8)]:
        t = type_sets(n, r)
        assert set(map(int, t[:, 0])) == set(range(n))


def test_ruler_embeds_mod_small_n():
    # r=3 ruler (0,1,3) is cyclic-Golomb mod 9 (paper Fig. 3 example)
    assert is_cyclic_golomb((0, 1, 3), 9)
    # ... but not mod 4 (differences collide)
    assert not is_cyclic_golomb((0, 1, 3), 4)


def test_pigeonhole_rejection():
    with pytest.raises(ValueError):
        golomb_ruler(10, 50)  # r(r-1)=90 > 49 residues


def test_greedy_fallback_kicks_in():
    # N too small for the table-optimal span but large enough for a Sidon set
    marks = golomb_ruler(4, 17)
    assert is_cyclic_golomb(marks, 17)


def test_max_redundancy_monotone():
    assert max_redundancy(200) >= 12
    assert max_redundancy(600) >= 20
    assert max_redundancy(1000) >= 26
