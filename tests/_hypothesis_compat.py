"""Minimal seeded-random stand-in for `hypothesis` (see requirements-dev.txt).

The property tests in this repo use a small slice of hypothesis:
``@given`` + ``@settings`` with the ``integers`` / ``sampled_from`` /
``permutations`` / ``lists`` / ``composite`` / ``data`` strategies. When
the real library is installed (``pip install -r requirements-dev.txt``)
the tests use it and get shrinking, the example database, and smarter
exploration. When it is not — the CI-minimal / air-gapped case — this
shim provides API-compatible, deterministically seeded random sampling so
the suite still *collects and runs* with meaningful (if less adversarial)
coverage instead of erroring out at import time.

Usage in test modules::

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:                      # pragma: no cover
        from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

import functools
import random
from types import SimpleNamespace

__all__ = ["given", "settings", "st"]

_DEFAULT_MAX_EXAMPLES = 100
_SEED = 0x5BA2E  # fixed: failures must reproduce across runs


class _Strategy:
    """A strategy is just a draw callable over a `random.Random`."""

    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def draw(self, rnd: random.Random):
        return self._draw_fn(rnd)


class _DataObject:
    """Interactive draws inside the test body (``st.data()``)."""

    def __init__(self, rnd: random.Random):
        self._rnd = rnd

    def draw(self, strategy: _Strategy):
        return strategy.draw(self._rnd)


class _DataStrategy(_Strategy):
    def __init__(self):
        super().__init__(lambda rnd: _DataObject(rnd))


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rnd: rnd.choice(seq))


def permutations(seq) -> _Strategy:
    seq = list(seq)

    def draw(rnd):
        out = list(seq)
        rnd.shuffle(out)
        return out

    return _Strategy(draw)


def lists(elements: _Strategy, min_size: int = 0, max_size: int | None = None,
          unique: bool = False) -> _Strategy:
    def draw(rnd):
        hi = max_size if max_size is not None else min_size + 8
        size = rnd.randint(min_size, hi)
        out = []
        seen = set()
        attempts = 0
        while len(out) < size and attempts < 100 * (size + 1):
            v = elements.draw(rnd)
            attempts += 1
            if unique:
                if v in seen:
                    continue
                seen.add(v)
            out.append(v)
        return out

    return _Strategy(draw)


def composite(fn):
    """``@st.composite``: fn(draw, *args) -> value becomes a strategy
    factory, mirroring hypothesis' signature."""

    @functools.wraps(fn)
    def factory(*args, **kwargs):
        return _Strategy(
            lambda rnd: fn(lambda strat: strat.draw(rnd), *args, **kwargs))

    return factory


def data() -> _Strategy:
    return _DataStrategy()


st = SimpleNamespace(
    integers=integers,
    sampled_from=sampled_from,
    permutations=permutations,
    lists=lists,
    composite=composite,
    data=data,
)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Records run parameters on the test function (``deadline`` and any
    other hypothesis-only knobs are accepted and ignored)."""

    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn

    return deco


def given(*strategies: _Strategy):
    """Run the test ``max_examples`` times with seeded random draws."""

    def deco(fn):
        max_examples = getattr(fn, "_compat_max_examples",
                               _DEFAULT_MAX_EXAMPLES)

        def wrapper():
            for example in range(max_examples):
                rnd = random.Random(f"{_SEED}:{example}")
                drawn = [s.draw(rnd) for s in strategies]
                try:
                    fn(*drawn)
                except Exception as e:  # noqa: BLE001 — re-raise annotated
                    raise AssertionError(
                        f"property falsified on example {example} "
                        f"(seed={_SEED}): {e}"
                    ) from e

        # copy identity by hand: functools.wraps would expose the wrapped
        # function's parameters via __wrapped__, and pytest would then try
        # to resolve the strategy parameters as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # keep the marker so stacked decorators in either order work
        wrapper._compat_max_examples = max_examples
        return wrapper

    return deco
