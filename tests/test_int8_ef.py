"""Fused int8-EF Pallas kernel vs reference + unfused collectives path.

Unlike the other kernel tests (tpu-marked), these run in tier-1 on the
CPU wheel: the kernel body executes in interpret mode, and its contract
is checked against the *jitted* :func:`repro.kernels.ref.int8_ef_ref` —
payload and scale bit-identical, residual within one fp32 ulp of the
dequantized value (compiler FMA contraction; see the kernel docstring).
The reference must go through the same compilation pipeline as the
kernel: XLA:CPU's default fast-math rewrites the ``/127`` scale divide
into a reciprocal multiply, so eager-vs-jitted differ by an ulp of
scale regardless of the kernel.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.collectives import compress_grad_int8, decompress_grad_int8
from repro.kernels.ops import int8_ef_quantize
from repro.kernels.ref import int8_ef_ref

RNG = np.random.default_rng(7)

SHAPES_DTYPES = [
    ((33, 70), jnp.float32),       # ragged 2-D, needs padding
    ((4096,), jnp.float32),        # exactly one (32, 128) multiple
    ((5000,), jnp.bfloat16),       # low-precision grads, ragged
    ((2, 3, 129), jnp.float16),    # odd trailing dim
    ((1,), jnp.float32),           # single element
]


def _ulp_bound(x):
    """One ulp at the magnitude of the largest dequantized value."""
    return float(jnp.max(jnp.abs(x))) * 1.5e-7 + 1e-30


@pytest.mark.parametrize("shape,dtype", SHAPES_DTYPES)
def test_kernel_matches_reference(shape, dtype):
    g = jnp.asarray(RNG.normal(size=shape), dtype)
    e = jnp.asarray(RNG.normal(size=shape) * 0.01, jnp.float32)
    qk, sk, ek = int8_ef_quantize(g, e, interpret=True)
    qr, sr, er = jax.jit(int8_ef_ref)(g, e)
    assert qk.dtype == jnp.int8 and qk.shape == shape
    assert ek.dtype == jnp.float32 and ek.shape == shape
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    assert float(sk) == float(sr)
    x = g.astype(jnp.float32) + e
    np.testing.assert_allclose(np.asarray(ek), np.asarray(er),
                               atol=_ulp_bound(x), rtol=0)


@pytest.mark.parametrize("shape,dtype", SHAPES_DTYPES)
def test_ef_invariant_through_kernel(shape, dtype):
    """restored + new_error == grad + error, to one fp32 ulp."""
    g = jnp.asarray(RNG.normal(size=shape), dtype)
    e = jnp.asarray(RNG.normal(size=shape) * 0.01, jnp.float32)
    q, s, err = int8_ef_quantize(g, e, interpret=True)
    x = g.astype(jnp.float32) + e
    restored = decompress_grad_int8(q, s)
    np.testing.assert_allclose(np.asarray(restored + err), np.asarray(x),
                               atol=_ulp_bound(x), rtol=0)


def test_kernel_matches_unfused_collectives_path():
    g = jnp.asarray(RNG.normal(size=(700,)), jnp.float32)
    e = jnp.asarray(RNG.normal(size=(700,)) * 0.01, jnp.float32)
    qk, sk, ek = compress_grad_int8(g, e, fused=True)    # kernel (interp)
    qu, su, eu = jax.jit(
        lambda a, b: compress_grad_int8(a, b, fused=False))(g, e)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qu))
    assert float(sk) == float(su)
    np.testing.assert_allclose(np.asarray(ek), np.asarray(eu),
                               atol=_ulp_bound(g + e), rtol=0)


def test_all_zero_tensor_safe():
    z = jnp.zeros((300,), jnp.float32)
    q, s, err = int8_ef_quantize(z, z, interpret=True)
    assert float(s) == 0.0
    assert not np.asarray(q).any()
    assert not np.asarray(err).any()


def test_error_feedback_converges_over_steps():
    """Cumulative transmitted signal tracks the cumulative gradient —
    the property that makes 8-bit compression safe for training."""
    g = jnp.asarray(RNG.normal(size=(512,)), jnp.float32)
    err = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    for _ in range(20):
        q, s, err = int8_ef_quantize(g, err, interpret=True)
        sent = sent + decompress_grad_int8(q, s)
    # after k steps: |k*g - sent| == |final residual| <= scale/2 + ulps
    resid = np.abs(np.asarray(20.0 * g - sent))
    assert resid.max() <= float(s) / 2 + 1e-4
