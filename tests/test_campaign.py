"""Campaign runner: grids, deterministic seeding, parallel execution.

Acceptance points: a process-parallel run is byte-identical to a serial
run of the same grid, and the adaptive scheme's ranking is
regime-dependent across quiet / bursty / correlated failure models.
"""
import json

import pytest

from repro.scenarios import (
    CAMPAIGN_PRESETS,
    CampaignSpec,
    aggregate,
    cell_seed,
    ranking_by_regime,
    run_campaign,
    run_cell,
    save_artifacts,
)

SMOKE = CampaignSpec(
    name="test_smoke",
    schemes=["spare", "replication"],
    ns=[200], rs=[4],
    models=[{"kind": "weibull", "label": "weibull"},
            {"kind": "correlated", "label": "rack", "burst_prob": 0.3}],
    seeds=[0], steps=120,
)


def test_grid_expansion_shapes():
    cells = SMOKE.cells()
    assert len(cells) == 4                       # 2 schemes x 2 models
    assert {c["scheme"] for c in cells} == {"spare", "replication"}
    assert all(c["steps"] == 120 for c in cells)


def test_grid_skips_r_axis_for_ckpt_only_and_pins_explicit_r():
    spec = CampaignSpec(name="x", schemes=["ckpt_only",
                                           ("replication", {"r": 2}),
                                           "spare"],
                        ns=[200], rs=[4, 9], seeds=[0])
    cells = spec.cells()
    by_scheme = {}
    for c in cells:
        by_scheme.setdefault(c["scheme"], []).append(c.get("r"))
    assert by_scheme["ckpt_only"] == [None]      # no r sweep
    assert by_scheme["replication"] == [2]       # pinned, not [4, 9]
    assert sorted(by_scheme["spare"]) == [4, 9]


def test_cell_seed_is_stable_and_distinct():
    cells = SMOKE.cells()
    seeds = [cell_seed(c) for c in cells]
    assert seeds == [cell_seed(c) for c in cells]          # stable
    assert len(set(seeds)) == len(seeds)                   # distinct
    assert cell_seed(cells[0], base_seed=1) != seeds[0]    # base folds in


def test_run_cell_returns_deterministic_row():
    cell = SMOKE.cells()[0]
    a = run_cell(dict(cell))
    b = run_cell(dict(cell))
    assert a["wall"] == b["wall"]
    assert a["ttt_norm"] == b["ttt_norm"]
    assert a["scheme"] == "spare" and a["model"] == "weibull"


def test_base_seed_flows_from_spec_and_raw_cell_matches_campaign():
    """Regression: a grid's base_seed must reach the per-cell hash, and
    run_cell on a raw spec cell must equal the same cell inside
    run_campaign (base_seed is excluded from the key, folded into the
    seed salt only)."""
    kw = dict(name="s", schemes=["spare"], ns=[200], rs=[4],
              models=[{"kind": "weibull"}], seeds=[0], steps=80)
    r0 = run_campaign(CampaignSpec(**kw).cells(), jobs=1)[0]
    r7 = run_campaign(CampaignSpec(**kw, base_seed=7).cells(), jobs=1)[0]
    assert r0["wall"] != r7["wall"]
    assert r0["key"] == r7["key"]               # same cell, other replica
    raw = run_cell(CampaignSpec(**kw).cells()[0])
    assert raw["wall"] == r0["wall"] and raw["key"] == r0["key"]


def test_campaign_smoke_2x2_grid():
    results = run_campaign(SMOKE.cells(), jobs=1)
    assert len(results) == 4
    csv_text, obj = aggregate(results)
    assert csv_text.count("\n") == 5             # header + 4 rows
    assert set(obj["ranking"]) == {"n=200/weibull", "n=200/rack"}


def test_parallel_equals_serial_byte_identical():
    """The acceptance determinism bar: worker count must not leak into
    the aggregated artifacts."""
    serial = run_campaign(SMOKE.cells(), jobs=1)
    parallel = run_campaign(SMOKE.cells(), jobs=2)
    csv_s, obj_s = aggregate(serial)
    csv_p, obj_p = aggregate(parallel)
    assert csv_s == csv_p
    assert json.dumps(obj_s, sort_keys=True) == \
        json.dumps(obj_p, sort_keys=True)


def test_save_artifacts_roundtrip(tmp_path):
    results = run_campaign(SMOKE.cells()[:2], jobs=1)
    csv_path, json_path = save_artifacts("t", results, outdir=tmp_path)
    assert csv_path.read_text().startswith("scheme,")
    obj = json.loads(json_path.read_text())
    assert len(obj["cells"]) == 2
    assert all("elapsed_s" not in c for c in obj["cells"])


def test_spec_from_json_roundtrip(tmp_path):
    path = tmp_path / "grid.json"
    path.write_text(json.dumps({
        "schemes": ["spare"], "ns": [200], "rs": [4],
        "models": [{"kind": "poisson"}], "seeds": [0], "steps": 50}))
    spec = CampaignSpec.from_json(path)
    assert spec.name == "grid"
    assert len(spec.cells()) == 1
    row = run_cell(dict(spec.cells()[0], base_seed=0))
    assert row["steps_done"] == 50


def test_presets_expand():
    for name, spec in CAMPAIGN_PRESETS.items():
        cells = spec.cells()
        assert cells, name
        if name == "smoke":
            assert len(cells) == 4
        if name == "quick":
            assert len(cells) >= 8               # speedup grid


def test_adaptive_regime_dependent_ranking():
    """ISSUE-2 acceptance: across quiet Poisson / bursty Weibull /
    correlated rack-kill regimes the fixed-scheme ranking flips, and
    adaptive tracks the per-regime winner."""
    spec = CampaignSpec(
        name="regimes_mini",
        schemes=["ckpt_only", ("replication", {"r": 2}), "spare",
                 "adaptive"],
        ns=[200], rs=[9],
        models=[
            {"kind": "poisson", "label": "quiet", "mtbf": 50_000.0},
            {"kind": "weibull", "label": "bursty", "shape": 0.55,
             "mtbf": 300.0},
            {"kind": "correlated", "label": "rack_kill",
             "burst_prob": 0.25, "mtbf": 600.0},
        ],
        seeds=[0], steps=250,
    )
    results = run_campaign(spec.cells(), jobs=1)
    ranking = ranking_by_regime(results)
    order = {regime.split("/")[1]: [e["scheme"] for e in entries]
             for regime, entries in ranking.items()}
    mean = {regime.split("/")[1]:
            {e["scheme"]: e["mean_ttt_norm"] for e in entries}
            for regime, entries in ranking.items()}

    # quiet: 1-stack policies win; replication's 2x compute loses
    assert order["quiet"][-1] == "replication"
    assert mean["quiet"]["ckpt_only"] < 1.2
    # storms: ckpt_only collapses (restart-dominant), spare wins
    for regime in ("bursty", "rack_kill"):
        assert order[regime][0] in ("spare", "adaptive")
        assert order[regime][-1] == "ckpt_only"
        assert mean[regime]["ckpt_only"] > 2 * mean[regime]["spare"]
    # the ranking actually flips with the regime
    assert order["quiet"] != order["rack_kill"]
    # adaptive tracks the winner everywhere (within 25%)
    for regime, by_scheme in mean.items():
        best_fixed = min(v for s, v in by_scheme.items() if s != "adaptive")
        assert by_scheme["adaptive"] <= best_fixed * 1.25, regime
