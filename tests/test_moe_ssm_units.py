"""Unit + property tests for the MoE dispatch and SSD layers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # pragma: no cover
    from _hypothesis_compat import given, settings, st

from repro.models.config import ModelConfig, MoEConfig, SSMConfig
from repro.models.moe import expert_ffn_local, moe_ffn_reference, route_topk
from repro.models.ssm import ssd_chunked, ssd_decode_step

RNG = np.random.default_rng(0)


def _moe_cfg(e=8, k=2, d=16, fe=32, shared=0):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=d, n_heads=2,
        n_kv_heads=2, d_ff=0, vocab=64,
        moe=MoEConfig(n_experts=e, top_k=k, d_expert=fe, n_shared=shared))


def test_route_topk_properties():
    x = jnp.asarray(RNG.normal(size=(32, 16)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(16, 8)), jnp.float32)
    idx, wts = route_topk(x, w, 3)
    assert idx.shape == (32, 3) and wts.shape == (32, 3)
    np.testing.assert_allclose(np.asarray(wts).sum(-1), 1.0, atol=1e-6)
    # indices unique per token
    for row in np.asarray(idx):
        assert len(set(row.tolist())) == 3


def test_expert_dispatch_equals_dense_when_capacity_ample():
    """Sharded local dispatch (all experts local) == dense reference when
    nothing is dropped."""
    cfg = _moe_cfg()
    t, d = 24, 16
    x = jnp.asarray(RNG.normal(size=(t, d)), jnp.float32)
    router = jnp.asarray(RNG.normal(size=(d, 8)), jnp.float32)
    experts = {
        "w_gate": jnp.asarray(RNG.normal(size=(8, d, 32)) * 0.1, jnp.float32),
        "w_up": jnp.asarray(RNG.normal(size=(8, d, 32)) * 0.1, jnp.float32),
        "w_down": jnp.asarray(RNG.normal(size=(8, 32, d)) * 0.1, jnp.float32),
    }
    idx, wts = route_topk(x, router, 2)
    got = expert_ffn_local(x, idx, wts, experts, e_first=0, e_local=8,
                           capacity=t * 2)
    ref = moe_ffn_reference(x[None], {"router": router, "experts": experts},
                            cfg)[0]
    np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4)


def test_expert_dispatch_partial_ranks_sum_to_whole():
    """EP invariant: sum of per-rank partial combines == full combine
    (this is what the psum over 'model' computes)."""
    t, d, e = 16, 8, 4
    x = jnp.asarray(RNG.normal(size=(t, d)), jnp.float32)
    router = jnp.asarray(RNG.normal(size=(d, e)), jnp.float32)
    experts = {
        "w_gate": jnp.asarray(RNG.normal(size=(e, d, 16)) * 0.1, jnp.float32),
        "w_up": jnp.asarray(RNG.normal(size=(e, d, 16)) * 0.1, jnp.float32),
        "w_down": jnp.asarray(RNG.normal(size=(e, 16, d)) * 0.1, jnp.float32),
    }
    idx, wts = route_topk(x, router, 2)
    full = expert_ffn_local(x, idx, wts, experts, 0, e, capacity=64)
    half = sum(
        expert_ffn_local(
            x, idx, wts,
            jax.tree.map(lambda a: a[r * 2:(r + 1) * 2], experts),
            e_first=r * 2, e_local=2, capacity=64)
        for r in range(2))
    np.testing.assert_allclose(half, full, atol=1e-5, rtol=1e-5)


def test_capacity_drop_bounded():
    """With capacity C, each expert processes <= C slots; dropped tokens
    produce zero contribution (never garbage)."""
    t, d, e = 64, 8, 2
    x = jnp.ones((t, d), jnp.float32)
    idx = jnp.zeros((t, 1), jnp.int32)          # all tokens -> expert 0
    wts = jnp.ones((t, 1), jnp.float32)
    experts = {
        "w_gate": jnp.ones((e, d, 4), jnp.float32),
        "w_up": jnp.ones((e, d, 4), jnp.float32),
        "w_down": jnp.ones((e, 4, d), jnp.float32),
    }
    out = expert_ffn_local(x, idx, wts, experts, 0, e, capacity=8)
    nonzero_rows = int((np.abs(np.asarray(out)).sum(-1) > 0).sum())
    assert nonzero_rows == 8                     # exactly capacity survived


# ------------------------------------------------------------------ #
# SSD                                                                 #
# ------------------------------------------------------------------ #
def test_ssd_chunked_equals_stepwise():
    b, s, h, p, n = 1, 64, 2, 8, 16
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.1, (b, s, h)), jnp.float32)
    a_log = jnp.zeros((h,), jnp.float32)
    bb = jnp.asarray(RNG.normal(size=(b, s, h, n)), jnp.float32)
    cc = jnp.asarray(RNG.normal(size=(b, s, h, n)), jnp.float32)
    y_chunk, final = ssd_chunked(x, dt, a_log, bb, cc, chunk=16)

    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y_t, state = ssd_decode_step(
            x[:, t], dt[:, t], a_log, bb[:, t], cc[:, t], state)
        ys.append(y_t)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_step, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(final, state, atol=2e-4, rtol=2e-4)


@given(st.integers(1, 4), st.sampled_from([16, 32, 64]))
@settings(max_examples=10, deadline=None)
def test_ssd_state_continuation(nchunks, chunk):
    """Splitting a sequence and feeding state0 across the split equals the
    unsplit scan (the decode/prefill handoff invariant)."""
    b, h, p, n = 1, 2, 4, 8
    s = nchunks * chunk
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (b, s, h)), jnp.float32)
    a_log = jnp.zeros((h,), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    y_full, st_full = ssd_chunked(x, dt, a_log, bb, cc, chunk=chunk)
    half = s // 2
    if half % chunk:
        return
    y1, st1 = ssd_chunked(x[:, :half], dt[:, :half], a_log,
                          bb[:, :half], cc[:, :half], chunk=chunk)
    y2, st2 = ssd_chunked(x[:, half:], dt[:, half:], a_log,
                          bb[:, half:], cc[:, half:], chunk=chunk,
                          state0=st1)
    np.testing.assert_allclose(
        jnp.concatenate([y1, y2], axis=1), y_full, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(st2, st_full, atol=1e-4, rtol=1e-4)
