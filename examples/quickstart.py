"""Quickstart: SPARe in 60 lines.

Builds a tiny decoder-only LM, wraps it in the SPARe trainer with N=8
data-parallel groups at redundancy r=3, injects failures every ~3 steps,
and shows training sailing through them without global restarts.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import smoke_config
from repro.core.theory import mu, r_star, s_bar
from repro.des import get_scheme
from repro.train.trainer import PoissonInjector, SpareTrainer

N, R = 8, 3

print(f"SPARe(N={N}, r={R}): masks ~{mu(N, R):.1f} failures before the "
      f"first wipe-out at ~{s_bar(N, R):.2f}x compute "
      f"(traditional replication would pay {R}x). Thm-4.3 optimal r* "
      f"for N={N}: {r_star(N)}")

cfg = smoke_config("qwen2.5-3b").scaled(grad_accum=1)
# the recovery policy is pluggable — any registered FaultToleranceScheme
# (the same objects the DES simulates); "spare" is also the default
trainer = SpareTrainer(cfg, n_groups=N, redundancy=R, seq=64,
                       per_type_batch=2, ckpt_dir="/tmp/spare_quickstart",
                       total_steps=60, scheme=get_scheme("spare", r=R))

report = trainer.run(40, injector=PoissonInjector(3.0, seed=0))

print(f"\ncompleted {report.steps_done} steps "
      f"(loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f})")
print(f"failures injected : {report.failures}")
print(f"wipe-outs (global restarts): {report.wipeouts}")
print(f"reorders / patch computes  : {report.reorders} / {report.patches}")
print(f"final all-reduce stack S_A : {trainer.state.s_a}")
print(f"survivors: {trainer.state.alive.sum()}/{N}")
print(f"RECTLR total time: {report.controller_seconds * 1e3:.1f} ms")
