"""Deep dive: watch RECTLR (Alg. 2) react to a failure trail.

Reproduces the paper's Fig. 3 walkthrough (N=9, r=3) then drives a larger
(N=32, r=5) system through a full random failure trail until wipe-out,
printing per-event controller decisions — and verifies the §3.1 gradient
invariant at every stage against a vanilla-DP oracle. Contrasts
SPARe against replication under a *correlated rack-burst* failure regime
(repro.scenarios), where whole racks of groups die simultaneously —
the regime production traces report, not the paper's i.i.d. one.
Finally drives the REAL trainer under that rack-burst model through the
live-failure bridge (repro.train.injection): whole-rack kill batches
reach scheme.recover in one call, and the §3.1 invariant is re-verified
after every recovery.

Run:  PYTHONPATH=src python examples/failure_masking_deep_dive.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core import Rectlr, SpareState
from repro.core.theory import capacity, mu
from repro.train.trainer import SpareTrainer

# ---------------------------------------------------------------- #
print("== paper Fig. 3 walkthrough: N=9, r=3 ==")
st, ctl = SpareState(9, 3), Rectlr()
print(f"(b) no failures: all types collectible at stack {st.s_a}")
ctl.on_failures(st, [1])
print(f"(c) group 1 fails -> all-reduce stack {st.s_a}")
out = ctl.on_failures(st, [2])
print(f"(e) group 2 fails -> reordered={out.reordered}, stack stays "
      f"{st.s_a} (Fig. 3e: reorder instead of 3rd stack), "
      f"moves={out.moves}")

# ---------------------------------------------------------------- #
print("\n== random failure trail: N=32, r=5 "
      f"(theory: masks ~{mu(32, 5):.0f} failures) ==")
st, ctl = SpareState(32, 5), Rectlr()
rng = np.random.default_rng(0)
k = 0
for w in rng.permutation(32):
    out = ctl.on_failures(st, [int(w)])
    k += 1
    if out.wipeout:
        print(f"k={k:2d}: group {w:2d} FAILS -> WIPE-OUT (global restart)")
        break
    tag = ("reorder" if out.reordered else "ok     ")
    print(f"k={k:2d}: group {w:2d} fails -> {tag} S_A={st.s_a} "
          f"(c(k)={capacity(k, 32)}) patches={out.patch_count} "
          f"moves={out.moves} hk_calls={out.hk_free_calls} "
          f"[{out.controller_seconds * 1e3:.2f} ms]")

# ---------------------------------------------------------------- #
print("\n== gradient invariant under failures (vs vanilla-DP oracle) ==")
cfg = smoke_config("glm4-9b").scaled(grad_accum=1)
tr = SpareTrainer(cfg, n_groups=8, redundancy=3, seq=32, per_type_batch=2)
ref = tr.vanilla_reference_grads(0)
for failures in ([], [2], [5], [7]):
    if failures:
        tr.ctl.on_failures(tr.state, failures)
    got = tr.spare_grads(0)
    diff = jax.tree.reduce(max, jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        ref, got))
    print(f"after failing {failures or 'nobody'}: S_A={tr.state.s_a}, "
          f"max |g_spare - g_vanilla| = {diff:.2e}")

# ---------------------------------------------------------------- #
print("\n== SPARe vs replication under correlated rack bursts ==")
from repro.des import DESParams, get_scheme
from repro.scenarios import ClusterTopology, model_from_spec

p = DESParams(n=200, steps=400)
topo = ClusterTopology(n_groups=200, hosts_per_rack=8)
regimes = {
    "iid weibull (paper Sec. 5)": {"kind": "weibull"},
    "rack bursts (25% of events kill a rack)":
        {"kind": "correlated", "scope": "rack", "burst_prob": 0.25},
}
print(f"{'regime':44s} {'scheme':14s} {'ttt/T0':>7s} {'avail':>6s} "
      f"{'wipeouts':>8s}")
for label, spec in regimes.items():
    for name, kw in (("spare", {"r": 9}), ("replication", {"r": 2})):
        res = get_scheme(name, **kw).simulate(
            p, seed=0, failure_model=model_from_spec(spec), topology=topo)
        print(f"{label:44s} {name:14s} {res.ttt_norm:7.2f} "
              f"{res.availability:6.3f} {res.wipeouts:8d}")
print("""
Rack bursts hit replication hardest: degree-2 replication dies whenever
both hosts of a type share the blast radius, while SPARe's cyclic-Golomb
placement spreads each type's r hosts across racks — exactly the
placement-diversity argument of Thm. 4.1, now visible under a failure
regime the paper never simulated.""")

# ---------------------------------------------------------------- #
print("\n== the REAL trainer under rack bursts (live-failure bridge) ==")
from repro.des.params import DESParams
from repro.train.injection import ScenarioInjector

# 2 hosts/group, 4 hosts/rack: every rack holds exactly 2 DP groups,
# so each burst is a genuine simultaneous multi-group kill
topo8 = ClusterTopology(n_groups=8, hosts_per_group=2, hosts_per_rack=4)
inj = ScenarioInjector(
    {"kind": "correlated", "scope": "rack", "burst_prob": 1.0,
     "mtbf": 400.0}, topo8, n_groups=8,
    params=DESParams(n=8, t_comp=64.0), seed=3)
tr = SpareTrainer(smoke_config("qwen2.5-3b").scaled(grad_accum=1),
                  n_groups=8, redundancy=3, seq=32, per_type_batch=1,
                  total_steps=100)
rep = tr.run(25, injector=inj, verify_equivalence=True)
for ev in rep.events:
    kind = ("WIPE-OUT" if ev.wipeout
            else "reorder" if ev.reordered else "mask")
    err = (f" §3.1 err={ev.grad_check_err:.1e}"
           if ev.grad_check_err is not None else "")
    print(f"step {ev.step:3d}: kill {ev.victims} -> {kind} "
          f"S_A {ev.s_a_before}->{ev.s_a_after} patches={ev.patch_count}"
          f" rollback={ev.rollback_depth}{err}")
print(f"steps={rep.steps_done} failures={rep.failures} "
      f"multi-group batches to scheme.recover={rep.multi_group_events} "
      f"max §3.1 err={rep.max_grad_check_err:.2e}")
print("""
Whole racks die in one event, the controller recovers the schedule in
one recover() call per burst, and the collected gradient stays equal to
vanilla DP's after every recovery — the invariant the simulator assumed,
now exercised by the executable protocol.""")
