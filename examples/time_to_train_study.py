"""Time-to-train study (paper Sec. 5 at reduced horizon).

Sweeps redundancy r for SPARe+CKPT vs Rep+CKPT on the Table-1 600k-H100
parameters (N=200 data-parallel groups, MTBF 300 s, T_r = 1 h) and prints
the Fig.-6-style table: normalized time-to-train, availability and
average computed stacks per step — reproducing the 40-50 % gain at a
horizon that runs in about a minute on CPU.

Run:  PYTHONPATH=src python examples/time_to_train_study.py [--steps 1500]
"""
import argparse

from repro.core.theory import j_normalized, mu, s_bar
from repro.des import DESParams, get_scheme

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=1500)
ap.add_argument("--n", type=int, default=200)
args = ap.parse_args()

p = DESParams(n=args.n, steps=args.steps)
print(f"N={p.n}, steps={p.steps}, MTBF={p.mtbf}s, T_r={p.t_restart}s, "
      f"T_comp={p.t_comp}s, T_a={p.t_allreduce}s\n")

print(f"{'scheme':12s} {'r':>3s} {'ttt/T0':>7s} {'avail':>7s} "
      f"{'stacks':>7s} {'fails':>6s} {'wipes':>6s}   theory J(r)")
best = {}
for r in (2, 3, 4):
    res = get_scheme("replication", r=r).simulate(p, seed=0)
    best.setdefault("rep", []).append(res)
    print(f"{'Rep+CKPT':12s} {r:3d} {res.ttt_norm:7.2f} "
          f"{res.availability * 100:6.1f}% {float(r):7.1f} "
          f"{res.node_failures:6d} {res.wipeouts:6d}")
for r in (3, 6, 9, 12):
    res = get_scheme("spare", r=r).simulate(p, seed=0)
    best.setdefault("spare", []).append(res)
    print(f"{'SPARe+CKPT':12s} {r:3d} {res.ttt_norm:7.2f} "
          f"{res.availability * 100:6.1f}% {res.avg_stacks:7.2f} "
          f"{res.node_failures:6d} {res.wipeouts:6d}   "
          f"J={j_normalized(r, p.n):.2f} "
          f"(mu={mu(p.n, r):.0f}, S={s_bar(p.n, r):.2f})")

r_best = min(best["spare"], key=lambda x: x.ttt_norm).r
res = get_scheme("adaptive", r=r_best).simulate(p, seed=0)
print(f"{'Adaptive':12s} {r_best:3d} {res.ttt_norm:7.2f} "
      f"{res.availability * 100:6.1f}% {res.avg_stacks:7.2f} "
      f"{res.node_failures:6d} {res.wipeouts:6d}   "
      f"(policy switches: {res.mode_switches})")

rep_best = min(best["rep"], key=lambda x: x.ttt_norm)
spare_best = min(best["spare"], key=lambda x: x.ttt_norm)
gain = 1 - spare_best.ttt_norm / rep_best.ttt_norm
print(f"\nbest Rep+CKPT   : r={rep_best.r}  ttt/T0={rep_best.ttt_norm:.2f}")
print(f"best SPARe+CKPT : r={spare_best.r}  ttt/T0={spare_best.ttt_norm:.2f}")
print(f"time-to-train gain: {gain * 100:.1f}%  (paper Table 2: 40-52%)")
