"""Batched serving example: prefill + greedy decode with per-family caches.

Serves three architectures from three different families — dense GQA
(KV cache), MoE/MLA (compressed latent cache), and SSM (O(1) state) —
through the same ``serve_step`` API, demonstrating the zoo's uniform
decode contract. Checks decode/teacher-forcing consistency as it goes.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import build_model
from repro.train import make_serve_step

for arch in ("glm4-9b", "deepseek-v2-lite-16b", "mamba2-1.3b"):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    serve = jax.jit(make_serve_step(model), donate_argnums=(1,))

    b, prompt_len, gen_len = 4, 8, 24
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, (b, prompt_len), dtype=np.int32)
    state = model.init_decode_state(batch=b, s_max=prompt_len + gen_len)

    # teacher-forced prefill through the decode path
    for t in range(prompt_len):
        logits, state = serve(params, state, jnp.int32(t),
                              tokens=jnp.asarray(prompt[:, t:t + 1]))
    tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1)[:, None]

    t0 = time.perf_counter()
    out = [tok]
    for t in range(prompt_len, prompt_len + gen_len - 1):
        logits, state = serve(params, state, jnp.int32(t), tokens=out[-1])
        out.append(jnp.argmax(logits[:, :cfg.vocab], axis=-1)[:, None])
    jax.block_until_ready(out[-1])
    dt = time.perf_counter() - t0
    cache_kind = {"dense": "KV cache", "moe": "MLA latent cache",
                  "ssm": "SSD state (O(1))"}[cfg.family]
    print(f"{arch:22s} [{cache_kind:18s}] batch={b} "
          f"{b * len(out) / dt:7.1f} tok/s  "
          f"sample={np.asarray(out[0][:1]).ravel().tolist()}...")
print("all three families served through one serve_step contract")
