"""Discrete-event simulation of restart-dominant LLM pretraining (paper Sec. 5).

A SimGrid-equivalent DES specialized to the bulk-synchronous training
timeline: compute phases, gradient all-reduces, failure injection (Weibull,
exponentially distributed alternatives), communicator shrink, RECTLR,
patch computes, checkpoint saves, rework, and global restarts — with the
paper's Table 1 parameters for a 600k-H100 cluster as defaults.

Schemes (App. E flowchart):

* :func:`repro.des.schemes.simulate_ckpt_only`   — vanilla DP + CKPT
* :func:`repro.des.schemes.simulate_replication` — Rep+CKPT (degree r)
* :func:`repro.des.schemes.simulate_spare`       — SPARe+CKPT (exact Alg. 1/2
  semantics via :class:`repro.core.SpareState` + :class:`repro.core.Rectlr`)
"""
from .params import DESParams
from .schemes import SimResult, simulate_ckpt_only, simulate_replication, simulate_spare

__all__ = [
    "DESParams", "SimResult",
    "simulate_ckpt_only", "simulate_replication", "simulate_spare",
]
