"""Discrete-event simulation of restart-dominant LLM pretraining (paper Sec. 5).

A SimGrid-equivalent DES specialized to the bulk-synchronous training
timeline: compute phases, gradient all-reduces, failure injection (Weibull,
exponentially distributed alternatives), communicator shrink, RECTLR,
patch computes, checkpoint saves, rework, and global restarts — with the
paper's Table 1 parameters for a 600k-H100 cluster as defaults.

Schemes are pluggable :class:`FaultToleranceScheme` policies driven by one
shared bulk-synchronous engine (:mod:`repro.des.engine`) and resolved by
string key::

    from repro.des import DESParams, get_scheme

    res = get_scheme("spare", r=9).simulate(DESParams(n=200), seed=0)

Registered policies (App. E flowchart + beyond-paper additions):

* ``"ckpt_only"``   — vanilla DP + CKPT
* ``"replication"`` — Rep+CKPT (degree r)
* ``"spare"``       — SPARe+CKPT (exact Alg. 1/2 semantics via
  :class:`repro.core.SpareState` + :class:`repro.core.Rectlr`)
* ``"adaptive"``    — Chameleon-style selector switching among the above
  from the observed failure rate

The ``simulate_*`` functions remain as deprecated aliases of the registry
entries; new code should use :func:`get_scheme`.
"""
from .engine import (FailureRecovery, FaultToleranceScheme, SimClock,
                     SimResult, run_scheme)
from .params import DESParams
from .schemes import (
    AdaptiveScheme,
    CkptOnlyScheme,
    ReplicationScheme,
    SpareScheme,
    get_scheme,
    list_schemes,
    register_scheme,
    simulate_ckpt_only,
    simulate_replication,
    simulate_spare,
)

__all__ = [
    "DESParams", "SimResult", "SimClock",
    "FaultToleranceScheme", "FailureRecovery", "run_scheme",
    "CkptOnlyScheme", "ReplicationScheme", "SpareScheme", "AdaptiveScheme",
    "register_scheme", "get_scheme", "list_schemes",
    "simulate_ckpt_only", "simulate_replication", "simulate_spare",
]
