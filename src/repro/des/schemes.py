"""The three simulated schemes (paper App. E flowchart, Sec. 5.2).

All three share the same bulk-synchronous skeleton::

    [maybe checkpoint] -> compute phase -> all-reduce attempt
        |- no failure detected: commit step
        |- failure(s): failed all-reduce (0.5 T_a) -> scheme-specific recovery

and the same accounting:

* ``wall``       — total simulated wall-clock = time-to-train;
* ``committed``  — work time of steps that survived to the end (compute
  including redundant stacks and patches + successful all-reduces).
  Checkpoint saves, failed all-reduces, shrink/controller time, global
  restarts, and rolled-back (reworked) steps are downtime/waste.
  ``availability = committed / wall`` — matching Eq. 2's semantics, where
  J(r) = ttt/T0 = S_bar / A.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.rectlr import Rectlr
from ..core.state import SpareState
from ..core.theory import mu as mu_theory
from ..core.theory import tc_star
from .failures import FailureProcess
from .params import DESParams

__all__ = ["SimResult", "simulate_ckpt_only", "simulate_replication", "simulate_spare"]


@dataclass
class SimResult:
    scheme: str
    n: int
    r: int
    wall: float
    committed: float
    t0: float
    steps_done: int
    node_failures: int
    wipeouts: int
    ckpt_count: int
    total_stacks: float      # stacks computed across committed steps
    patches: int
    controller_seconds: float = 0.0

    @property
    def ttt_norm(self) -> float:
        return self.wall / self.t0

    @property
    def availability(self) -> float:
        return self.committed / self.wall if self.wall > 0 else 1.0

    @property
    def avg_stacks(self) -> float:
        return self.total_stacks / max(self.steps_done, 1)


class _Sim:
    """Shared clock / failure-stream / accounting plumbing."""

    def __init__(self, p: DESParams, seed: int):
        self.p = p
        self.rng = np.random.default_rng(seed)
        self.proc = FailureProcess(
            p.mtbf, p.weibull_shape, self.rng, law=p.failure_law,
            scale_with_survivors=p.scale_rate_with_survivors,
        )
        self.now = 0.0
        self.alive = p.n
        self.next_fail = self.proc.next_arrival(0.0, self.alive, p.n)
        self.pending: list[int] = []        # failed groups awaiting detection
        self.dead: set[int] = set()
        # accounting
        self.committed = 0.0
        self.work_since_ckpt = 0.0
        self.node_failures = 0
        self.wipeouts = 0
        self.ckpt_count = 0
        self.total_stacks = 0.0
        self.patches = 0
        self.stacks_since_ckpt = 0.0
        self.total_stacks_committed = 0.0

    # -------------------------------------------------------------- #
    def jitter(self) -> float:
        return max(0.0, float(self.rng.normal(1.0, self.p.jitter_std)))

    def advance(self, duration: float) -> float:
        """Advance the clock by a jittered duration; harvest failure
        arrivals that land inside the window into ``pending``."""
        dur = duration * self.jitter()
        end = self.now + dur
        while self.next_fail <= end and self.alive > 0:
            victim = self._draw_victim()
            if victim is not None:
                self.pending.append(victim)
                self.dead.add(victim)
                self.alive -= 1
                self.node_failures += 1
            self.next_fail = self.proc.next_arrival(
                self.next_fail, max(self.alive, 1), self.p.n
            )
        self.now = end
        return dur

    def _draw_victim(self) -> int | None:
        candidates = [w for w in range(self.p.n) if w not in self.dead]
        if not candidates:
            return None
        return int(self.rng.choice(candidates))

    def restart(self) -> None:
        """Global restart: T_r downtime, full capacity restored, progress
        rolls back to the last checkpoint (handled by caller), pending
        failure queue cleared, arrival process re-armed."""
        self.now += self.p.t_restart * self.jitter()
        self.dead.clear()
        self.pending.clear()
        self.alive = self.p.n
        self.wipeouts += 1
        self.work_since_ckpt = 0.0
        self.stacks_since_ckpt = 0.0
        self.next_fail = self.proc.next_arrival(self.now, self.alive, self.p.n)

    def checkpoint(self) -> None:
        self.advance(self.p.t_save)
        self.committed += self.work_since_ckpt
        self.total_stacks_committed += self.stacks_since_ckpt
        self.work_since_ckpt = 0.0
        self.stacks_since_ckpt = 0.0
        self.ckpt_count += 1

    def finish(self) -> None:
        self.committed += self.work_since_ckpt
        self.total_stacks_committed += self.stacks_since_ckpt


def _result(sim: _Sim, scheme: str, r: int, steps_done: int,
            controller_seconds: float = 0.0) -> SimResult:
    p = sim.p
    return SimResult(
        scheme=scheme, n=p.n, r=r,
        wall=sim.now, committed=sim.committed, t0=p.t0,
        steps_done=steps_done,
        node_failures=sim.node_failures, wipeouts=sim.wipeouts,
        ckpt_count=sim.ckpt_count,
        total_stacks=sim.total_stacks_committed,
        patches=sim.patches,
        controller_seconds=controller_seconds,
    )


# ------------------------------------------------------------------ #
# Scheme 1: CKPT-only (vanilla DP + checkpointing)                    #
# ------------------------------------------------------------------ #
def simulate_ckpt_only(p: DESParams, seed: int = 0,
                       t_c: float | None = None,
                       max_wall: float | None = None) -> SimResult:
    """Vanilla synchronous DP: *any* node failure is a system failure
    (all N partial gradients required), so every failure costs a global
    restart plus rework. In the restart-dominant regime this barely makes
    progress (paper Sec. 5.2.1)."""
    sim = _Sim(p, seed)
    t_c = t_c if t_c is not None else tc_star(p.mtbf, p.t_save, p.t_restart)
    max_wall = max_wall if max_wall is not None else 500.0 * p.t0

    step = 0
    ckpt_step = 0
    last_ckpt_wall = 0.0
    while step < p.steps and sim.now < max_wall:
        if sim.now - last_ckpt_wall >= t_c and step > ckpt_step:
            sim.checkpoint()
            ckpt_step = step
            last_ckpt_wall = sim.now
        work = sim.advance(p.t_comp)                # one stack
        if sim.pending:                             # detected at all-reduce
            sim.advance(p.t_allreduce * p.failed_allreduce_frac)
            step = ckpt_step                        # rework to last ckpt
            sim.restart()
            last_ckpt_wall = sim.now
            continue
        work += sim.advance(p.t_allreduce)
        if sim.pending:
            # failure landed inside the all-reduce window: treat as failed
            step = ckpt_step
            sim.restart()
            last_ckpt_wall = sim.now
            continue
        step += 1
        sim.work_since_ckpt += work
        sim.stacks_since_ckpt += 1.0
    sim.finish()
    return _result(sim, "ckpt_only", r=1, steps_done=step)


# ------------------------------------------------------------------ #
# Scheme 2: Rep+CKPT (traditional replication, degree r)              #
# ------------------------------------------------------------------ #
def simulate_replication(p: DESParams, r: int, seed: int = 0,
                         t_c: float | None = None,
                         max_wall: float | None = None) -> SimResult:
    """Traditional replication (Fig. 2): group ``w`` hosts the ``r``
    consecutive types ``{w .. w+r-1 mod N}`` and computes *all* of them
    every step (r x workload). Failures are masked while every type keeps
    >= 1 surviving host; wipe-out forces the global restart."""
    sim = _Sim(p, seed)
    n = p.n
    t_f = mu_theory(n, r) * p.mtbf
    t_c = t_c if t_c is not None else tc_star(t_f, p.t_save, p.t_restart)
    max_wall = max_wall if max_wall is not None else 500.0 * p.t0

    # hosts[i] = {i-r+1 .. i} mod N  (consecutive-window replication)
    hosts = (np.arange(n)[:, None] - np.arange(r)[None, :]) % n
    host_alive = np.full(n, r, dtype=np.int64)

    def apply_failures(groups: list[int]) -> bool:
        """Returns True on wipe-out."""
        for w in groups:
            types_of_w = (w + np.arange(r)) % n
            host_alive[types_of_w] -= 1
        return bool((host_alive == 0).any())

    step = 0
    ckpt_step = 0
    last_ckpt_wall = 0.0
    while step < p.steps and sim.now < max_wall:
        if sim.now - last_ckpt_wall >= t_c and step > ckpt_step:
            sim.checkpoint()
            ckpt_step = step
            last_ckpt_wall = sim.now
        work = sim.advance(r * p.t_comp)            # all r stacks, always
        if sim.pending:
            sim.advance(p.t_allreduce * p.failed_allreduce_frac)
            failed = sim.pending[:]
            sim.pending.clear()
            if apply_failures(failed):
                step = ckpt_step
                host_alive[:] = r
                sim.restart()
                last_ckpt_wall = sim.now
                continue
            sim.advance(p.t_shrink)
            # surviving copies already computed: redo all-reduce only
            work += sim.advance(p.t_allreduce)
            step += 1
            sim.work_since_ckpt += work
            sim.stacks_since_ckpt += r
            continue
        work += sim.advance(p.t_allreduce)
        step += 1
        sim.work_since_ckpt += work
        sim.stacks_since_ckpt += r
    sim.finish()
    return _result(sim, "replication", r=r, steps_done=step)


# ------------------------------------------------------------------ #
# Scheme 3: SPARe+CKPT (Alg. 1 exact semantics)                        #
# ------------------------------------------------------------------ #
def simulate_spare(p: DESParams, r: int, seed: int = 0,
                   t_c: float | None = None,
                   max_wall: float | None = None,
                   binary_search: bool = False,
                   dynamic_ckpt: bool = False,
                   straggler_frac: float = 0.0,
                   straggler_slowdown: float = 3.0) -> SimResult:
    """SPARe+CKPT with the *actual* protocol implementation: the DES calls
    the same :class:`SpareState`/:class:`Rectlr` objects the trainer uses,
    so simulated availability reflects the real controller decisions
    (all-reduce stack evolution, reordering, patch computes, wipe-outs).

    ``dynamic_ckpt`` enables the beyond-paper Weibull-aware checkpoint
    interval (Sec. 5.2.2 of the paper suggests it closes the low-r gap):
    with shape k < 1 the hazard rate is highest right after a failure, so
    the policy shortens the interval while failures are recent and relaxes
    back to T_c* as the system stays quiet.

    ``straggler_frac`` > 0 enables the beyond-paper straggler model: each
    step, that fraction of groups runs ``straggler_slowdown``x slow.
    Vanilla DP (and replication) wait for the slowest group; SPARe's
    early-all-reduce trigger fires as soon as every shard *type* is
    collectible from the fast groups' stacks — when redundancy covers a
    straggler's types elsewhere, its compute is off the critical path
    (the paper's "aggregate as soon as all types are collectible" doubles
    as straggler masking; here we quantify it).
    """
    sim = _Sim(p, seed)
    n = p.n
    t_f = mu_theory(n, r) * p.mtbf
    t_c_base = t_c if t_c is not None else tc_star(t_f, p.t_save, p.t_restart)
    max_wall = max_wall if max_wall is not None else 500.0 * p.t0

    state = SpareState(n, r)
    ctl = Rectlr(binary_search=binary_search)

    step = 0
    ckpt_step = 0
    last_ckpt_wall = 0.0
    last_failure_wall = -p.mtbf
    controller_seconds = 0.0

    def current_t_c() -> float:
        if not dynamic_ckpt:
            return t_c_base
        # hazard-adapted interval: fresh failures (age << MTBF) => shorter
        age = max(sim.now - last_failure_wall, 1.0)
        k = p.weibull_shape
        scale = min((age / p.mtbf) ** (1.0 - k), 1.5)
        return max(2.0 * p.t_save, t_c_base * scale)

    while step < p.steps and sim.now < max_wall:
        if sim.now - last_ckpt_wall >= current_t_c() and step > ckpt_step:
            sim.checkpoint()
            ckpt_step = step
            last_ckpt_wall = sim.now
        s_a = state.s_a
        if straggler_frac > 0.0:
            # which alive groups are slow this step?
            alive_groups = state.survivors
            slow = sim.rng.random(alive_groups.size) < straggler_frac
            fast = alive_groups[~slow]
            # fast groups' committed prefixes cover the stragglers' types?
            covered = np.zeros(state.n, dtype=bool)
            covered[state.stacks[fast, :s_a].ravel()] = True
            if covered.all():
                step_comp = s_a * p.t_comp          # stragglers irrelevant
            else:
                # SPARe masking: fast hosts supply the missing types by
                # computing extra stacks (the patch-compute path) — the
                # step costs the minimal covering depth d <= r, or waiting
                # for the stragglers, whichever is cheaper
                wait = straggler_slowdown * s_a
                best = wait
                for d in range(s_a + 1, state.r + 1):
                    if d >= wait:
                        break
                    cov = np.zeros(state.n, dtype=bool)
                    cov[state.stacks[fast, :d].ravel()] = True
                    if cov.all():
                        best = float(d)
                        break
                step_comp = best * p.t_comp
        else:
            step_comp = s_a * p.t_comp
        work = sim.advance(step_comp)               # compute S_A stacks
        if not sim.pending:
            work += sim.advance(p.t_allreduce)
            if sim.pending:
                # failure landed inside the all-reduce: it fails late;
                # charge the failed fraction and fall through to recovery
                work -= p.t_allreduce * (1.0 - p.failed_allreduce_frac)
            else:
                step += 1
                sim.work_since_ckpt += work
                sim.stacks_since_ckpt += s_a
                continue
        else:
            work += sim.advance(p.t_allreduce * p.failed_allreduce_frac)

        # ---- recovery path ----
        failed = sim.pending[:]
        sim.pending.clear()
        last_failure_wall = sim.now
        outcome = ctl.on_failures(state, failed)
        controller_seconds += outcome.controller_seconds
        sim.advance(p.t_controller)
        if outcome.wipeout:
            state.reset()
            step = ckpt_step
            sim.restart()
            last_ckpt_wall = sim.now
            continue
        # patch computes run in parallel across groups: time = max per-group
        patch_stacks = 0
        if outcome.patch:
            loads: dict[int, int] = {}
            for w, _ in outcome.patch:
                loads[w] = loads.get(w, 0) + 1
            patch_stacks = max(loads.values())
            work += sim.advance(patch_stacks * p.t_comp)
            sim.patches += len(outcome.patch)
        sim.advance(p.t_shrink)
        work += sim.advance(p.t_allreduce)          # redo the all-reduce
        step += 1
        sim.work_since_ckpt += work
        # wall-time-equivalent stacks this step: S_A at compute time plus the
        # critical-path patch stacks (this is exactly the c(k)+rho_k quantity
        # of Thm. 4.2, measured instead of predicted)
        sim.stacks_since_ckpt += s_a + patch_stacks
        continue
    sim.finish()
    res = _result(sim, "spare", r=r, steps_done=step,
                  controller_seconds=controller_seconds)
    return res
