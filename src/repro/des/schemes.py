"""Pluggable fault-tolerance schemes (paper App. E flowchart, Sec. 5.2).

Every scheme is a :class:`repro.des.engine.FaultToleranceScheme` driven by
the one shared bulk-synchronous engine (:func:`repro.des.engine.run_scheme`)
and registered under a string key::

    from repro.des import get_scheme, DESParams

    res = get_scheme("spare", r=9).simulate(DESParams(n=200), seed=0)

Registered schemes:

``ckpt_only``    — vanilla synchronous DP + checkpointing: any node failure
                   is a system failure (Sec. 5.2.1).
``replication``  — traditional replication of degree ``r`` (Fig. 2):
                   every group always computes all ``r`` hosted stacks.
``spare``        — SPARe+CKPT with exact Alg. 1/2 semantics via the real
                   :class:`repro.core.SpareState` / :class:`repro.core.Rectlr`
                   controller objects (plus the beyond-paper dynamic-ckpt
                   and straggler-masking options).
``adaptive``     — Chameleon-style policy selector: starts from the
                   closed-form-optimal policy for the configured MTBF and
                   re-evaluates against the *observed* failure rate at
                   every checkpoint / restart, switching policies at those
                   clean boundaries.

The legacy ``simulate_ckpt_only`` / ``simulate_replication`` /
``simulate_spare`` entry points are kept as thin deprecated aliases over
the registry; ``tests/test_scheme_api.py`` proves each ported scheme
reproduces the frozen pre-refactor loops (:mod:`repro.des._legacy`)
bit-for-bit at fixed seeds.
"""
from __future__ import annotations

import warnings

import numpy as np

from ..core.rectlr import Rectlr, RectlrOutcome
from ..core.state import SpareState
from ..core.theory import availability_star, mu as mu_theory, s_bar, tc_star
from .engine import (FailureRecovery, FaultToleranceScheme, SimClock,
                     SimResult, run_scheme)
from .params import DESParams

__all__ = [
    "SimResult",
    "CkptOnlyScheme", "ReplicationScheme", "SpareScheme", "AdaptiveScheme",
    "register_scheme", "get_scheme", "list_schemes",
    "simulate_ckpt_only", "simulate_replication", "simulate_spare",
]


# ------------------------------------------------------------------ #
# registry                                                           #
# ------------------------------------------------------------------ #
_REGISTRY: dict[str, type[FaultToleranceScheme]] = {}


def register_scheme(cls: type[FaultToleranceScheme]):
    """Class decorator: register ``cls`` under ``cls.name``."""
    if not cls.name or cls.name == "base":
        raise ValueError(f"{cls.__name__} must set a unique `name`")
    _REGISTRY[cls.name] = cls
    return cls


def get_scheme(name: str, **kwargs) -> FaultToleranceScheme:
    """Instantiate a registered scheme: ``get_scheme("spare", r=9)``."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scheme {name!r}; registered: {list_schemes()}"
        ) from None
    return cls(**kwargs)


def list_schemes() -> list[str]:
    return sorted(_REGISTRY)


def _overhead(stacks: float, t_f: float, p: DESParams) -> float:
    """Time-accurate normalized ttt: step-cost ratio over availability."""
    a = availability_star(t_f, p.t_save, p.t_restart)
    return ((stacks * p.t_comp + p.t_allreduce)
            / (p.t_comp + p.t_allreduce)) / a


# ------------------------------------------------------------------ #
# Scheme 1: CKPT-only (vanilla DP + checkpointing)                    #
# ------------------------------------------------------------------ #
@register_scheme
class CkptOnlyScheme(FaultToleranceScheme):
    """Vanilla synchronous DP: *any* node failure is a system failure
    (all N partial gradients required), so every failure costs a global
    restart plus rework. In the restart-dominant regime this barely makes
    progress (paper Sec. 5.2.1)."""

    name = "ckpt_only"
    late_detection = True
    failed_allreduce_in_work = False

    def default_t_c(self, p: DESParams) -> float:
        return tc_star(p.mtbf, p.t_save, p.t_restart)

    def on_step_start(self, sim: SimClock) -> tuple[float, float]:
        return sim.p.t_comp, 1.0

    def on_failure(self, sim: SimClock, failed: list[int],
                   work: float) -> FailureRecovery:
        return FailureRecovery(wipeout=True)

    def predicted_overhead(self, p: DESParams | None = None,
                           mtbf: float | None = None) -> float:
        p = p if p is not None else self.p
        m = mtbf if mtbf is not None else p.mtbf
        return _overhead(1.0, m, p)

    def recover(self, state: SpareState, failed: list[int],
                step: int | None = None) -> RectlrOutcome:
        """Vanilla DP cannot mask anything: every failure is a wipe-out."""
        return RectlrOutcome(wipeout=True, reordered=False,
                             s_a_before=state.s_a, s_a_after=state.s_a)


# ------------------------------------------------------------------ #
# Scheme 2: Rep+CKPT (traditional replication, degree r)              #
# ------------------------------------------------------------------ #
@register_scheme
class ReplicationScheme(FaultToleranceScheme):
    """Traditional replication (Fig. 2): group ``w`` hosts the ``r``
    consecutive types ``{w .. w+r-1 mod N}`` and computes *all* of them
    every step (r x workload). Failures are masked while every type keeps
    >= 1 surviving host; wipe-out forces the global restart."""

    name = "replication"
    late_detection = False          # masked failures surface next step
    failed_allreduce_in_work = False

    def __init__(self, r: int):
        self.r = r
        self.ctl = Rectlr()        # trainer-facing recovery bookkeeping

    def bind(self, p: DESParams, sim: SimClock,
             t_c: float | None = None) -> None:
        super().bind(p, sim, t_c=t_c)
        self._host_alive = np.full(p.n, self.r, dtype=np.int64)

    def default_t_c(self, p: DESParams) -> float:
        t_f = mu_theory(p.n, self.r) * p.mtbf
        return tc_star(t_f, p.t_save, p.t_restart)

    def on_step_start(self, sim: SimClock) -> tuple[float, float]:
        return self.r * sim.p.t_comp, float(self.r)

    def _apply_failures(self, n: int, groups: list[int]) -> bool:
        """Returns True on wipe-out."""
        for w in groups:
            types_of_w = (w + np.arange(self.r)) % n
            self._host_alive[types_of_w] -= 1
        return bool((self._host_alive == 0).any())

    def on_failure(self, sim: SimClock, failed: list[int],
                   work: float) -> FailureRecovery:
        if self._apply_failures(sim.p.n, failed):
            return FailureRecovery(wipeout=True)
        sim.advance(sim.p.t_shrink)
        # surviving copies already computed: redo all-reduce only
        work += sim.advance(sim.p.t_allreduce)
        return FailureRecovery(wipeout=False, work=work)

    def on_wipeout(self, sim: SimClock) -> None:
        self._host_alive[:] = self.r

    def predicted_overhead(self, p: DESParams | None = None,
                           mtbf: float | None = None) -> float:
        p = p if p is not None else self.p
        m = mtbf if mtbf is not None else p.mtbf
        return _overhead(float(self.r), mu_theory(p.n, self.r) * m, p)

    def recover(self, state: SpareState, failed: list[int],
                step: int | None = None) -> RectlrOutcome:
        """Live recovery on a trainer's :class:`SpareState`: replication
        masks by redundancy alone, so the shared reordering controller is
        used only for supplier bookkeeping (it reports wipe-out exactly
        when some shard type lost every host)."""
        return self.ctl.on_failures(state, failed)


# ------------------------------------------------------------------ #
# Scheme 3: SPARe+CKPT (Alg. 1 exact semantics)                        #
# ------------------------------------------------------------------ #
@register_scheme
class SpareScheme(FaultToleranceScheme):
    """SPARe+CKPT with the *actual* protocol implementation: the DES calls
    the same :class:`SpareState`/:class:`Rectlr` objects the trainer uses,
    so simulated availability reflects the real controller decisions
    (all-reduce stack evolution, reordering, patch computes, wipe-outs).

    ``dynamic_ckpt`` enables the beyond-paper Weibull-aware checkpoint
    interval (Sec. 5.2.2 of the paper suggests it closes the low-r gap):
    with shape k < 1 the hazard rate is highest right after a failure, so
    the policy shortens the interval while failures are recent and relaxes
    back to T_c* as the system stays quiet.

    ``straggler_frac`` > 0 enables the beyond-paper straggler model: each
    step, that fraction of groups runs ``straggler_slowdown``x slow.
    Vanilla DP (and replication) wait for the slowest group; SPARe's
    early-all-reduce trigger fires as soon as every shard *type* is
    collectible from the fast groups' stacks — when redundancy covers a
    straggler's types elsewhere, its compute is off the critical path.
    """

    name = "spare"
    late_detection = True
    failed_allreduce_in_work = True

    def __init__(self, r: int, binary_search: bool = False,
                 dynamic_ckpt: bool = False, straggler_frac: float = 0.0,
                 straggler_slowdown: float = 3.0):
        self.r = r
        self.binary_search = binary_search
        self.dynamic_ckpt = dynamic_ckpt
        self.straggler_frac = straggler_frac
        self.straggler_slowdown = straggler_slowdown
        self.ctl = Rectlr(binary_search=binary_search)
        self._controller_seconds = 0.0

    def bind(self, p: DESParams, sim: SimClock,
             t_c: float | None = None) -> None:
        super().bind(p, sim, t_c=t_c)
        self._state = SpareState(p.n, self.r)
        self._last_failure_wall = -p.mtbf
        self._controller_seconds = 0.0

    def default_t_c(self, p: DESParams) -> float:
        t_f = mu_theory(p.n, self.r) * p.mtbf
        return tc_star(t_f, p.t_save, p.t_restart)

    def checkpoint_interval(self, sim: SimClock) -> float:
        if not self.dynamic_ckpt:
            return self._t_c
        # hazard-adapted interval: fresh failures (age << MTBF) => shorter
        p = sim.p
        age = max(sim.now - self._last_failure_wall, 1.0)
        k = p.weibull_shape
        scale = min((age / p.mtbf) ** (1.0 - k), 1.5)
        return max(2.0 * p.t_save, self._t_c * scale)

    def on_step_start(self, sim: SimClock) -> tuple[float, float]:
        p = sim.p
        state = self._state
        s_a = state.s_a
        if self.straggler_frac > 0.0:
            # which alive groups are slow this step?
            alive_groups = state.survivors
            slow = sim.rng.random(alive_groups.size) < self.straggler_frac
            fast = alive_groups[~slow]
            # fast groups' committed prefixes cover the stragglers' types?
            covered = np.zeros(state.n, dtype=bool)
            covered[state.stacks[fast, :s_a].ravel()] = True
            if covered.all():
                step_comp = s_a * p.t_comp          # stragglers irrelevant
            else:
                # SPARe masking: fast hosts supply the missing types by
                # computing extra stacks (the patch-compute path) — the
                # step costs the minimal covering depth d <= r, or waiting
                # for the stragglers, whichever is cheaper
                wait = self.straggler_slowdown * s_a
                best = wait
                for d in range(s_a + 1, state.r + 1):
                    if d >= wait:
                        break
                    cov = np.zeros(state.n, dtype=bool)
                    cov[state.stacks[fast, :d].ravel()] = True
                    if cov.all():
                        best = float(d)
                        break
                step_comp = best * p.t_comp
        else:
            step_comp = s_a * p.t_comp
        return step_comp, float(s_a)

    def on_failure(self, sim: SimClock, failed: list[int],
                   work: float) -> FailureRecovery:
        p = sim.p
        self._last_failure_wall = sim.now
        outcome = self.ctl.on_failures(self._state, failed)
        self._controller_seconds += outcome.controller_seconds
        sim.advance(p.t_controller)
        if outcome.wipeout:
            return FailureRecovery(wipeout=True)
        # patch computes run in parallel across groups: time = max per-group
        patch_stacks = 0
        if outcome.patch:
            loads: dict[int, int] = {}
            for w, _ in outcome.patch:
                loads[w] = loads.get(w, 0) + 1
            patch_stacks = max(loads.values())
            work += sim.advance(patch_stacks * p.t_comp)
            sim.patches += len(outcome.patch)
        sim.advance(p.t_shrink)
        work += sim.advance(p.t_allreduce)          # redo the all-reduce
        # wall-time-equivalent extra stacks: the critical-path patch depth
        # (S_A itself was already accounted at step start — together this
        # is exactly the c(k)+rho_k quantity of Thm. 4.2, measured)
        return FailureRecovery(wipeout=False, work=work,
                               extra_stacks=float(patch_stacks))

    def on_wipeout(self, sim: SimClock) -> None:
        self._state.reset()

    @property
    def controller_seconds(self) -> float:
        return self._controller_seconds

    def predicted_overhead(self, p: DESParams | None = None,
                           mtbf: float | None = None) -> float:
        p = p if p is not None else self.p
        m = mtbf if mtbf is not None else p.mtbf
        return _overhead(s_bar(p.n, self.r), mu_theory(p.n, self.r) * m, p)

    def recover(self, state: SpareState, failed: list[int],
                step: int | None = None) -> RectlrOutcome:
        """Live recovery decision (Alg. 2): shared verbatim between the
        DES above and :class:`repro.train.trainer.SpareTrainer`."""
        outcome = self.ctl.on_failures(state, failed)
        self._controller_seconds += outcome.controller_seconds
        return outcome


# ------------------------------------------------------------------ #
# Scheme 4: adaptive policy selector (beyond-paper, Chameleon-style)  #
# ------------------------------------------------------------------ #
@register_scheme
class AdaptiveScheme(FaultToleranceScheme):
    """Real-time policy selection between ckpt-only / replication / SPARe.

    The selector keeps a smoothed estimate of the system MTBF,

        m_hat = (t_elapsed + w * m_prior) / (n_failures + w),

    and at every clean boundary — a committed checkpoint, or the global
    restart after a wipe-out — re-evaluates each candidate's closed-form
    ``predicted_overhead`` (Sec. 4 theory, :mod:`repro.core.theory`) at
    ``m_hat`` and switches to the argmin.  Switching at a checkpoint
    (only possible with no outstanding dead groups) charges ``t_reconfig``
    for the resharding; switching during a restart is free — the restart
    rebuilds every group anyway.

    With a quiet cluster the selector stays on cheap vanilla-DP
    checkpointing; as the observed failure rate approaches the
    restart-dominant regime it moves to SPARe, tracking the best fixed
    policy without knowing the failure rate in advance.
    """

    name = "adaptive"
    # detection/work attributes delegate to the active mode (see below)

    def __init__(self, r: int, r_rep: int = 2, initial: str | None = None,
                 prior_weight: float = 1.0, **spare_kwargs):
        self.r = r
        self.r_rep = r_rep
        self.initial = initial
        self.prior_weight = prior_weight
        self._modes: dict[str, FaultToleranceScheme] = {
            "ckpt_only": CkptOnlyScheme(),
            "replication": ReplicationScheme(r=r_rep),
            "spare": SpareScheme(r=r, **spare_kwargs),
        }
        self._mode_name = initial or "spare"
        self._switches = 0
        self.history: list[tuple[float, str]] = []   # (wall, mode) log
        # live-trainer observation state (see prepare()/recover())
        self._live_failures = 0
        self._live_step0: int | None = None
        # per-event mask-vs-reshape-vs-restart estimates (live trainer)
        self.unmaskable_decisions: list[dict] = []

    # -------------------------------------------------------------- #
    @property
    def mode(self) -> FaultToleranceScheme:
        return self._modes[self._mode_name]

    @property
    def ctl(self) -> Rectlr:
        """Shared reordering controller (the SPARe candidate's)."""
        return self._modes["spare"].ctl

    @property
    def mode_name(self) -> str:
        return self._mode_name

    @property
    def late_detection(self) -> bool:  # type: ignore[override]
        return self.mode.late_detection

    @property
    def failed_allreduce_in_work(self) -> bool:  # type: ignore[override]
        return self.mode.failed_allreduce_in_work

    # -------------------------------------------------------------- #
    def bind(self, p: DESParams, sim: SimClock,
             t_c: float | None = None) -> None:
        self.p, self.sim = p, sim
        self._switches = 0
        for m in self._modes.values():
            m.bind(p, sim, t_c=t_c)
        if self.initial is None:
            self._mode_name = self._best_mode(p.mtbf)
        else:
            self._mode_name = self.initial
        self.history = [(0.0, self._mode_name)]

    def _mtbf_hat(self, sim: SimClock) -> float:
        w = self.prior_weight
        return (sim.now + w * sim.p.mtbf) / (sim.node_failures + w)

    def _best_mode(self, mtbf: float) -> str:
        scores = {name: m.predicted_overhead(self.p, mtbf=mtbf)
                  for name, m in self._modes.items()}
        return min(scores, key=scores.get)

    def _switch_to(self, name: str, sim: SimClock, free: bool) -> None:
        if name == self._mode_name:
            return
        # the target must start from consistent (fully-redundant) state
        self._modes[name].on_wipeout(sim)
        self._mode_name = name
        self._switches += 1
        self.history.append((sim.now, name))
        if not free:
            sim.advance(sim.p.t_reconfig)   # resharding / policy rollout

    # -------------------------------------------------------------- #
    # delegated lifecycle                                            #
    # -------------------------------------------------------------- #
    def checkpoint_interval(self, sim: SimClock) -> float:
        return self.mode.checkpoint_interval(sim)

    def on_step_start(self, sim: SimClock) -> tuple[float, float]:
        return self.mode.on_step_start(sim)

    def on_allreduce(self, sim: SimClock) -> bool:
        return self.mode.on_allreduce(sim)

    def on_failure(self, sim: SimClock, failed: list[int],
                   work: float) -> FailureRecovery:
        return self.mode.on_failure(sim, failed, work)

    def on_wipeout(self, sim: SimClock) -> None:
        self.mode.on_wipeout(sim)
        # the engine restarts next: every group comes back, so switching
        # here is free and always consistent
        self._switch_to(self._best_mode(self._mtbf_hat(sim)), sim, free=True)

    def on_checkpoint(self, sim: SimClock) -> None:
        if sim.dead:
            return      # mid-degradation: no clean reshard point
        self._switch_to(self._best_mode(self._mtbf_hat(sim)), sim, free=False)

    # -------------------------------------------------------------- #
    @property
    def result_r(self) -> int:
        return self.r

    @property
    def controller_seconds(self) -> float:
        return self._modes["spare"].controller_seconds

    @property
    def mode_switches(self) -> int:
        return self._switches

    def predicted_overhead(self, p: DESParams | None = None,
                           mtbf: float | None = None) -> float:
        p = p if p is not None else self.p
        return min(m.predicted_overhead(p, mtbf=mtbf)
                   for m in self._modes.values())

    # -------------------------------------------------------------- #
    # live-trainer protocol                                          #
    # -------------------------------------------------------------- #
    def prepare(self, p: DESParams) -> None:
        """Pick the initial policy for live training from the trainer's
        failure model (the Chameleon prior); observation state resets."""
        self.p = p
        self._live_failures = 0
        self._live_step0 = None
        self.unmaskable_decisions = []
        self.degraded_decisions = []
        if self.initial is None:
            self._mode_name = self._best_mode(p.mtbf)
        self.history = [(0.0, self._mode_name)]

    def recover(self, state: SpareState, failed: list[int],
                step: int | None = None) -> RectlrOutcome:
        """Delegate to the current mode; on a wipe-out (the trainer's
        global-restart boundary — every group comes back, so any policy
        is consistent) re-evaluate against the failure rate observed in
        *step* time, converted to wall time via the prepared step cost."""
        if self._live_step0 is None:
            self._live_step0 = step if step is not None else 0
        self._live_failures += len(failed)
        decision = self.mode.recover(state, failed, step=step)
        if decision.wipeout and step is not None and hasattr(self, "p"):
            p = self.p
            elapsed = (step - self._live_step0) * (p.t_comp + p.t_allreduce)
            w = self.prior_weight
            mtbf_hat = ((elapsed + w * p.mtbf)
                        / (self._live_failures + w))
            target = self._best_mode(mtbf_hat)
            if target != self._mode_name:
                self._mode_name = target
                self._switches += 1
                self.history.append((elapsed, target))
        return decision

    def decide_unmaskable(self, *, dp_full: int, dp_new: int,
                          remaining_steps: int, seconds_per_step: float,
                          rollback_steps: int = 0,
                          t_restart: float | None = None,
                          t_reshape: float | None = None, **_) -> str:
        """The live third-regime decision: an unmaskable failure set is
        past every mode's masking power, so the selector weighs the
        paper's closed-form TTT of degraded-continue at ``dp_new``
        against restart-and-rollback (:func:`repro.elastic.policy
        .ttt_estimates`). Outage defaults come from the prepared
        :class:`DESParams` (``t_restart``; ``t_reconfig`` as the
        resharding cost). Every estimate is logged in
        ``unmaskable_decisions`` for the campaign's policy audit."""
        from repro.elastic.policy import ttt_estimates
        p = getattr(self, "p", None)
        if t_restart is None:
            t_restart = p.t_restart if p is not None else 3600.0
        if t_reshape is None:
            t_reshape = p.t_reconfig if p is not None else 1.0
        est = ttt_estimates(
            dp_full=dp_full, dp_new=dp_new,
            remaining_steps=remaining_steps,
            seconds_per_step=seconds_per_step,
            rollback_steps=rollback_steps,
            t_restart=t_restart, t_reshape=t_reshape)
        self.unmaskable_decisions.append(est)
        return est["action"]

    def decide_degraded(self, *, factors, candidates, remaining_steps: int,
                        seconds_per_step: float, dp_full: int,
                        dp_new: int = 0, maskable: bool = True,
                        alive=None, demoted=(), rollback_steps: int = 0,
                        t_restart: float | None = None,
                        t_reshape: float | None = None,
                        t_demote: float = 0.0, **_) -> str:
        """The gray-failure decision: the detector flagged
        ``candidates`` as stragglers (per-group slowdown ``factors``),
        and the selector weighs tolerate vs SPARe demotion vs elastic
        reshape vs restart with the closed-form degraded-throughput
        model (:func:`repro.health.policy.degraded_ttt_estimates` —
        step time = max factor over groups still in the barrier).
        ``maskable=False`` means RECTLR cannot re-cover the candidate
        set, ruling demotion out. Outage defaults come from the
        prepared :class:`DESParams` as in :meth:`decide_unmaskable`;
        every estimate lands in ``degraded_decisions``."""
        from repro.health.policy import degraded_ttt_estimates
        p = getattr(self, "p", None)
        if t_restart is None:
            t_restart = p.t_restart if p is not None else 3600.0
        if t_reshape is None:
            t_reshape = p.t_reconfig if p is not None else 1.0
        est = degraded_ttt_estimates(
            factors=factors, candidates=candidates,
            remaining_steps=remaining_steps,
            seconds_per_step=seconds_per_step,
            dp_full=dp_full, dp_new=dp_new, maskable=maskable,
            alive=alive, demoted=demoted, rollback_steps=rollback_steps,
            t_restart=t_restart, t_reshape=t_reshape, t_demote=t_demote)
        if not hasattr(self, "degraded_decisions"):
            self.degraded_decisions = []
        self.degraded_decisions.append(est)
        return est["action"]


# ------------------------------------------------------------------ #
# deprecated aliases (pre-registry entry points)                      #
# ------------------------------------------------------------------ #
def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"repro.des.{old} is deprecated; use repro.des.get_scheme({new})"
        f".simulate(p, ...) instead",
        DeprecationWarning, stacklevel=3)


def simulate_ckpt_only(p: DESParams, seed: int = 0,
                       t_c: float | None = None,
                       max_wall: float | None = None) -> SimResult:
    """Deprecated alias for ``get_scheme("ckpt_only").simulate(...)``."""
    _deprecated("simulate_ckpt_only", '"ckpt_only"')
    return run_scheme(CkptOnlyScheme(), p, seed=seed, t_c=t_c,
                      max_wall=max_wall)


def simulate_replication(p: DESParams, r: int, seed: int = 0,
                         t_c: float | None = None,
                         max_wall: float | None = None) -> SimResult:
    """Deprecated alias for ``get_scheme("replication", r=r).simulate(...)``."""
    _deprecated("simulate_replication", '"replication", r=r')
    return run_scheme(ReplicationScheme(r=r), p, seed=seed, t_c=t_c,
                      max_wall=max_wall)


def simulate_spare(p: DESParams, r: int, seed: int = 0,
                   t_c: float | None = None,
                   max_wall: float | None = None,
                   binary_search: bool = False,
                   dynamic_ckpt: bool = False,
                   straggler_frac: float = 0.0,
                   straggler_slowdown: float = 3.0) -> SimResult:
    """Deprecated alias for ``get_scheme("spare", r=r, ...).simulate(...)``."""
    _deprecated("simulate_spare", '"spare", r=r')
    scheme = SpareScheme(r=r, binary_search=binary_search,
                         dynamic_ckpt=dynamic_ckpt,
                         straggler_frac=straggler_frac,
                         straggler_slowdown=straggler_slowdown)
    return run_scheme(scheme, p, seed=seed, t_c=t_c, max_wall=max_wall)
