"""Frozen pre-refactor scheme loops — the bit-for-bit parity reference.

These are the original hand-rolled ``simulate_*`` implementations exactly
as they existed before the :class:`repro.des.engine.FaultToleranceScheme`
redesign. They exist ONLY so ``tests/test_scheme_api.py`` can assert that
the ported schemes on the shared engine reproduce the legacy trajectories
bit-for-bit at fixed seeds (same RNG-draw order => identical walls,
committed work, and event counts).

Do not add features here; the public API is :func:`repro.des.get_scheme`.
"""
from __future__ import annotations

from ..core.rectlr import Rectlr
from ..core.state import SpareState
from ..core.theory import mu as mu_theory
from ..core.theory import tc_star
from .engine import SimClock as _Sim
from .engine import SimResult, build_result as _result
from .params import DESParams

import numpy as np

__all__ = ["legacy_ckpt_only", "legacy_replication", "legacy_spare"]


# ------------------------------------------------------------------ #
# Scheme 1: CKPT-only (vanilla DP + checkpointing)                    #
# ------------------------------------------------------------------ #
def legacy_ckpt_only(p: DESParams, seed: int = 0,
                     t_c: float | None = None,
                     max_wall: float | None = None) -> SimResult:
    sim = _Sim(p, seed)
    t_c = t_c if t_c is not None else tc_star(p.mtbf, p.t_save, p.t_restart)
    max_wall = max_wall if max_wall is not None else 500.0 * p.t0

    step = 0
    ckpt_step = 0
    last_ckpt_wall = 0.0
    while step < p.steps and sim.now < max_wall:
        if sim.now - last_ckpt_wall >= t_c and step > ckpt_step:
            sim.checkpoint()
            ckpt_step = step
            last_ckpt_wall = sim.now
        work = sim.advance(p.t_comp)                # one stack
        if sim.pending:                             # detected at all-reduce
            sim.advance(p.t_allreduce * p.failed_allreduce_frac)
            step = ckpt_step                        # rework to last ckpt
            sim.restart()
            last_ckpt_wall = sim.now
            continue
        work += sim.advance(p.t_allreduce)
        if sim.pending:
            # failure landed inside the all-reduce window: treat as failed
            step = ckpt_step
            sim.restart()
            last_ckpt_wall = sim.now
            continue
        step += 1
        sim.work_since_ckpt += work
        sim.stacks_since_ckpt += 1.0
    sim.finish()
    return _result(sim, "ckpt_only", r=1, steps_done=step)


# ------------------------------------------------------------------ #
# Scheme 2: Rep+CKPT (traditional replication, degree r)              #
# ------------------------------------------------------------------ #
def legacy_replication(p: DESParams, r: int, seed: int = 0,
                       t_c: float | None = None,
                       max_wall: float | None = None) -> SimResult:
    sim = _Sim(p, seed)
    n = p.n
    t_f = mu_theory(n, r) * p.mtbf
    t_c = t_c if t_c is not None else tc_star(t_f, p.t_save, p.t_restart)
    max_wall = max_wall if max_wall is not None else 500.0 * p.t0

    # hosts[i] = {i-r+1 .. i} mod N  (consecutive-window replication)
    hosts = (np.arange(n)[:, None] - np.arange(r)[None, :]) % n
    host_alive = np.full(n, r, dtype=np.int64)

    def apply_failures(groups: list[int]) -> bool:
        """Returns True on wipe-out."""
        for w in groups:
            types_of_w = (w + np.arange(r)) % n
            host_alive[types_of_w] -= 1
        return bool((host_alive == 0).any())

    step = 0
    ckpt_step = 0
    last_ckpt_wall = 0.0
    while step < p.steps and sim.now < max_wall:
        if sim.now - last_ckpt_wall >= t_c and step > ckpt_step:
            sim.checkpoint()
            ckpt_step = step
            last_ckpt_wall = sim.now
        work = sim.advance(r * p.t_comp)            # all r stacks, always
        if sim.pending:
            sim.advance(p.t_allreduce * p.failed_allreduce_frac)
            failed = sim.pending[:]
            sim.pending.clear()
            if apply_failures(failed):
                step = ckpt_step
                host_alive[:] = r
                sim.restart()
                last_ckpt_wall = sim.now
                continue
            sim.advance(p.t_shrink)
            # surviving copies already computed: redo all-reduce only
            work += sim.advance(p.t_allreduce)
            step += 1
            sim.work_since_ckpt += work
            sim.stacks_since_ckpt += r
            continue
        work += sim.advance(p.t_allreduce)
        step += 1
        sim.work_since_ckpt += work
        sim.stacks_since_ckpt += r
    sim.finish()
    return _result(sim, "replication", r=r, steps_done=step)


# ------------------------------------------------------------------ #
# Scheme 3: SPARe+CKPT (Alg. 1 exact semantics)                        #
# ------------------------------------------------------------------ #
def legacy_spare(p: DESParams, r: int, seed: int = 0,
                 t_c: float | None = None,
                 max_wall: float | None = None,
                 binary_search: bool = False,
                 dynamic_ckpt: bool = False,
                 straggler_frac: float = 0.0,
                 straggler_slowdown: float = 3.0) -> SimResult:
    sim = _Sim(p, seed)
    n = p.n
    t_f = mu_theory(n, r) * p.mtbf
    t_c_base = t_c if t_c is not None else tc_star(t_f, p.t_save, p.t_restart)
    max_wall = max_wall if max_wall is not None else 500.0 * p.t0

    state = SpareState(n, r)
    ctl = Rectlr(binary_search=binary_search)

    step = 0
    ckpt_step = 0
    last_ckpt_wall = 0.0
    last_failure_wall = -p.mtbf
    controller_seconds = 0.0

    def current_t_c() -> float:
        if not dynamic_ckpt:
            return t_c_base
        # hazard-adapted interval: fresh failures (age << MTBF) => shorter
        age = max(sim.now - last_failure_wall, 1.0)
        k = p.weibull_shape
        scale = min((age / p.mtbf) ** (1.0 - k), 1.5)
        return max(2.0 * p.t_save, t_c_base * scale)

    while step < p.steps and sim.now < max_wall:
        if sim.now - last_ckpt_wall >= current_t_c() and step > ckpt_step:
            sim.checkpoint()
            ckpt_step = step
            last_ckpt_wall = sim.now
        s_a = state.s_a
        if straggler_frac > 0.0:
            # which alive groups are slow this step?
            alive_groups = state.survivors
            slow = sim.rng.random(alive_groups.size) < straggler_frac
            fast = alive_groups[~slow]
            # fast groups' committed prefixes cover the stragglers' types?
            covered = np.zeros(state.n, dtype=bool)
            covered[state.stacks[fast, :s_a].ravel()] = True
            if covered.all():
                step_comp = s_a * p.t_comp          # stragglers irrelevant
            else:
                wait = straggler_slowdown * s_a
                best = wait
                for d in range(s_a + 1, state.r + 1):
                    if d >= wait:
                        break
                    cov = np.zeros(state.n, dtype=bool)
                    cov[state.stacks[fast, :d].ravel()] = True
                    if cov.all():
                        best = float(d)
                        break
                step_comp = best * p.t_comp
        else:
            step_comp = s_a * p.t_comp
        work = sim.advance(step_comp)               # compute S_A stacks
        if not sim.pending:
            work += sim.advance(p.t_allreduce)
            if sim.pending:
                # failure landed inside the all-reduce: it fails late;
                # charge the failed fraction and fall through to recovery
                work -= p.t_allreduce * (1.0 - p.failed_allreduce_frac)
            else:
                step += 1
                sim.work_since_ckpt += work
                sim.stacks_since_ckpt += s_a
                continue
        else:
            work += sim.advance(p.t_allreduce * p.failed_allreduce_frac)

        # ---- recovery path ----
        failed = sim.pending[:]
        sim.pending.clear()
        last_failure_wall = sim.now
        outcome = ctl.on_failures(state, failed)
        controller_seconds += outcome.controller_seconds
        sim.advance(p.t_controller)
        if outcome.wipeout:
            state.reset()
            step = ckpt_step
            sim.restart()
            last_ckpt_wall = sim.now
            continue
        # patch computes run in parallel across groups: time = max per-group
        patch_stacks = 0
        if outcome.patch:
            loads: dict[int, int] = {}
            for w, _ in outcome.patch:
                loads[w] = loads.get(w, 0) + 1
            patch_stacks = max(loads.values())
            work += sim.advance(patch_stacks * p.t_comp)
            sim.patches += len(outcome.patch)
        sim.advance(p.t_shrink)
        work += sim.advance(p.t_allreduce)          # redo the all-reduce
        step += 1
        sim.work_since_ckpt += work
        sim.stacks_since_ckpt += s_a + patch_stacks
        continue
    sim.finish()
    res = _result(sim, "spare", r=r, steps_done=step,
                  controller_seconds=controller_seconds)
    return res
