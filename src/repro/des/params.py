"""DES system parameters (paper Table 1 — 600k H100 cluster)."""
from __future__ import annotations

from dataclasses import dataclass, replace

__all__ = ["DESParams"]

# Paper Table 1: T_a = 2, 6, 10 s at N = 200, 600, 1000 (ring all-reduce,
# linear in N for the 20 TB gradient at ~400 Gb/s per-GPU goodput).
_ALLREDUCE_BY_N = {200: 2.0, 600: 6.0, 1000: 10.0}


@dataclass(frozen=True)
class DESParams:
    """Table 1 defaults. All times in seconds."""

    n: int = 600                    # data-parallel degree (DP groups)
    mtbf: float = 300.0             # system MTBF on node failures
    weibull_shape: float = 0.78     # Schroeder & Gibson seminal shape k
    t_restart: float = 3600.0       # T_r — global restart latency
    t_comp: float = 64.0            # compute time per stack (256M tok, 4 acc)
    t_save: float = 60.0            # T_s — checkpoint save
    t_shrink: float = 0.1           # communicator shrink
    t_controller: float = 0.1       # RECTLR cost (conservative; measured <10ms)
    t_reconfig: float = 1.0         # adaptive policy-switch reshard cost
    steps: int = 10_000             # training horizon
    failed_allreduce_frac: float = 0.5   # failed all-reduce costs 0.5 * T_a
    jitter_std: float = 0.05        # event jitter ~ N(1, 0.05^2)
    scale_rate_with_survivors: bool = True  # failure rate ∝ #active GPUs
    failure_law: str = "weibull"    # "weibull" | "exponential"

    @property
    def t_allreduce(self) -> float:
        """T_a — gradient all-reduce time at this N (ring, linear in N)."""
        if self.n in _ALLREDUCE_BY_N:
            return _ALLREDUCE_BY_N[self.n]
        return 10.0 * self.n / 1000.0  # linear extrapolation of Table 1

    @property
    def t0(self) -> float:
        """No-failure baseline time-to-train: steps x (T_comp + T_a)."""
        return self.steps * (self.t_comp + self.t_allreduce)

    def with_(self, **kw) -> "DESParams":
        return replace(self, **kw)
