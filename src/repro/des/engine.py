"""Shared bulk-synchronous DES engine for fault-tolerance schemes.

Every scheme in the paper's comparison (App. E flowchart, Sec. 5.2) runs
the same outer timeline::

    [maybe checkpoint] -> compute phase -> all-reduce attempt
        |- no failure detected: commit step
        |- failure(s): failed all-reduce (0.5 T_a) -> scheme-specific recovery

What differs between CKPT-only, Rep+CKPT, SPARe+CKPT (and any future
policy) is *only* the per-step compute load, the failure-detection timing,
and the recovery protocol. This module factors the shared skeleton into
:func:`run_scheme` driving a :class:`FaultToleranceScheme` through its
lifecycle hooks:

``on_step_start``
    called once per step, before the compute phase; returns the compute
    duration (seconds) and the number of stacks the step will commit.
``on_allreduce``
    called when failures land *inside* an otherwise-successful all-reduce
    window; returns whether the scheme detects them now (failing the
    all-reduce late) or defers detection to the next step's attempt.
``on_failure``
    the recovery protocol: the scheme performs its recovery advances on
    the clock (controller, patch computes, shrink, redo-all-reduce) and
    reports wipe-out vs. masked, plus any extra committed work/stacks.
``on_wipeout``
    reset scheme-private state right before the engine's global restart.
``on_checkpoint``
    called after each checkpoint save commits (the natural point for
    adaptive policies to re-evaluate, since a checkpoint is the only
    clean switch boundary — committed work can never be rolled past it).

Accounting (identical to the original three hand-rolled loops):

* ``wall``       — total simulated wall-clock = time-to-train;
* ``committed``  — work time of steps that survived to the end (compute
  including redundant stacks and patches + successful all-reduces).
  Checkpoint saves, failed all-reduces, shrink/controller time, global
  restarts, and rolled-back (reworked) steps are downtime/waste.
  ``availability = committed / wall`` — matching Eq. 2's semantics, where
  J(r) = ttt/T0 = S_bar / A.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .params import DESParams

__all__ = ["SimResult", "SimClock", "FailureRecovery", "FaultToleranceScheme",
           "run_scheme"]


@dataclass
class SimResult:
    scheme: str
    n: int
    r: int
    wall: float
    committed: float
    t0: float
    steps_done: int
    node_failures: int
    wipeouts: int
    ckpt_count: int
    total_stacks: float      # stacks computed across committed steps
    patches: int
    controller_seconds: float = 0.0
    mode_switches: int = 0   # adaptive-policy mode changes (0 for fixed)

    @property
    def ttt_norm(self) -> float:
        return self.wall / self.t0

    @property
    def availability(self) -> float:
        return self.committed / self.wall if self.wall > 0 else 1.0

    @property
    def avg_stacks(self) -> float:
        return self.total_stacks / max(self.steps_done, 1)


class SimClock:
    """Shared clock / failure-stream / accounting plumbing.

    Failure arrivals and victim selection are delegated to a pluggable
    :class:`repro.scenarios.models.FailureModel`; the default
    ``RenewalModel`` draws *exactly* the sequence the pre-scenario clock
    drew (one interval via ``FailureProcess``, one uniform victim), so
    the legacy parity tests stay bit-for-bit. Non-default models may
    kill several groups per event (rack/pod bursts, trace replay) —
    every victim lands in ``pending`` and the scheme's ``on_failure``
    sees the whole simultaneous-failure set.
    """

    def __init__(self, p: DESParams, seed: int, failure_model=None,
                 topology=None):
        # local import to avoid the des <-> scenarios cycle; keep the
        # window drain as an attribute so advance() pays no per-call
        # import-machinery cost in the hot loop
        from ..scenarios.models import RenewalModel, drain_event_window
        self._drain = drain_event_window
        self.p = p
        self.rng = np.random.default_rng(seed)
        self.topology = topology
        self.model = failure_model if failure_model is not None \
            else RenewalModel()
        self.model.bind(p, self.rng, topology)
        self.proc = getattr(self.model, "proc", None)  # legacy attribute
        self.now = 0.0
        self.alive = p.n
        self.next_fail = self.model.next_arrival(0.0, self.alive, p.n)
        self.pending: list[int] = []        # failed groups awaiting detection
        self.dead: set[int] = set()
        # accounting
        self.committed = 0.0
        self.work_since_ckpt = 0.0
        self.node_failures = 0
        self.wipeouts = 0
        self.ckpt_count = 0
        self.total_stacks = 0.0
        self.patches = 0
        self.stacks_since_ckpt = 0.0
        self.total_stacks_committed = 0.0

    # -------------------------------------------------------------- #
    def jitter(self) -> float:
        return max(0.0, float(self.rng.normal(1.0, self.p.jitter_std)))

    def advance(self, duration: float) -> float:
        """Advance the clock by a jittered duration; harvest failure
        arrivals that land inside the window into ``pending`` (via the
        victim-batching loop shared with the live trainer bridge)."""
        dur = duration * self.jitter()
        end = self.now + dur
        events, self.next_fail, self.alive = self._drain(
            self.model, self.next_fail, end, self.dead, self.alive, self.p.n)
        for _, victims in events:
            self.pending.extend(victims)
            self.node_failures += len(victims)
        self.now = end
        return dur

    def restart(self) -> None:
        """Global restart: T_r downtime, full capacity restored, progress
        rolls back to the last checkpoint (handled by caller), pending
        failure queue cleared, arrival process re-armed."""
        self.now += self.p.t_restart * self.jitter()
        self.dead.clear()
        self.pending.clear()
        self.alive = self.p.n
        self.wipeouts += 1
        self.work_since_ckpt = 0.0
        self.stacks_since_ckpt = 0.0
        self.next_fail = self.model.reset(self.now, self.alive, self.p.n)

    def checkpoint(self) -> None:
        self.advance(self.p.t_save)
        self.committed += self.work_since_ckpt
        self.total_stacks_committed += self.stacks_since_ckpt
        self.work_since_ckpt = 0.0
        self.stacks_since_ckpt = 0.0
        self.ckpt_count += 1

    def finish(self) -> None:
        self.committed += self.work_since_ckpt
        self.total_stacks_committed += self.stacks_since_ckpt


def build_result(sim: SimClock, scheme: str, r: int, steps_done: int,
                 controller_seconds: float = 0.0,
                 mode_switches: int = 0) -> SimResult:
    p = sim.p
    return SimResult(
        scheme=scheme, n=p.n, r=r,
        wall=sim.now, committed=sim.committed, t0=p.t0,
        steps_done=steps_done,
        node_failures=sim.node_failures, wipeouts=sim.wipeouts,
        ckpt_count=sim.ckpt_count,
        total_stacks=sim.total_stacks_committed,
        patches=sim.patches,
        controller_seconds=controller_seconds,
        mode_switches=mode_switches,
    )


@dataclass
class FailureRecovery:
    """What a scheme's :meth:`on_failure` decided.

    ``wipeout``      — the failure set exceeded the scheme's redundancy;
                       the engine rolls back to the last checkpoint and
                       performs the global restart.
    ``work``         — the step's updated committed-work total: the
                       ``work`` the hook received plus any recovery time
                       that counts as useful (redone all-reduce, patch
                       computes), accumulated *by the scheme* so the
                       float summation order matches the recovery's
                       advance order exactly. Ignored on wipe-out.
    ``extra_stacks`` — additional stacks committed by the recovery (e.g.
                       SPARe patch computes on the critical path).
    """

    wipeout: bool
    work: float = 0.0
    extra_stacks: float = 0.0


class FaultToleranceScheme:
    """Base class for pluggable fault-tolerance policies.

    A scheme instance is created via :func:`repro.des.get_scheme` (or
    directly), then either simulated with :meth:`simulate` / consumed by
    :class:`repro.train.trainer.SpareTrainer` for live recovery decisions
    via :meth:`recover`.

    Subclasses set :attr:`name`, implement the lifecycle hooks, and may
    carry per-run state (initialised in :meth:`bind`, which the engine
    calls once per simulation).
    """

    #: registry key / SimResult.scheme label
    name: str = "base"
    #: does a failure landing inside a successful all-reduce window fail
    #: that all-reduce (detected now), or surface at the next attempt?
    late_detection: bool = True
    #: does the failed all-reduce fraction count as committed work when
    #: the step ultimately survives?  (SPARe charges it — the partial
    #: all-reduce moved real gradient bytes; replication discards it.)
    failed_allreduce_in_work: bool = False

    # ---------------------------------------------------------------- #
    # lifecycle hooks (engine-facing)                                  #
    # ---------------------------------------------------------------- #
    def bind(self, p: DESParams, sim: SimClock,
             t_c: float | None = None) -> None:
        """Initialise per-run state. Called once before the event loop."""
        self.p = p
        self.sim = sim
        self._t_c = t_c if t_c is not None else self.default_t_c(p)

    def default_t_c(self, p: DESParams) -> float:
        """Scheme's optimal static checkpoint interval (Eq. 1)."""
        raise NotImplementedError

    def checkpoint_interval(self, sim: SimClock) -> float:
        """Current checkpoint interval (may adapt to observed hazard)."""
        return self._t_c

    def on_step_start(self, sim: SimClock) -> tuple[float, float]:
        """Return ``(compute_seconds, stacks)`` for the next step."""
        raise NotImplementedError

    def on_allreduce(self, sim: SimClock) -> bool:
        """Failures landed inside the successful all-reduce window; return
        True to fail the all-reduce now (late detection)."""
        return self.late_detection

    def on_failure(self, sim: SimClock, failed: list[int],
                   work: float) -> FailureRecovery:
        """Run the scheme's recovery protocol for ``failed`` groups.
        ``work`` is the step's committed-work total so far; return it
        (plus any recovery work) in :attr:`FailureRecovery.work`."""
        raise NotImplementedError

    def on_wipeout(self, sim: SimClock) -> None:
        """Reset scheme-private state; the engine restarts right after."""

    def on_checkpoint(self, sim: SimClock) -> None:
        """A checkpoint just committed (clean policy-switch boundary)."""

    # ---------------------------------------------------------------- #
    # results / introspection                                          #
    # ---------------------------------------------------------------- #
    @property
    def result_r(self) -> int:
        """Redundancy degree reported in :class:`SimResult`."""
        return getattr(self, "r", 1)

    @property
    def controller_seconds(self) -> float:
        return 0.0

    @property
    def mode_switches(self) -> int:
        return 0

    def predicted_overhead(self) -> float:
        """Closed-form normalized time-to-train J = ttt/T0 (Sec. 4 theory)."""
        raise NotImplementedError

    # ---------------------------------------------------------------- #
    # trainer-facing protocol                                          #
    # ---------------------------------------------------------------- #
    def prepare(self, p: DESParams) -> None:
        """Attach the live system's failure model (N, MTBF, T_s, T_r) for
        trainer use — the out-of-simulation counterpart of :meth:`bind`.
        Called once by :class:`SpareTrainer`; adaptive policies use it to
        pick their initial mode from the configured prior."""
        self.p = p

    def recover(self, state, failed: list[int], step: int | None = None):
        """Live recovery decision for :class:`SpareTrainer`: given the
        trainer's :class:`repro.core.SpareState` and newly failed groups,
        return a :class:`repro.core.rectlr.RectlrOutcome`-compatible
        object (``wipeout`` / ``reordered`` / ``patch`` / ...).
        ``step`` is the trainer's current step counter; adaptive policies
        use it to estimate the observed failure rate."""
        raise NotImplementedError

    # ---------------------------------------------------------------- #
    def simulate(self, p: DESParams, seed: int = 0,
                 t_c: float | None = None,
                 max_wall: float | None = None,
                 failure_model=None, topology=None) -> SimResult:
        """Run this scheme through the shared engine.

        ``failure_model`` / ``topology`` select the failure regime (see
        :mod:`repro.scenarios`); the default is the legacy single-victim
        renewal stream."""
        return run_scheme(self, p, seed=seed, t_c=t_c, max_wall=max_wall,
                          failure_model=failure_model, topology=topology)


def run_scheme(scheme: FaultToleranceScheme, p: DESParams, seed: int = 0,
               t_c: float | None = None,
               max_wall: float | None = None,
               failure_model=None, topology=None) -> SimResult:
    """The one bulk-synchronous event loop every scheme runs on.

    Event order (and therefore RNG-draw order) is identical to the three
    original hand-rolled loops — the parity tests in
    ``tests/test_scheme_api.py`` assert bit-for-bit equality against the
    frozen copies in :mod:`repro.des._legacy`.

    ``failure_model`` may inject multi-group simultaneous failures
    (rack/pod bursts, trace replay): all victims of one event surface in
    the same ``on_failure`` call, so wipe-out and stack accounting see
    the full blast radius at once.
    """
    sim = SimClock(p, seed, failure_model=failure_model, topology=topology)
    scheme.bind(p, sim, t_c=t_c)
    max_wall = max_wall if max_wall is not None else 500.0 * p.t0

    step = 0
    ckpt_step = 0
    last_ckpt_wall = 0.0
    while step < p.steps and sim.now < max_wall:
        if sim.now - last_ckpt_wall >= scheme.checkpoint_interval(sim) \
                and step > ckpt_step:
            sim.checkpoint()
            ckpt_step = step
            last_ckpt_wall = sim.now
            scheme.on_checkpoint(sim)
        compute_s, stacks = scheme.on_step_start(sim)
        work = sim.advance(compute_s)
        if not sim.pending:
            work += sim.advance(p.t_allreduce)
            if not sim.pending or not scheme.on_allreduce(sim):
                # committed step (failures inside the window, if any,
                # surface at the next step's attempt)
                step += 1
                sim.work_since_ckpt += work
                sim.stacks_since_ckpt += stacks
                continue
            # late detection: the all-reduce fails near its end — only
            # the failed fraction of it was useful motion
            work -= p.t_allreduce * (1.0 - p.failed_allreduce_frac)
        else:
            dur = sim.advance(p.t_allreduce * p.failed_allreduce_frac)
            if scheme.failed_allreduce_in_work:
                work += dur

        # ---- recovery path ----
        failed = sim.pending[:]
        sim.pending.clear()
        rec = scheme.on_failure(sim, failed, work)
        if rec.wipeout:
            scheme.on_wipeout(sim)
            step = ckpt_step                    # rework to last ckpt
            sim.restart()
            last_ckpt_wall = sim.now
            continue
        work = rec.work
        step += 1
        sim.work_since_ckpt += work
        sim.stacks_since_ckpt += stacks + rec.extra_stacks
    sim.finish()
    return build_result(sim, scheme.name, r=scheme.result_r, steps_done=step,
                        controller_seconds=scheme.controller_seconds,
                        mode_switches=scheme.mode_switches)
