"""Failure injection for the DES (paper Sec. 5.1).

Node failures arrive as a renewal process whose inter-arrival law is
Weibull with the seminal Schroeder-Gibson shape ``k = 0.78`` (or
exponential, for apples-to-apples checks against the Sec. 4 theory, which
assumes memorylessness). The *system* rate is calibrated so the mean
inter-failure time equals the configured MTBF when all groups are active.

Two empirical effects from the paper are modeled:

* **Rate ∝ active GPUs** (Schroeder & Gibson 2009; Kokolis et al. 2025):
  as groups die and are not replaced until the next global restart, the
  aggregate failure rate drops proportionally — this is exactly why the
  paper observes SPARe beating its own theory at high r (Sec. 5.2.2).
* **k < 1 burstiness**: with ``k = 0.78`` failures cluster; the renewal
  intervals are drawn i.i.d. but their coefficient of variation > 1, which
  is what degrades low-r SPARe below the exponential-based prediction.
"""
from __future__ import annotations

import math

import numpy as np

__all__ = ["FailureProcess"]


class FailureProcess:
    """Renewal failure stream with survivor-scaled rate.

    ``next_arrival(now, alive, n)`` returns the absolute time of the next
    node failure given the current clock and survivor count. The victim
    group is drawn uniformly among survivors by the caller (group-level
    abstraction: one node failure interrupts its whole model-parallel
    group).
    """

    def __init__(self, mtbf: float, shape: float, rng: np.random.Generator,
                 law: str = "weibull", scale_with_survivors: bool = True):
        if mtbf <= 0:
            raise ValueError("mtbf must be positive")
        self.mtbf = mtbf
        self.shape = shape
        self.rng = rng
        self.law = law
        self.scale_with_survivors = scale_with_survivors
        if law == "weibull":
            # numpy's weibull(k) has scale 1 => mean Gamma(1 + 1/k)
            self._norm = math.gamma(1.0 + 1.0 / shape)
        elif law == "exponential":
            self._norm = 1.0
        else:
            raise ValueError(f"unknown failure law {law!r}")

    def draw_interval(self, alive: int, n: int) -> float:
        """One inter-arrival sample at the current survivor count."""
        if self.law == "weibull":
            base = float(self.rng.weibull(self.shape)) / self._norm * self.mtbf
        else:
            base = float(self.rng.exponential(self.mtbf))
        if self.scale_with_survivors and alive < n:
            if alive <= 0:
                return math.inf
            base *= n / alive  # rate ∝ active GPUs => interval ∝ N / alive
        return base

    def next_arrival(self, now: float, alive: int, n: int) -> float:
        return now + self.draw_interval(alive, n)
