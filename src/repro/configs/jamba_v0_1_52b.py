"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba:attn 7:1 interleave (1 attention block per period of 8,
at offset 4), MoE 16 experts top-2 every other layer [arXiv:2403.19887; hf].

The Mamba sublayers use our SSD implementation at Jamba's d_state=16 —
Jamba ships Mamba-1 selective-scan; SSD is the successor formulation with
identical state size and interface (deviation recorded in DESIGN.md).
"""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    head_dim=128,
    hybrid_period=8,
    hybrid_attn_pos=4,
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, conv_width=4,
                  n_groups=1, chunk=256),
    moe=MoEConfig(
        n_experts=16,
        top_k=2,
        d_expert=14336,
        n_shared=0,
        layer_period=2,
    ),
)
