"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H (MLA kv_lora=512)
vocab=102400, MoE 64 routed + 2 shared, top-6, expert d_ff=1408
[arXiv:2405.04434; hf].

V2-Lite has no q compression (q_lora_rank=0); first layer is dense
(d_ff=10944).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,               # dense FFN of the first layer
    vocab=102400,
    attn_kind="mla",
    kv_lora_rank=512,
    q_lora_rank=0,
    mla_d_nope=128,
    mla_d_rope=64,
    mla_d_v=128,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_expert=1408,
        n_shared=2,
        first_k_dense=1,
    ),
)
