"""Assigned input shapes (one set shared by all 10 LM archs).

  train_4k     seq 4,096   global_batch 256   (training; lowers train_step)
  prefill_32k  seq 32,768  global_batch 32    (inference prefill)
  decode_32k   seq 32,768  global_batch 128   (decode: 1 token, 32k KV)
  long_500k    seq 524,288 global_batch 1     (long-context decode)

``decode_*``/``long_*`` lower ``serve_step`` (one new token against a
KV/SSM cache of ``seq``), NOT ``train_step``. long_500k requires
sub-quadratic attention: only the ssm/hybrid families run it; pure
full-attention archs record a documented skip (DESIGN.md §long-context).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "applicable", "cells"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k needs sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (O(seq) KV readback per "
            "decoded token at 524k context) — documented skip")
    return True, ""


def cells(cfg: ModelConfig) -> list[tuple[ShapeSpec, bool, str]]:
    """All four assigned cells for one arch with applicability verdicts."""
    return [(s, *applicable(cfg, s)) for s in SHAPES.values()]
