"""musicgen-medium [audio] — decoder-only LM over EnCodec tokens.

48L d_model=1536 24H (MHA: kv=24) d_ff=6144 vocab=2048 [arXiv:2306.05284; hf].
The EnCodec frontend is a stub: ``input_specs`` provides precomputed frame
embeddings (B, S, d_model); the backbone predicts the next audio token.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="dense",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    mlp_kind="gelu",
    vocab=2048,
    frontend="audio",
)
