"""Architecture registry: ``--arch <id>`` -> ModelConfig (+ smoke variants)."""
from __future__ import annotations

from dataclasses import replace

from repro.models.config import ModelConfig, MoEConfig, SSMConfig

from . import (
    deepseek_v2_lite_16b,
    deepseek_v3_671b,
    glm4_9b,
    jamba_v0_1_52b,
    mamba2_1_3b,
    minitron_4b,
    musicgen_medium,
    qwen2_5_3b,
    qwen2_vl_2b,
    starcoder2_7b,
)
from .shapes import SHAPES, ShapeSpec, applicable, cells

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        musicgen_medium, qwen2_vl_2b, deepseek_v3_671b, deepseek_v2_lite_16b,
        minitron_4b, starcoder2_7b, qwen2_5_3b, glm4_9b, mamba2_1_3b,
        jamba_v0_1_52b,
    )
}

__all__ = ["ARCHS", "get_config", "smoke_config", "SHAPES", "ShapeSpec",
           "applicable", "cells"]


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch]


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config: small width/depth, few experts, tiny
    vocab — one CPU train step must run in seconds (per-arch smoke tests).
    """
    cfg = get_config(arch)
    kw: dict = {
        "d_model": 64,
        "vocab": 512,
        "rope_theta": 1e4,
    }
    if cfg.family == "hybrid":
        kw["n_layers"] = cfg.hybrid_period          # one full period
    else:
        kw["n_layers"] = 2 if cfg.moe is None else max(2, (cfg.moe.first_k_dense > 0) + 2)
    if cfg.n_heads:
        kw["n_heads"] = 4
        kw["n_kv_heads"] = max(1, min(cfg.n_kv_heads, 2))
        kw["head_dim"] = 16
    if cfg.d_ff:
        kw["d_ff"] = 128
    if cfg.attn_kind == "mla":
        kw.update(kv_lora_rank=32, q_lora_rank=(32 if cfg.q_lora_rank else 0),
                  mla_d_nope=16, mla_d_rope=8, mla_d_v=16)
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            n_experts=8, top_k=2, d_expert=32,
            n_shared=min(cfg.moe.n_shared, 1),
            first_k_dense=min(cfg.moe.first_k_dense, 1),
            layer_period=cfg.moe.layer_period,
        )
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, head_dim=8, expand=2,
                              conv_width=4, n_groups=1, chunk=32)
    return replace(cfg, **kw)
