"""mamba2-1.3b [ssm] — 48L d_model=2048 attention-free vocab=50280 (tied
embeddings), SSD d_state=128 head_dim=64 expand=2 [arXiv:2405.21060]."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  n_groups=1, chunk=256),
)
