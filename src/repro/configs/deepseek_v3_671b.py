"""deepseek-v3-671b [moe] — 61L d_model=7168 128H (MLA) vocab=129280,
MoE 256 routed + 1 shared, top-8, expert d_ff=2048 [arXiv:2412.19437; hf].

MLA: kv_lora=512, q_lora=1536, d_nope=128, d_rope=64, d_v=128; first 3
layers use a dense FFN (18432), the rest are MoE. (MTP head omitted —
orthogonal to SPARe; noted in DESIGN.md.)
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,               # dense FFN of the first_k_dense layers
    vocab=129280,
    attn_kind="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    mla_d_nope=128,
    mla_d_rope=64,
    mla_d_v=128,
    rope_theta=1e4,
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        d_expert=2048,
        n_shared=1,
        first_k_dense=3,
    ),
    # 671B on v5e HBM arithmetic: params bf16 (1.34 TB) + fp32 Adam
    # (5.4 TB) cannot fit even the 512-chip multi-pod (8.2 TB aggregate).
    # bf16 moments + bf16 grad accumulation is the memory point that fits
    # multi-pod (DeepSeek-V3 itself trained with a low-precision
    # optimizer); see EXPERIMENTS.md §Dry-run.
    moment_dtype="bfloat16",
    grad_accum_dtype="bfloat16",
    grad_accum=8,
)
