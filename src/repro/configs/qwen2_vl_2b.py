"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, QKV bias, M-RoPE [arXiv:2409.12191; hf].

Vision frontend is a stub: ``input_specs`` provides precomputed patch
embeddings mixed into the token stream (B, S, d_model). M-RoPE's text-only
case degenerates to standard 1-D RoPE (the three position components
coincide), which is what the backbone applies here — see DESIGN.md.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
    frontend="vlm",
)
