from .pipeline import ShardedTokenPipeline, spare_batch

__all__ = ["ShardedTokenPipeline", "spare_batch"]
