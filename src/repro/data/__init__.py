from .pipeline import (RequestStream, ServeRequest, ShardedTokenPipeline,
                       spare_batch, spare_batch_rows)

__all__ = ["ShardedTokenPipeline", "spare_batch", "spare_batch_rows",
           "ServeRequest", "RequestStream"]
