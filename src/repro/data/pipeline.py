"""Deterministic synthetic data pipeline with SPARe shard-type mapping.

The unit of SPARe accounting is the *shard type*: type ``i`` at step ``t``
is a fixed, reproducible microbatch (the paper's 256M-token "stack"; here
scaled to the configured batch). Determinism is the property SPARe
actually relies on — whichever surviving group computes type ``i``, it
must see the *same* tokens, or reordering would change the gradient. We
derive every token from ``hash(type, step, position)`` via counter-based
`Philox` so any host can materialize any shard without coordination.

:func:`spare_batch` assembles the *global* stacked batch for one training
step from a :class:`repro.core.SpareState` schedule: group ``w``'s slice
of stack ``j`` carries shard type ``stk[w][j]`` and weight
``1/N``-if-supplier-else-``0`` (paper §3.1 invariant — the weighted psum
equals vanilla DP's gradient exactly; property-tested).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.state import SpareState
from repro.models.config import ModelConfig

__all__ = ["ShardedTokenPipeline", "spare_batch", "spare_batch_rows",
           "ServeRequest", "RequestStream"]


class ShardedTokenPipeline:
    """Reproducible token stream: (type, step) -> (per_type_batch, seq+1)."""

    def __init__(self, cfg: ModelConfig, seq: int, per_type_batch: int,
                 seed: int = 0):
        self.cfg = cfg
        self.seq = seq
        self.per_type_batch = per_type_batch
        self.seed = seed

    def shard(self, shard_type: int, step: int) -> np.ndarray:
        """Tokens (per_type_batch, seq+1) for one shard type at one step."""
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[shard_type, step, 0, 0]))
        return rng.integers(0, self.cfg.vocab,
                            (self.per_type_batch, self.seq + 1),
                            dtype=np.int32)

    def embeds(self, shard_type: int, step: int) -> np.ndarray:
        """Frontend-stub embeddings (audio frames / vision patches)."""
        rng = np.random.Generator(np.random.Philox(
            key=self.seed + 1, counter=[shard_type, step, 0, 0]))
        return rng.standard_normal(
            (self.per_type_batch, self.seq, self.cfg.d_model)
        ).astype(np.float32) * 0.02


def spare_batch_rows(pipeline: ShardedTokenPipeline,
                     schedule: tuple[np.ndarray, np.ndarray], s_a: int,
                     step: int, lo: int, hi: int) -> dict[str, np.ndarray]:
    """Example rows ``[lo, hi)`` of the stacked batch — the per-host cut.

    ``schedule`` is ``state.device_schedule()``'s ``(stack_types,
    weights)`` pair, passed as plain arrays so a prefetch thread can
    build rows without touching mutable trainer state. Only the shard
    types owned by groups ``lo // per_type_batch .. (hi - 1) //
    per_type_batch`` are materialized — a host feeding its addressable
    shards via ``jax.make_array_from_callback`` never pays for the
    global batch. Row content is identical to the same rows of
    :func:`spare_batch` (the counter-based pipeline makes every slice a
    pure function of ``(type, step)``).
    """
    stack_types, wts = schedule
    ptb = pipeline.per_type_batch
    use_embeds = pipeline.cfg.frontend is not None
    rows = hi - lo

    toks = np.zeros((s_a, rows, pipeline.seq + 1), np.int32)
    embeds = (np.zeros((s_a, rows, pipeline.seq, pipeline.cfg.d_model),
                       np.float32) if use_embeds else None)
    weights = np.zeros((s_a, rows), np.float64)
    for w in range(lo // ptb, (hi + ptb - 1) // ptb):
        glo = w * ptb                      # group w's global row range
        dlo, dhi = max(glo, lo), min(glo + ptb, hi)
        src = slice(dlo - glo, dhi - glo)  # within the group's shard
        dst = slice(dlo - lo, dhi - lo)    # within this cut
        for j in range(s_a):
            t = int(stack_types[w, j])
            toks[j, dst] = pipeline.shard(t, step)[src]
            if use_embeds:
                embeds[j, dst] = pipeline.embeds(t, step)[src]
            # per-example weight: supplier weight (1/N or 0) divided by the
            # per-type batch so sum_jb pw * CE_b == (1/N) sum_i mean_i(CE)
            # == vanilla DP's batch-mean loss
            weights[j, dst] = wts[w, j] / ptb
    batch = {
        "labels": toks[:, :, 1:],
        "weights": weights.astype(np.float32),
    }
    if use_embeds:
        batch["embeds"] = embeds
    else:
        batch["tokens"] = toks[:, :, :-1]
    return batch


@dataclass
class ServeRequest:
    """One decode request for the serving tier.

    ``tokens`` is the exact-length prompt (no padding — the SSM prefill
    runs through every token); ``max_new`` counts generated tokens
    including the one the prefill itself produces.
    """

    req_id: int
    tokens: np.ndarray                    # (L,) int32
    max_new: int
    generated: list = field(default_factory=list, repr=False)

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])


class RequestStream:
    """Reproducible serving workload: req_id -> ServeRequest.

    The same counter-based Philox trick as :class:`ShardedTokenPipeline`,
    keyed per *request* — any replica (or a requeue after a replica
    death) can re-materialize request ``i`` without coordination, which
    is what makes the zero-dropped-requests assertion exact: a requeued
    request is bit-identical to its first admission, and greedy decode
    then reproduces the same output tokens on any survivor.

    Prompt lengths are drawn from a small fixed ``buckets`` set — the
    engine compiles one prefill executable per bucket (exact lengths, no
    padding: see :meth:`repro.models.model.Model.prefill`).
    """

    def __init__(self, cfg: ModelConfig, buckets: tuple[int, ...] = (8, 16),
                 max_new: int = 8, seed: int = 0):
        if not buckets:
            raise ValueError("need at least one prompt-length bucket")
        self.cfg = cfg
        self.buckets = tuple(sorted(buckets))
        self.max_new = max_new
        self.seed = seed

    def request(self, req_id: int) -> ServeRequest:
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[req_id, 0, 0, 0]))
        length = self.buckets[int(rng.integers(len(self.buckets)))]
        toks = rng.integers(0, self.cfg.vocab, (length,), dtype=np.int32)
        return ServeRequest(req_id=req_id, tokens=toks, max_new=self.max_new)

    def requests(self, n: int, start: int = 0) -> list[ServeRequest]:
        return [self.request(i) for i in range(start, start + n)]


def spare_batch(pipeline: ShardedTokenPipeline, state: SpareState,
                step: int) -> dict[str, np.ndarray]:
    """Global stacked batch for the current SPARe schedule.

    Returns dict with:
      tokens/embeds: (S_A, N*per_type_batch, seq[(+1 tokens)])
      labels:        (S_A, N*per_type_batch, seq)
      weights:       (S_A, N*per_type_batch)  — per-example supplier weight,
                     scaled so a plain sum of weighted per-example mean-CE
                     gradients equals vanilla DP's batch-mean gradient.
    """
    return spare_batch_rows(pipeline, state.device_schedule(), state.s_a,
                            step, 0, state.n * pipeline.per_type_batch)
