"""SPARe-masked serving replicas over the cluster topology.

The serving half of the paper's thesis: a failure is **pure weight-table
data**. A :class:`ReplicaServer` maps R serving replicas onto
:class:`~repro.scenarios.topology.ClusterTopology` host groups (replica
r = group r, so rack/pod blast radii resolve exactly as they do for
training) and tracks liveness in a ``SpareState(R, 1)``. Request routing
is smooth weighted round-robin over the SPARe supplier-style weight
table ``alive / alive.sum()`` — when a replica dies:

* its weight drops to 0 and survivors absorb its share — a host-side
  array edit, **no recompile**: all replicas share one
  :class:`~repro.serve.engine.ExecutableCache`, whose ``misses`` counter
  is frozen after warmup (the acceptance gate asserts this through a
  rack-burst campaign);
* its queued *and in-flight* requests requeue onto survivors from their
  prompts — the counter-based :class:`~repro.data.pipeline.RequestStream`
  plus greedy decode make the re-run bit-identical, so zero requests are
  dropped while any replica survives;
* wipe-out (every replica dead — e.g. a rack that hosts all of them)
  falls back to reload-from-checkpoint via
  :class:`~repro.ckpt.checkpoint.CheckpointManager` exactly like the
  trainer: ``restore_latest`` the params, rebuild the engines, requeue
  everything, ``injector.notify_wipeout()`` to account the outage.

Failures arrive through the same
:class:`~repro.train.injection.ScenarioInjector` bridge the trainer
uses (``poll(state) -> [StepEvent]`` with topology-resolved victim
sets); configure it with ``n_groups == n_replicas``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.state import SpareState
from repro.data.pipeline import ServeRequest
from repro.models.model import Model
from repro.obs.trace import maybe_span
from repro.scenarios.topology import ClusterTopology

from .engine import ExecutableCache, FinishedRequest, ServeEngine

__all__ = ["ReplicaServer", "ReplicaEvent"]


@dataclass
class ReplicaEvent:
    """One liveness transition, for reports/tests."""

    step: int
    kind: str                              # "kill" | "wipeout"
    victims: list[int] = field(default_factory=list)
    requeued: int = 0


class ReplicaServer:
    """R serving replicas with SPARe weight-table failure masking."""

    def __init__(self, model: Model, params, *, n_replicas: int,
                 topology: ClusterTopology | None = None,
                 injector=None, ckpt=None, engine_kwargs: dict,
                 telemetry=None, detector=None):
        self.model = model
        self.params = params
        self.topology = topology
        self.injector = injector
        self.ckpt = ckpt
        self.telemetry = telemetry      # repro.obs.Telemetry | None
        # optional repro.health.StragglerDetector (n_groups ==
        # n_replicas): per-tick replica timings fold into the routing
        # weights, so traffic steers around fail-slow replicas long
        # before they die
        self.detector = detector
        if telemetry is not None and injector is not None \
                and hasattr(injector, "telemetry"):
            injector.telemetry = telemetry
        self.spare = SpareState(n_replicas, 1)
        # the cache's hit/miss counters ARE the metrics-registry entries
        # when telemetry is on — one source of truth for the
        # frozen-recompiles gate
        self.exec_cache = ExecutableCache(
            None if telemetry is None else telemetry.metrics)
        self.engine_kwargs = dict(engine_kwargs)
        self.engines = [self._new_engine(r) for r in range(n_replicas)]
        # smooth weighted round-robin credits over the weight table
        self._credits = np.zeros(n_replicas, np.float64)
        self.step_idx = 0
        self.events: list[ReplicaEvent] = []
        self.dropped = 0                   # must stay 0 unless wiped out
        if ckpt is not None:
            # durable base image for the wipe-out path
            ckpt.maybe_save(0, params, block=True, force=True)

    def _new_engine(self, r: int) -> ServeEngine:
        return ServeEngine(self.model, self.params,
                           exec_cache=self.exec_cache,
                           telemetry=self.telemetry, track=f"replica/{r}",
                           **self.engine_kwargs)

    # ------------------------------------------------------------- #
    # weight table + routing                                         #
    # ------------------------------------------------------------- #
    @property
    def health_factors(self) -> np.ndarray:
        """Per-replica slowdown estimates from the detector (all 1.0
        without one)."""
        n = self.spare.n
        if self.detector is None or not self.detector.reports:
            return np.ones(n, np.float64)
        return np.maximum(self.detector.reports[-1].factors, 1.0)

    @property
    def weights(self) -> np.ndarray:
        """SPARe-style masking weights with detector health folded in:
        a dead replica's entry is zero; a live replica's share is
        proportional to its estimated throughput ``1 / factor``; and a
        replica the detector has *flagged* as a straggler is routed
        around entirely (weight 0) while any unflagged replica
        survives — all of it data, not program."""
        alive = self.spare.alive.astype(np.float64)
        w = alive / self.health_factors
        if self.detector is not None:
            flagged = list(self.detector.flagged)
            if flagged:
                spared = w.copy()
                spared[flagged] = 0.0
                if spared.any():   # someone healthy remains: avoid slow
                    w = spared
        total = w.sum()
        return w / total if total else w

    @property
    def recompiles(self) -> int:
        return self.exec_cache.misses

    def warmup(self) -> None:
        for eng in self.engines:
            eng.warmup()

    def submit(self, req: ServeRequest) -> None:
        self._route(req)

    def _route(self, req: ServeRequest) -> None:
        w = self.weights
        if not w.any():
            # wiped out mid-recovery: park on replica 0's queue; the
            # wipe-out reload requeues it properly
            self.engines[0].submit(req)
            return
        self._credits += w
        # only weight-bearing replicas are eligible: a flagged-slow
        # replica (weight 0) must not win on stale credits
        pick = int(np.argmax(np.where(self.spare.alive & (w > 0),
                                      self._credits, -np.inf)))
        self._credits[pick] -= 1.0
        self.engines[pick].submit(req)

    # ------------------------------------------------------------- #
    # failure handling                                               #
    # ------------------------------------------------------------- #
    def _kill(self, victims: list[int]) -> int:
        requeued = []
        for v in victims:
            if not self.spare.alive[v]:
                continue
            self.spare.alive[v] = False
            self._credits[v] = 0.0
            requeued += self.engines[v].drain_requests()
        for req in sorted(requeued, key=lambda r: r.req_id):
            self._route(req)
        return len(requeued)

    def _wipeout(self) -> int:
        """Every replica dead: reload params, rebuild engines, requeue."""
        pending: list[ServeRequest] = []
        for eng in self.engines:
            pending += eng.drain_requests()
        if self.injector is not None:
            self.injector.notify_wipeout()
        if self.ckpt is not None:
            _, self.params = self.ckpt.restore_latest(self.params)
        self.spare.reset()
        self._credits[:] = 0.0
        self.engines = [self._new_engine(r)
                        for r in range(len(self.engines))]
        # fresh pools over restored params; executables are shape-keyed
        # so the shared cache still hits — a wipe-out reload does not
        # recompile either
        for req in sorted(pending, key=lambda r: r.req_id):
            self._route(req)
        return len(pending)

    # ------------------------------------------------------------- #
    # gray failures: detector-weighted routing                       #
    # ------------------------------------------------------------- #
    def _health_tick(self) -> None:
        """Feed the straggler detector one tick of per-replica timings
        (the injector's fail-slow model on the emulated cluster; real
        deployments would feed measured per-replica decode latencies).
        Flag transitions surface as ``slow`` / ``healed`` events and
        immediately reshape the routing weights."""
        if self.detector is None or self.injector is None:
            return
        timings_fn = getattr(self.injector, "group_step_seconds", None)
        if timings_fn is None:
            return
        t = np.asarray(timings_fn(), dtype=np.float64)
        if t.shape != self.spare.alive.shape:
            return
        hr = self.detector.observe(t, alive=self.spare.alive,
                                   step=self.step_idx)
        tel = self.telemetry
        for v in hr.newly_flagged:
            self.events.append(ReplicaEvent(step=self.step_idx,
                                            kind="slow", victims=[v]))
            if tel is not None:
                tel.instant("straggler", track=f"replica/{v}",
                            args={"step": self.step_idx})
        for v in hr.newly_cleared:
            self.events.append(ReplicaEvent(step=self.step_idx,
                                            kind="healed", victims=[v]))
            if tel is not None:
                tel.instant("healed", track=f"replica/{v}",
                            args={"step": self.step_idx})
        if tel is not None:
            tel.gauge("serve.slow_replicas").set(len(hr.flagged))

    # ------------------------------------------------------------- #
    # the loop                                                       #
    # ------------------------------------------------------------- #
    def step(self) -> list[FinishedRequest]:
        """One server tick: deliver failures, mask, drive live engines."""
        tel = self.telemetry
        self._health_tick()
        if self.injector is not None:
            for ev in self.injector.poll(self.spare):
                if tel is not None:
                    for v in ev.victims:
                        tel.instant("failure", track=f"replica/{v}",
                                    args={"step": self.step_idx})
                    tel.counter("serve.kills").inc(len(ev.victims))
                n = self._kill(ev.victims)
                if tel is not None and n:
                    tel.counter("serve.requeued").inc(n)
                self.events.append(ReplicaEvent(
                    step=self.step_idx, kind="kill",
                    victims=list(ev.victims), requeued=n))
            if not self.spare.alive.any():
                with maybe_span(tel, "recover",
                                args=(None if tel is None else
                                      {"step": self.step_idx,
                                       "wipeout": True})):
                    n = self._wipeout()
                if tel is not None:
                    tel.counter("serve.wipeouts").inc()
                    if n:
                        tel.counter("serve.requeued").inc(n)
                self.events.append(ReplicaEvent(
                    step=self.step_idx, kind="wipeout", requeued=n))

        done: list[FinishedRequest] = []
        for r in np.flatnonzero(self.spare.alive):
            done += self.engines[int(r)].step()
        self.step_idx += 1
        if tel is not None:
            tel.gauge("serve.replicas_alive").set(
                int(self.spare.alive.sum()))
            tel.gauge("serve.queue_depth").set(
                sum(e.pending for e in self.engines))
            tel.gauge("serve.kv_pages.free").set(
                sum(e.alloc.free_pages for e in self.engines))
        return done

    def run(self, max_steps: int = 10_000) -> list[FinishedRequest]:
        """Step until every submitted request completes."""
        out: list[FinishedRequest] = []
        for _ in range(max_steps):
            if not any(eng.pending or eng.in_flight
                       for eng in self.engines):
                break
            out += self.step()
        return out

    # ------------------------------------------------------------- #
    @property
    def pending(self) -> int:
        return sum(eng.pending + eng.in_flight for eng in self.engines)

    def report(self) -> dict:
        return {
            "replicas": len(self.engines),
            "alive": int(self.spare.alive.sum()),
            "weights": self.weights.tolist(),
            "steps": self.step_idx,
            "admitted": sum(e.admitted for e in self.engines),
            "completed": sum(e.completed for e in self.engines),
            "recompiles": self.recompiles,
            "executables": [list(k) for k in self.exec_cache.keys],
            "flagged_slow": ([] if self.detector is None
                             else list(self.detector.flagged)),
            "health_factors": self.health_factors.tolist(),
            "events": [(e.step, e.kind, e.victims, e.requeued)
                       for e in self.events],
        }
