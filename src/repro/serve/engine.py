"""Continuous-batching decode engine over the paged KV cache.

One :class:`ServeEngine` drives one serving replica: a queue of
:class:`~repro.data.pipeline.ServeRequest`, a fixed set of decode slots,
and the paged pools from :meth:`Model.init_paged_state`. Per
:meth:`step`:

1. **admit** — while a slot and enough pages are free, pop a request,
   run the fused cache-filling prefill (one forward — the satellite fix
   to ``make_prefill``), scatter its dense cache into the pools
   (:func:`~repro.serve.kvcache.make_cache_writer`), and seed the slot
   with the prefill's first generated token;
2. **decode** — one ``make_serve_step(paged=True)`` call advances every
   slot one token (inactive slots spin on the trash page);
3. **evict** — slots that reached ``max_new`` free their pages and emit
   a :class:`FinishedRequest`; the freed capacity admits new requests on
   the next step.

Everything device-side is AOT-compiled through a shared
:class:`ExecutableCache` — the per-S_A executable-cache idiom of
:class:`repro.exec.executor.MeshExecutor` transplanted to serving. Keys
are ``("decode",)`` and ``("prefill", L)`` / ``("write", L)`` per
prompt-length bucket; :meth:`ServeEngine.warmup` populates them all, and
because admissions, evictions, and SPARe replica re-weighting are pure
host-side data, ``cache.misses`` is provably frozen afterwards — the
no-recompile acceptance gate asserts exactly this counter. AOT (``jit
-> lower -> compile``) rather than plain ``jit`` so an accidental shape
change errors loudly instead of silently recompiling.

Prompts are *exact-length* per bucket (no right-padding): the SSM
prefill runs its recurrence through every input token, so padding would
corrupt the state (see :meth:`Model.prefill`).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import ServeRequest
from repro.models.model import Model
from repro.obs.metrics import Counter
from repro.obs.trace import maybe_span
from repro.train.step import make_prefill, make_serve_step

from .kvcache import BlockAllocator, make_cache_writer, pages_needed

__all__ = ["ExecutableCache", "FinishedRequest", "ServeEngine"]


class ExecutableCache:
    """AOT executables keyed by (kind, *bucket); shared across replicas.

    ``misses`` counts compilations; after :meth:`ServeEngine.warmup` it
    must stay frozen through any failure/re-weight sequence (the
    acceptance gate). Shared by every replica engine of a
    :class:`~repro.serve.replicas.ReplicaServer` so a request re-routed
    to a survivor hits the same executables.

    The counts live in :class:`repro.obs.metrics.Counter` objects — pass
    a :class:`~repro.obs.metrics.MetricsRegistry` and they ARE the
    registry's ``serve.exec_cache.misses`` / ``.hits`` entries, so a
    metrics snapshot and this cache can never disagree (the serve CLI's
    frozen-recompiles gate checks the snapshot).

    Each build site declares how many flat buffer leaves it donates
    (``donated_leaves``); the static analyzer (``repro.analysis``, run
    via ``python -m repro.launch.lint``) replays :meth:`programs` and
    cross-checks every declaration against the compiled module's
    ``input_output_alias`` table — the donation contract here is
    analyzer-enforced, not just documented.
    """

    def __init__(self, metrics=None):
        self._exe: dict[tuple, object] = {}
        self._donated: dict[tuple, int] = {}
        if metrics is None:
            self._misses = Counter()
            self._hits = Counter()
        else:
            self._misses = metrics.counter("serve.exec_cache.misses")
            self._hits = metrics.counter("serve.exec_cache.hits")

    @property
    def misses(self) -> int:
        return self._misses.value

    @property
    def hits(self) -> int:
        return self._hits.value

    def get(self, key: tuple, build, donated_leaves: int = 0):
        exe = self._exe.get(key)
        if exe is None:
            self._misses.inc()
            exe = self._exe[key] = build()
            self._donated[key] = donated_leaves
        else:
            self._hits.inc()
        return exe

    def programs(self):
        """``(key, hlo_text, donated_leaves)`` per cached executable —
        the donation-audit surface for ``repro.analysis``."""
        for key in sorted(self._exe):
            yield key, self._exe[key].as_text(), self._donated.get(key, 0)

    @property
    def keys(self) -> list[tuple]:
        return sorted(self._exe)


@dataclass
class FinishedRequest:
    """A completed request: generated ids + per-token latencies."""

    req_id: int
    prompt_len: int
    tokens: np.ndarray                    # (max_new,) int32 generated ids
    latencies: np.ndarray                 # (max_new,) seconds per token
    admitted_step: int
    finished_step: int


@dataclass
class _Slot:
    request: ServeRequest
    pages: list[int]
    generated: list[int] = field(default_factory=list)
    latencies: list[float] = field(default_factory=list)
    admitted_step: int = 0


class ServeEngine:
    """One replica's continuous-batching loop (host control plane)."""

    def __init__(self, model: Model, params, *, n_slots: int,
                 n_pages: int, page_size: int, max_new: int,
                 buckets: tuple[int, ...],
                 exec_cache: ExecutableCache | None = None,
                 telemetry=None, track: str = "serve"):
        self.model = model
        self.params = params
        self.telemetry = telemetry      # repro.obs.Telemetry | None
        self.track = track              # trace lane (replica/<r> under
        #                                 a ReplicaServer)
        self.n_slots = n_slots
        self.page_size = page_size
        self.max_new = max_new
        self.buckets = tuple(sorted(buckets))
        self.cache = exec_cache if exec_cache is not None else ExecutableCache()

        # worst case: longest bucket + full generation budget
        self.max_pages = pages_needed(self.buckets[-1] + max_new, page_size)
        self.alloc = BlockAllocator(n_pages, page_size)
        self.pools = model.init_paged_state(n_slots, n_pages, page_size)
        self._writer = make_cache_writer(model)

        # host-side slot arrays (the compiled step's data plane)
        self.table = np.zeros((n_slots, self.max_pages), np.int32)
        self.pos = np.zeros((n_slots,), np.int32)
        self.next_tok = np.zeros((n_slots,), np.int32)
        self.slots: list[_Slot | None] = [None] * n_slots

        self.queue: deque[ServeRequest] = deque()
        self.step_idx = 0
        self.admitted = 0
        self.completed = 0

    # ------------------------------------------------------------- #
    # executables                                                    #
    # ------------------------------------------------------------- #
    def _decode_exe(self):
        def build():
            fn = make_serve_step(self.model, paged=True)
            args = (self.params, self.pools,
                    jnp.asarray(self.table), jnp.asarray(self.pos),
                    jnp.asarray(self.next_tok[:, None]))
            return jax.jit(
                lambda p, s, t, pos, tok: fn(p, s, t, pos, tokens=tok),
                donate_argnums=(1,)).lower(*args).compile()
        return self.cache.get(
            ("decode",), build,
            donated_leaves=len(jax.tree_util.tree_leaves(self.pools)))

    def _prefill_exe(self, length: int):
        if length not in self.buckets:
            raise ValueError(f"prompt length {length} not in buckets "
                             f"{self.buckets}")

        def build():
            fn = make_prefill(self.model, return_cache=True)
            toks = jnp.zeros((1, length), jnp.int32)
            return jax.jit(
                lambda p, t: fn(p, tokens=t)).lower(
                    self.params, toks).compile()
        return self.cache.get(("prefill", length), build)

    def _write_exe(self, length: int):
        n_alloc = pages_needed(length + self.max_new, self.page_size)

        def build():
            dense = jax.eval_shape(
                lambda: self.model.init_decode_state(1, length))
            dense = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), dense)
            pages = jnp.zeros((n_alloc,), jnp.int32)
            return jax.jit(self._writer, donate_argnums=(0,)).lower(
                self.pools, dense, pages, jnp.int32(0)).compile()
        return self.cache.get(
            ("write", length), build,
            donated_leaves=len(jax.tree_util.tree_leaves(self.pools)))

    def warmup(self) -> None:
        """Compile every executable this engine can ever need. After
        this, ``cache.misses`` is frozen — any later compile is a bug."""
        self._decode_exe()
        for length in self.buckets:
            self._prefill_exe(length)
            self._write_exe(length)

    # ------------------------------------------------------------- #
    # request flow                                                   #
    # ------------------------------------------------------------- #
    def submit(self, req: ServeRequest) -> None:
        if req.prompt_len not in self.buckets:
            raise ValueError(f"prompt length {req.prompt_len} not in "
                             f"buckets {self.buckets}")
        if req.max_new > self.max_new:
            raise ValueError(f"max_new {req.max_new} > engine budget "
                             f"{self.max_new}")
        self.queue.append(req)

    @property
    def in_flight(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def pending(self) -> int:
        return len(self.queue)

    def drain_requests(self) -> list[ServeRequest]:
        """Pull every queued AND in-flight request out of this engine
        (replica death): in-flight sequences restart from their prompt —
        greedy decode makes the requeued output bit-identical, so a
        failure costs latency, never correctness. Pages are freed; pools
        keep their (now unreachable) contents."""
        out = list(self.queue)
        self.queue.clear()
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            self.alloc.free(slot.pages)
            self._clear_slot(i)
            out.append(slot.request)
        out.sort(key=lambda r: r.req_id)
        return out

    def _clear_slot(self, i: int) -> None:
        self.slots[i] = None
        self.table[i] = 0
        self.pos[i] = 0
        self.next_tok[i] = 0

    # ------------------------------------------------------------- #
    # the loop                                                       #
    # ------------------------------------------------------------- #
    def _admit(self) -> None:
        tel = self.telemetry
        for i in range(self.n_slots):
            if not self.queue or self.slots[i] is not None:
                continue
            req = self.queue[0]
            total = req.prompt_len + self.max_new
            if not self.alloc.can_alloc(total):
                break                      # FIFO: don't starve the head
            self.queue.popleft()
            pages = self.alloc.alloc(total)
            length = req.prompt_len

            with maybe_span(tel, "admit", self.track,
                            args=(None if tel is None else
                                  {"req": req.req_id, "len": length})):
                t0 = time.perf_counter()
                with maybe_span(tel, "prefill", self.track):
                    logits, dense = self._prefill_exe(length)(
                        self.params, jnp.asarray(req.tokens[None, :]))
                    self.pools = self._write_exe(length)(
                        self.pools, dense, jnp.asarray(pages, jnp.int32),
                        jnp.int32(i))
                    first = int(np.argmax(
                        np.asarray(logits[0, -1, :self.model.cfg.vocab])))
                dt = time.perf_counter() - t0

                slot = _Slot(request=req, pages=pages,
                             admitted_step=self.step_idx)
                slot.generated.append(first)
                slot.latencies.append(dt)
                self.slots[i] = slot
                self.table[i] = 0
                self.table[i, :len(pages)] = pages
                self.pos[i] = length
                self.next_tok[i] = first
                self.admitted += 1
            if tel is not None:
                tel.counter("serve.admitted").inc()
                tel.histogram("serve.prefill_latency_s").observe(dt)

    def _evict_finished(self) -> list[FinishedRequest]:
        tel = self.telemetry
        done = []
        for i, slot in enumerate(self.slots):
            if slot is None or len(slot.generated) < slot.request.max_new:
                continue
            with maybe_span(tel, "evict", self.track,
                            args=(None if tel is None else
                                  {"req": slot.request.req_id})):
                self.alloc.free(slot.pages)
                self._clear_slot(i)
            if tel is not None:
                tel.counter("serve.completed").inc()
            self.completed += 1
            done.append(FinishedRequest(
                req_id=slot.request.req_id,
                prompt_len=slot.request.prompt_len,
                tokens=np.asarray(
                    slot.generated[:slot.request.max_new], np.int32),
                latencies=np.asarray(
                    slot.latencies[:slot.request.max_new], np.float64),
                admitted_step=slot.admitted_step,
                finished_step=self.step_idx))
        return done

    def step(self) -> list[FinishedRequest]:
        """One engine tick: admit, decode one token everywhere, evict."""
        tel = self.telemetry
        self._admit()
        done = self._evict_finished()      # max_new == 1 finishes here

        active = [i for i, s in enumerate(self.slots) if s is not None]
        if active:
            with maybe_span(tel, "decode", self.track,
                            args=(None if tel is None else
                                  {"active": len(active)})):
                t0 = time.perf_counter()
                logits, self.pools = self._decode_exe()(
                    self.params, self.pools, jnp.asarray(self.table),
                    jnp.asarray(self.pos),
                    jnp.asarray(self.next_tok[:, None]))
                toks = np.argmax(
                    np.asarray(logits[:, :self.model.cfg.vocab]), axis=-1)
                dt = time.perf_counter() - t0
            for i in active:
                slot = self.slots[i]
                slot.generated.append(int(toks[i]))
                slot.latencies.append(dt)
                self.pos[i] += 1
                self.next_tok[i] = int(toks[i])
            done += self._evict_finished()
            if tel is not None:
                tel.counter("serve.tokens").inc(len(active))
                tel.histogram("serve.token_latency_s").observe(dt)

        self.step_idx += 1
        if tel is not None:
            tel.gauge("serve.queue_depth").set(len(self.queue))
            tel.gauge("serve.kv_pages.free").set(self.alloc.free_pages)
            tel.gauge("serve.kv_pages.used").set(
                self.alloc.n_pages - 1 - self.alloc.free_pages)
        return done

    def run(self, max_steps: int = 10_000) -> list[FinishedRequest]:
        """Step until queue and slots drain (or ``max_steps``)."""
        out = []
        for _ in range(max_steps):
            if not self.queue and self.in_flight == 0:
                break
            out += self.step()
        return out
