"""Paged, sharded KV cache: block-table allocation over one physical pool.

The serving tier's memory model (vLLM-style paging, adapted to the
scan-over-layers cache layout of :meth:`repro.models.model.Model`):

* every attention layer owns a **physical page pool** ``(n_rep, n_pages,
  page_size, ...)`` (:meth:`Model.init_paged_state`); sequences of
  different lengths share it through a host-side **block table**
  ``(n_slots, max_pages)`` of physical page ids, one row per decode slot;
* Mamba layers need no paging — SSD state is O(1) per sequence, so their
  caches stay slot-dense and the slot index is the "page";
* **page 0 is the trash page**: never allocated, it absorbs the reads and
  writes of inactive decode slots (all-zero table rows, pos 0) so the
  compiled decode step is total — admission and eviction are pure
  host-side data edits, the program never changes;
* stale pool contents after eviction are *unreachable*, not just
  unlikely: the decode mask scores positions past ``pos`` at ``-2^20``
  and fp32 softmax underflows them to exactly ``0.0`` (property-tested in
  ``tests/test_serve.py`` by dirtying the whole pool).

Shardings come from :func:`repro.dist.sharding.paged_cache_specs`: the
page/slot axis shards over the DP axes exactly like the decode batch
would — the block table itself is host memory and never enters the
compiled program.

:class:`BlockAllocator` is deliberately a tiny deterministic LIFO
free-list: given the same alloc/free call sequence it hands out the same
pages (tested), so a failure-requeued request reproduces its healthy-run
output bit for bit (page *identity* never affects gathered values).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import ssm as ssm_mod
from repro.models.model import Model

__all__ = ["BlockAllocator", "pages_needed", "pool_pages_for",
           "make_cache_writer"]

TRASH_PAGE = 0


def pages_needed(total_len: int, page_size: int) -> int:
    """Pages covering ``total_len`` cache rows."""
    return max(1, math.ceil(total_len / page_size))


def pool_pages_for(n_slots: int, max_len: int, page_size: int) -> int:
    """Pool size (pages) so ``n_slots`` worst-case sequences always fit,
    plus the reserved trash page."""
    return n_slots * pages_needed(max_len, page_size) + 1


class BlockAllocator:
    """Deterministic page allocator over one physical pool.

    LIFO free list seeded with pages ``1 .. n_pages-1`` (page 0 is the
    trash page and is never handed out). Allocation is all-or-nothing:
    a request that doesn't fit stays in the queue rather than holding a
    partial reservation.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        self.page_size = page_size
        # LIFO with low pages on top: pop() returns 1, 2, 3, ...
        self._free = list(range(n_pages - 1, 0, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def can_alloc(self, total_len: int) -> bool:
        return pages_needed(total_len, self.page_size) <= len(self._free)

    def alloc(self, total_len: int) -> list[int]:
        """Allocate pages for a sequence of ``total_len`` rows."""
        n = pages_needed(total_len, self.page_size)
        if n > len(self._free):
            raise MemoryError(
                f"need {n} pages, {len(self._free)} free")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: list[int]) -> None:
        for pg in pages:
            if pg == TRASH_PAGE:
                raise ValueError("page 0 (trash) is not allocatable")
            if pg in self._free:
                raise ValueError(f"double free of page {pg}")
            self._free.append(pg)


def make_cache_writer(model: Model):
    """Build the pure prefill→pool scatter for ``model``.

    Returns ``write(paged_state, dense_state, pages, slot) ->
    paged_state`` where ``dense_state`` is a batch-1
    :meth:`Model.prefill` state of prompt length L, ``pages`` is the
    ``(n_alloc,)`` int32 page list for the sequence (``n_alloc * PS >=
    L``; the tail of the last page is zero-filled — masked, never read),
    and ``slot`` is the scalar decode-slot index for the Mamba leaves.
    Jit per prompt-length bucket (L and n_alloc are shape-static).
    """

    def write(paged, dense, pages, slot):
        new_state = []
        for seg_pool, seg_dense in zip(paged, dense):
            per_pos = []
            for pool_c, dense_c in zip(seg_pool, seg_dense):
                if isinstance(pool_c, ssm_mod.MambaCache):
                    # slot-dense: drop the batch-1 axis, land in the slot
                    per_pos.append(jax.tree.map(
                        lambda pl, dn: pl.at[:, slot].set(
                            dn[:, 0].astype(pl.dtype)),
                        pool_c, dense_c))
                else:
                    def scatter(pl, dn):
                        # pl (n_rep, NP, PS, *t); dn (n_rep, 1, L, *t)
                        n_rep, _, ps = pl.shape[:3]
                        length = dn.shape[2]
                        n_alloc = pages.shape[0]
                        pad = n_alloc * ps - length
                        d = jnp.pad(dn[:, 0],
                                    ((0, 0), (0, pad)) +
                                    ((0, 0),) * (dn.ndim - 3))
                        d = d.reshape(n_rep, n_alloc, ps, *pl.shape[3:])
                        return pl.at[:, pages].set(d.astype(pl.dtype))
                    per_pos.append(jax.tree.map(scatter, pool_c, dense_c))
            new_state.append(tuple(per_pos))
        return new_state

    return write
