"""Fault-tolerant serving tier: paged KV cache, continuous batching,
SPARe-masked replicas. See ``README.md`` §repro.serve."""
from .engine import ExecutableCache, FinishedRequest, ServeEngine
from .kvcache import (BlockAllocator, make_cache_writer, pages_needed,
                      pool_pages_for)
from .replicas import ReplicaEvent, ReplicaServer

__all__ = ["BlockAllocator", "pages_needed", "pool_pages_for",
           "make_cache_writer", "ExecutableCache", "FinishedRequest",
           "ServeEngine", "ReplicaEvent", "ReplicaServer"]
