"""repro.obs — unified telemetry: span tracing + metrics registry.

The measurement substrate every layer reports into:

* :mod:`repro.obs.trace` — low-overhead host-side span recorder with
  Chrome-trace/Perfetto export, instant failure/recovery markers on
  per-DP-group tracks, and the nullable :class:`Telemetry` handle the
  trainer / mesh executor / serving tier thread through their hot
  loops (``None`` keeps the uninstrumented path allocation-free);
* :mod:`repro.obs.metrics` — counters / gauges / exact-quantile
  histograms, snapshottable to deterministic JSON;
* ``python -m repro.launch.obs trace.json`` — text timeline + the
  recovery-attribution table (time lost to masking vs rollback vs
  restart) rendered from a dumped trace.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               latency_stats, quantile_key)
from repro.obs.trace import (Instant, Span, Telemetry, TraceRecorder,
                             TraceView, load_trace, maybe_span, tick)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "latency_stats",
    "quantile_key",
    "Telemetry", "TraceRecorder", "TraceView", "Span", "Instant",
    "load_trace", "maybe_span", "tick",
]
