"""Metrics registry: counters, gauges, exact-quantile histograms.

The live half of the observability substrate (the other half is the
span recorder in :mod:`repro.obs.trace`). Three primitive types:

* :class:`Counter` — monotone accumulator (steps, failures, wire bytes,
  executable-cache misses);
* :class:`Gauge` — last-write-wins level (S_A, KV-page-pool occupancy,
  serve queue depth, per-step wire bytes);
* :class:`Histogram` — stores *every* observation, so quantiles are
  exact (``np.quantile``-identical), not sketch approximations — at
  repro scale the observation count is bounded by steps/tokens, and the
  serving acceptance gates (p99, p99.9) must not move with sketch
  resolution.

A :class:`MetricsRegistry` is a flat get-or-create namespace of those
three; :meth:`MetricsRegistry.snapshot` renders it to a JSON-able dict
with sorted keys, so two seeded runs that observe the same deterministic
values snapshot to byte-identical JSON (the determinism gate in
``tests/test_obs.py``).

This module deliberately imports numpy only (no jax): the serving tier's
:class:`~repro.serve.engine.ExecutableCache` keeps its miss counter here
as the single source of truth, and must stay importable everywhere.
"""
from __future__ import annotations

import json

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "quantile_key", "latency_stats"]

#: default snapshot quantiles (percent)
DEFAULT_QUANTILES = (50.0, 90.0, 99.0, 99.9)


def quantile_key(q: float) -> str:
    """``50 -> "p50"``, ``99.9 -> "p99_9"`` — stable JSON field names."""
    s = f"{q:g}".replace(".", "_")
    return f"p{s}"


class Counter:
    """Monotone accumulator. ``inc`` only; resets are a new Counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: float = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins level."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = None

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Exact-quantile histogram: every observation is retained.

    Quantiles use numpy's default linear interpolation, so
    ``h.quantile(99.0) == np.percentile(h.values, 99.0)`` exactly —
    property-tested against random data in ``tests/test_obs.py``.
    """

    __slots__ = ("_values",)

    def __init__(self):
        self._values: list[float] = []

    def observe(self, v: float) -> None:
        self._values.append(float(v))

    def observe_many(self, vs) -> None:
        self._values.extend(float(v) for v in np.asarray(vs).ravel())

    @property
    def count(self) -> int:
        return len(self._values)

    @property
    def sum(self) -> float:
        return float(np.sum(self._values)) if self._values else 0.0

    @property
    def values(self) -> np.ndarray:
        return np.asarray(self._values, np.float64)

    def quantile(self, q: float) -> float:
        """Exact q-th percentile (``q`` in percent, numpy semantics)."""
        if not self._values:
            raise ValueError("quantile of an empty histogram")
        return float(np.percentile(self.values, q))

    def summary(self, quantiles=DEFAULT_QUANTILES) -> dict:
        if not self._values:
            return {"count": 0}
        v = self.values
        out = {"count": len(self._values), "sum": float(v.sum()),
               "min": float(v.min()), "max": float(v.max()),
               "mean": float(v.mean())}
        for q in quantiles:
            out[quantile_key(q)] = float(np.percentile(v, q))
        return out


class MetricsRegistry:
    """Flat get-or-create namespace of counters/gauges/histograms."""

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls()
        elif type(m) is not cls:
            raise TypeError(f"metric {name!r} is a {type(m).__name__}, "
                            f"not a {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self, quantiles=DEFAULT_QUANTILES) -> dict:
        """JSON-able view with sorted keys — deterministic given
        deterministic observations."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.summary(quantiles)
        return out

    def dumps(self, quantiles=DEFAULT_QUANTILES) -> str:
        return json.dumps(self.snapshot(quantiles), indent=1,
                          sort_keys=True)

    def dump(self, path, quantiles=DEFAULT_QUANTILES) -> None:
        with open(path, "w") as fh:
            fh.write(self.dumps(quantiles))


# ------------------------------------------------------------------ #
# serving latency stats (shared by launch/serve.py and the bench)    #
# ------------------------------------------------------------------ #
def latency_stats(done, *, quantiles=(50.0, 99.0, 99.9)) -> dict:
    """Aggregate per-token latency stats over finished requests.

    The one implementation behind both ``repro.launch.serve`` and
    ``benchmarks/serving_bench.py`` (previously duplicated): builds an
    exact-quantile :class:`Histogram` over every token latency and
    reports ``{"tokens", "p50_ms", "p99_ms", "p99_9_ms"}`` (one
    ``p<q>_ms`` key per requested percent, ``None`` when no tokens
    finished).
    """
    h = Histogram()
    for d in done:
        h.observe_many(d.latencies)
    out = {"tokens": h.count}
    for q in quantiles:
        key = quantile_key(q) + "_ms"
        out[key] = (round(h.quantile(q) * 1e3, 3) if h.count else None)
    return out
