"""Host-side span recorder with Chrome-trace / Perfetto JSON export.

The tracing half of the observability substrate. Design constraints:

* **low overhead** — recording a span is two clock reads and one tuple
  append; no per-span dict churn until export. The telemetry-off path
  never reaches this module at all (``maybe_span`` returns a module
  singleton), so the uninstrumented hot loop is allocation-free — the
  tracemalloc gate in ``tests/test_obs.py``.
* **deterministic export** — the clock is injectable. With the default
  ``time.perf_counter`` the trace carries real wall time; with a
  deterministic clock (``tick()`` below) two seeded runs export
  byte-identical JSON, which is how the trace format itself is
  regression-tested.
* **Perfetto-loadable** — ``dump()`` writes the Chrome trace-event
  format (``{"traceEvents": [...]}``, complete ``"X"`` events + instant
  ``"i"`` markers + ``"M"`` thread-name metadata). Load it at
  https://ui.perfetto.dev or ``chrome://tracing`` unchanged.

Tracks are named lanes (``main``, ``dp/<g>``, ``replica/<r>``): each
becomes one Perfetto thread row, created on first use. Failure and
recovery events land as instant markers on the per-DP-group tracks, so
the Perfetto view shows exactly *which* groups died under each
recovery span on the main track.

Span vocabulary used by the instrumented layers (the obs CLI's
attribution table keys off these names):

====================  ==================================================
``step``              one trainer loop iteration (main track)
``compute``           device step: dispatch through blocking on loss
``feed``              per-host input feed wait (mesh executor)
``grad_sync``         deep-mode marker scope for the compressed sync
``bucket/<i>``        deep-mode per-bucket markers inside the jitted sync
``ckpt_save``         snapshot + async checkpoint save
``recover``           one failure event's recovery (args carry kind/victims)
``grad_check``        post-recovery §3.1 gradient re-verification
``prefill``           serving: fused cache-filling prefill (per admission)
``decode``            serving: one batched decode step
``admit``/``evict``   serving: admission / eviction bookkeeping
``compile``           executable-cache miss (args carry the cache key)
====================  ==================================================
"""
from __future__ import annotations

import json
import time

from repro.obs.metrics import MetricsRegistry

__all__ = ["TraceRecorder", "Telemetry", "maybe_span", "tick",
           "load_trace", "TraceView", "Span", "Instant"]


def tick(step: float = 1.0):
    """A deterministic monotone clock for byte-stable traces/tests."""
    state = {"t": 0.0}

    def clock() -> float:
        state["t"] += step
        return state["t"]

    return clock


class _SpanCtx:
    """Context manager recording one complete ("X") event.

    Exposes ``dur`` (seconds) after exit so callers can feed the same
    measurement into a histogram without a second clock read pair.
    """

    __slots__ = ("_rec", "name", "track", "args", "t0", "dur")

    def __init__(self, rec: "TraceRecorder", name: str, track: str, args):
        self._rec = rec
        self.name = name
        self.track = track
        self.args = args
        self.t0 = 0.0
        self.dur = 0.0

    def __enter__(self) -> "_SpanCtx":
        self.t0 = self._rec._clock()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = self._rec._clock()
        self.dur = t1 - self.t0
        self._rec._events.append(
            ("X", self.name, self.track, self.t0, t1, self.args))
        return False


class _NullSpan:
    """The telemetry-off span: a reusable no-op context manager."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _TimedSpan:
    """Metrics-only span: measures ``dur`` but records no trace event.

    What ``Telemetry(trace=False).span(...)`` hands out, so callers
    that feed a span's duration into a histogram (the trainer's
    ``train.step_seconds``) work identically with span recording off.
    """

    __slots__ = ("_clock", "t0", "dur")

    def __init__(self, clock):
        self._clock = clock
        self.t0 = 0.0
        self.dur = 0.0

    def __enter__(self) -> "_TimedSpan":
        self.t0 = self._clock()
        return self

    def __exit__(self, *exc) -> bool:
        self.dur = self._clock() - self.t0
        return False


class TraceRecorder:
    """Append-only span/instant recorder for one process."""

    def __init__(self, clock=None):
        self._clock = clock if clock is not None else time.perf_counter
        self._t0 = self._clock()
        # (ph, name, track, t_start, t_end, args) — args may be None
        self._events: list[tuple] = []
        self._tracks: dict[str, int] = {}       # track name -> tid

    # -- recording ------------------------------------------------- #
    def span(self, name: str, track: str = "main",
             args: dict | None = None) -> _SpanCtx:
        return _SpanCtx(self, name, track, args)

    def instant(self, name: str, track: str = "main",
                args: dict | None = None) -> None:
        t = self._clock()
        self._events.append(("i", name, track, t, t, args))

    @property
    def n_events(self) -> int:
        return len(self._events)

    # -- export ---------------------------------------------------- #
    def _tid(self, track: str) -> int:
        tid = self._tracks.get(track)
        if tid is None:
            # main pinned to row 0; other tracks in first-seen order
            tid = self._tracks[track] = \
                0 if track == "main" else len(self._tracks) + 1
        return tid

    def _us(self, t: float) -> float:
        return round((t - self._t0) * 1e6, 3)

    def to_chrome(self) -> dict:
        """Chrome trace-event JSON (Perfetto-loadable)."""
        events = []
        body = []
        for ph, name, track, t0, t1, args in self._events:
            ev = {"name": name, "ph": ph, "pid": 0,
                  "tid": self._tid(track), "ts": self._us(t0)}
            if ph == "X":
                ev["dur"] = round((t1 - t0) * 1e6, 3)
            else:
                ev["s"] = "t"
            if args:
                ev["args"] = args
            body.append(ev)
        events.append({"name": "process_name", "ph": "M", "pid": 0,
                       "args": {"name": "repro"}})
        for track in sorted(self._tracks, key=self._tracks.get):
            tid = self._tracks[track]
            events.append({"name": "thread_name", "ph": "M", "pid": 0,
                           "tid": tid, "args": {"name": track}})
            events.append({"name": "thread_sort_index", "ph": "M",
                           "pid": 0, "tid": tid,
                           "args": {"sort_index": tid}})
        events.extend(body)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def dumps(self) -> str:
        return json.dumps(self.to_chrome(), sort_keys=True,
                          separators=(",", ":"))

    def dump(self, path) -> None:
        with open(path, "w") as fh:
            fh.write(self.dumps())


class Telemetry:
    """The nullable handle threaded through trainer/executor/serving.

    Layers take ``telemetry: Telemetry | None = None`` and guard every
    touch with ``if tel is not None`` (or :func:`maybe_span`), so the
    uninstrumented path stays allocation-free. One Telemetry carries
    both halves: the span recorder (``tracer``, optional) and the
    metrics registry (always present — counters are cheap).

    ``deep=True`` opts into instrumentation that *changes the compiled
    program or adds device syncs* (in-jit bucket markers via
    ``jax.debug.callback``, per-step EF-residual norms). Deep mode is
    for attribution sessions, not steady-state monitoring, and is
    excluded from the <2% overhead gate.
    """

    def __init__(self, *, trace: bool = True, clock=None,
                 deep: bool = False):
        self.tracer = TraceRecorder(clock=clock) if trace else None
        self._clock = clock if clock is not None else time.perf_counter
        self.metrics = MetricsRegistry()
        self.deep = deep

    # -- tracing --------------------------------------------------- #
    def span(self, name: str, track: str = "main", args: dict | None = None):
        if self.tracer is None:
            return _TimedSpan(self._clock)     # metrics-only: dur still real
        return self.tracer.span(name, track, args)

    def instant(self, name: str, track: str = "main",
                args: dict | None = None) -> None:
        if self.tracer is not None:
            self.tracer.instant(name, track, args)

    def jit_instant(self, name: str, track: str = "device", *deps) -> None:
        """Emit an instant marker from *inside* a jitted computation.

        Fires a host callback when the device program reaches the
        marker at run time (not trace time). ``deps`` are arrays the
        marker must wait for — the callback carries a data dependency
        on ``deps[0]``'s first element so XLA cannot hoist it before
        the producing op. Timing is approximate under async dispatch;
        deep-mode only.
        """
        if self.tracer is None:
            return
        import jax

        def cb(*_):
            self.instant(name, track=track)

        if deps:
            jax.debug.callback(cb, deps[0].ravel()[0])
        else:
            jax.debug.callback(cb)

    # -- metrics --------------------------------------------------- #
    def counter(self, name: str):
        return self.metrics.counter(name)

    def gauge(self, name: str):
        return self.metrics.gauge(name)

    def histogram(self, name: str):
        return self.metrics.histogram(name)

    def snapshot(self, **kw) -> dict:
        return self.metrics.snapshot(**kw)

    def dump_trace(self, path) -> None:
        if self.tracer is None:
            raise ValueError("telemetry was built with trace=False")
        self.tracer.dump(path)


def maybe_span(tel: Telemetry | None, name: str, track: str = "main",
               args: dict | None = None):
    """``tel.span(...)`` or the allocation-free null span when off."""
    if tel is None:
        return NULL_SPAN
    return tel.span(name, track, args)


# ------------------------------------------------------------------ #
# loading (the obs CLI + tests)                                      #
# ------------------------------------------------------------------ #
class Span:
    __slots__ = ("name", "track", "ts", "dur", "depth", "args")

    def __init__(self, name, track, ts, dur, depth, args):
        self.name = name
        self.track = track
        self.ts = ts              # µs from trace start
        self.dur = dur            # µs
        self.depth = depth        # 0 = top-level on its track
        self.args = args

    @property
    def end(self) -> float:
        return self.ts + self.dur

    def __repr__(self):  # pragma: no cover - debug aid
        return (f"Span({self.name!r}, track={self.track!r}, ts={self.ts}, "
                f"dur={self.dur}, depth={self.depth})")


class Instant:
    __slots__ = ("name", "track", "ts", "args")

    def __init__(self, name, track, ts, args):
        self.name = name
        self.track = track
        self.ts = ts
        self.args = args


class TraceView:
    """Parsed trace: spans with nesting depth, instants, track names."""

    def __init__(self, spans, instants, tracks):
        self.spans = spans
        self.instants = instants
        self.tracks = tracks

    def track_spans(self, track: str, depth: int | None = None):
        return [s for s in self.spans if s.track == track
                and (depth is None or s.depth == depth)]

    def named(self, name: str):
        return [s for s in self.spans if s.name == name]

    def wall_us(self, track: str = "main") -> float:
        """Last end minus first start over the track's events."""
        ts = [s.ts for s in self.spans if s.track == track] + \
             [i.ts for i in self.instants if i.track == track]
        ends = [s.end for s in self.spans if s.track == track] + \
               [i.ts for i in self.instants if i.track == track]
        return (max(ends) - min(ts)) if ts else 0.0


def load_trace(source) -> TraceView:
    """Parse a Chrome trace (path, JSON string, or dict) back into
    spans with containment-derived nesting depth."""
    if isinstance(source, dict):
        doc = source
    else:
        text = None
        try:
            with open(source) as fh:
                text = fh.read()
        except (OSError, TypeError):
            text = source
        doc = json.loads(text)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    names = {}          # tid -> track name
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[ev.get("tid", 0)] = ev["args"]["name"]

    raw_spans, instants = [], []
    for ev in events:
        ph = ev.get("ph")
        track = names.get(ev.get("tid", 0), str(ev.get("tid", 0)))
        if ph == "X":
            raw_spans.append((ev["ts"], ev.get("dur", 0.0), ev["name"],
                              track, ev.get("args")))
        elif ph in ("i", "I"):
            instants.append(Instant(ev["name"], track, ev["ts"],
                                    ev.get("args")))

    # depth by containment: per track, sweep by (start, -dur) with a
    # stack of open end-times (spans from one recorder nest properly)
    spans: list[Span] = []
    by_track: dict[str, list] = {}
    for rec in raw_spans:
        by_track.setdefault(rec[3], []).append(rec)
    for track, recs in by_track.items():
        recs.sort(key=lambda r: (r[0], -r[1]))
        stack: list[float] = []
        for ts, dur, name, trk, args in recs:
            while stack and ts >= stack[-1]:
                stack.pop()
            spans.append(Span(name, trk, ts, dur, len(stack), args))
            stack.append(ts + dur)
    spans.sort(key=lambda s: (s.ts, -s.dur))
    instants.sort(key=lambda i: i.ts)
    tracks = sorted({s.track for s in spans} |
                    {i.track for i in instants})
    return TraceView(spans, instants, tracks)
