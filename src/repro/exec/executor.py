"""MeshExecutor — SPARe's Alg. 1 running on a real SPMD device mesh.

:class:`MeshExecutor` is :class:`repro.train.trainer.SpareTrainer` with
the device plane swapped from one-process emulation to a sharded program
over a ``(data, model)`` mesh (:func:`repro.launch.mesh
.make_emulated_mesh` / :func:`~repro.launch.mesh.make_production_mesh`).
Two sync spellings of the same pure ``make_train_step`` are supported:

* ``sync="shard_map"`` (default) — the §3.1 wire protocol made explicit:
  manual ``shard_map`` over the mesh, one SPARe DP group per ``data``
  slice, supplier-weighted local gradients reduced ONCE per step via
  ``weighted_all_reduce(..., axis_name="data")`` + a **bucketed flat
  gradient sync** (:class:`~repro.dist.collectives.BucketedAllReduce`):
  the gradient pytree is flattened into a handful of size-capped
  contiguous fp32 buckets, so the per-step sync costs O(1) collectives
  regardless of leaf count, with a bit-transparent unflatten. Per-device
  parameters are replicas (pure DP), which keeps the manual program
  free of tensor-parallel collectives.
* ``sync="gspmd"`` — the dry-run's production spelling: ``jit`` with
  NamedShardings, parameters/Adam moments sharded on ``model``, the
  stacked batch on ``data``; GSPMD derives the identical all-reduce
  from the batch-sharded weighted contraction. (The mixed
  manual-data/auto-model ``shard_map`` would unify the two, but XLA's
  partial-manual subgroup handling hard-crashes on scanned+remat
  programs in the pinned toolchain — ``IsManualSubgroup`` check — so
  the executor keeps the two proven paths instead.)

``grad_compress="int8_ef"`` (shard_map sync only) swaps the bucketed
psum for the two-phase int8 error-feedback wire protocol
(:class:`~repro.dist.collectives.CompressedBucketSync`): int8 payloads +
per-bucket fp32 scales over the wire (~4x fewer gradient-sync bytes,
gated on compiled HLO by ``launch/hlo.py``), dequant-accumulated in fp32
inside the ``shard_map`` program — never int-psummed, so no overflow at
any DP degree. The EF residuals are device-local sharded state threaded
through the step (donated like params/opt) and preserved across
wipe-out rollback.

Input feeding is **per-host**: each batch leaf is built with
``jax.make_array_from_callback``, so a host materializes only the
example rows its addressable shards cover (the pipeline is counter-based
and coordination-free), and the next step's rows are prefetched on a
builder thread while the dispatched step executes (double buffering).

Failure masking is identical in all modes: recovery is pure weight-table
data. After ``scheme.recover`` re-plans the schedule, the next step
feeds the new ``SpareState.device_schedule()`` weights through the
batch — no resharding, no new collectives, no recompile (executables
are cached per ``S_A``). The paper's zero-extra-collectives property is
asserted on compiled HLO in ``tests/test_exec.py`` — with and without
compression — and the whole :class:`~repro.train.injection
.ScenarioInjector` bridge is inherited, so rack/pod burst events from
the scenario engine re-weight the live mesh step mid-run.

Runs anywhere: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
fans a CPU host out into 8 emulated devices executing the same SPMD
program (partitioner, collectives, HLO) a TPU pod would run.
"""
from __future__ import annotations

from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.data import spare_batch_rows
from repro.dist.collectives import (BucketedAllReduce, CompressedBucketSync,
                                    bucket_layout,
                                    shard_map_compat as _shard_map)
from repro.launch.mesh import make_emulated_mesh
from repro.models.config import ModelConfig
from repro.obs.trace import maybe_span
from repro.train.step import make_train_step, weighted_loss
from repro.train.trainer import SpareTrainer, TrainReport


__all__ = ["MeshExecutor", "executor_param_specs"]

_SYNCS = ("shard_map", "gspmd")
_COMPRESS = (None, "int8_ef")


def executor_param_specs(params, model_degree: int):
    """Model-axis specs for the gspmd layout: every matrix whose last dim
    divides the TP degree is column-sharded on ``model``; everything else
    (norm scales, ragged leaves) is replicated. All leaves are replicated
    across ``data`` — that axis carries the stacked batch and its
    all-reduced gradients, exactly vanilla DP + SPARe weights."""

    def spec(leaf):
        if leaf.ndim >= 2 and leaf.shape[-1] % model_degree == 0:
            return P(*(None,) * (leaf.ndim - 1), "model")
        return P()

    return jax.tree.map(spec, params)


class MeshExecutor(SpareTrainer):
    """Drop-in :class:`SpareTrainer` whose step runs sharded on a mesh.

    Extra parameters on top of the trainer's:

    mesh: a ``(data, model)`` mesh to run on; by default an emulated one
        with ``data == n_groups`` slices (requires
        ``n_groups * model_degree`` visible devices).
    model_degree: tensor-parallel degree of the default mesh (gspmd
        sync; the manual shard_map program treats model columns as
        replicas).
    sync: ``"shard_map"`` (explicit bucketed psum) or ``"gspmd"``
        (NamedShardings, params on the model axis) — see the module
        docstring.
    grad_compress: ``None`` (fp32 buckets on the wire) or ``"int8_ef"``
        (two-phase int8 error-feedback compressed sync; shard_map only).
    bucket_mb: flat-bucket size cap in MiB of fp32 — the gradient sync
        issues O(total_params / bucket) collectives per step, never one
        per leaf.
    """

    def __init__(self, cfg: ModelConfig, *, n_groups: int, redundancy: int,
                 mesh: jax.sharding.Mesh | None = None,
                 model_degree: int = 1, sync: str = "shard_map",
                 grad_compress: str | None = None, bucket_mb: float = 32.0,
                 base_lr: float = 3e-4, total_steps: int = 1000,
                 **kwargs: Any):
        if sync not in _SYNCS:
            raise ValueError(f"sync must be one of {_SYNCS}, got {sync!r}")
        if grad_compress not in _COMPRESS:
            raise ValueError(f"grad_compress must be one of {_COMPRESS}, "
                             f"got {grad_compress!r}")
        if grad_compress and sync != "shard_map":
            raise ValueError(
                "grad_compress needs the manual collective program: use "
                "sync='shard_map' (gspmd derives its own fp32 all-reduce)")
        if mesh is None:
            mesh = make_emulated_mesh(n_groups, model_degree)
        if "model" not in mesh.axis_names or "data" not in mesh.axis_names:
            raise ValueError(f"mesh must carry (data, model) axes, "
                             f"got {mesh.axis_names}")
        self.mesh = mesh
        self.sync = sync
        self.grad_compress = grad_compress
        self.data_degree = mesh.shape["data"]
        self.model_degree = mesh.shape["model"]
        super().__init__(cfg, n_groups=n_groups, redundancy=redundancy,
                         base_lr=base_lr, total_steps=total_steps, **kwargs)
        examples = n_groups * self.pipeline.per_type_batch
        if examples % self.data_degree != 0:
            raise ValueError(
                f"{examples} stacked examples do not divide the data axis "
                f"({self.data_degree}); pick per_type_batch so that "
                f"N*per_type_batch % data == 0")
        # bucketed flat sync: the manual program's per-step gradient
        # reduction is O(n_buckets) collectives (fp32 psum, or the int8
        # EF wire protocol), never one per parameter leaf. The layout is
        # built ONCE, padded to the construction-time DP degree, and
        # kept across elastic reshapes: any shrunken data axis that
        # divides the original degree still tiles every bucket, so EF
        # residuals move between meshes bit-transparently (repro.elastic)
        self._grad_sync = None
        self._ef_state = None
        self._ef_snapshot = None
        self._layout = None
        if sync == "shard_map":
            acc = jnp.dtype(cfg.grad_accum_dtype)
            gtree = jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, acc), self.params)
            self._layout = bucket_layout(
                gtree, max_bucket_elems=max(int(bucket_mb * (1 << 20) // 4),
                                            self.data_degree),
                pad_to=self.data_degree)
        self._bind_mesh(mesh)
        self.params = jax.device_put(self.params, self._pshard)
        self.opt_state = jax.device_put(self.opt_state, self._oshard)
        if grad_compress:
            self._ef_state = jax.device_put(self._grad_sync.init_state(),
                                            self._ef_shard)
        # per-host feeding plumbing: the one-slot double buffer (the
        # builder thread materializes the next step's rows while the
        # dispatched step executes)
        self._feed_pool = ThreadPoolExecutor(max_workers=1)
        self.total_recompiles = 0   # cache misses, run-driven or not
        # live wire accounting: launch/hlo.py's byte audit of the
        # compiled step, memoized per (mesh shape, S_A) cache key
        self._wire_info: dict[tuple, dict] = {}

    def _bind_mesh(self, mesh: jax.sharding.Mesh) -> None:
        """(Re)build every mesh-shape-dependent piece of the step
        plumbing: gradient sync, step fn, param/opt/EF/batch shardings.
        Called at construction and by the elastic reshaper
        (:class:`repro.elastic.ElasticMeshExecutor`) after it swaps the
        mesh for a survivor submesh — the executable cache itself is
        keyed on the mesh shape (:meth:`_cache_key`), so executables for
        other shapes stay warm."""
        self.mesh = mesh
        self.data_degree = mesh.shape["data"]
        self.model_degree = mesh.shape["model"]
        if self.sync == "shard_map":
            if self.grad_compress == "int8_ef":
                self._grad_sync = CompressedBucketSync(
                    self._layout, self.data_degree, "data")
                if self.telemetry is not None and self.telemetry.deep:
                    # deep mode: in-jit per-bucket markers (changes the
                    # compiled program)
                    self._grad_sync.tel = self.telemetry
            else:
                self._grad_sync = BucketedAllReduce(self._layout, "data")
        # the sharded spelling of the step the parent already built: the
        # same pure function, with the named-axis gradient sync when the
        # program is manual
        self._step_fn = make_train_step(
            self.model, base_lr=self._base_lr, total_steps=self.total_steps,
            axis_name="data" if self.sync == "shard_map" else None,
            grad_sync=self._grad_sync)
        if self.sync == "gspmd":
            p_specs = executor_param_specs(self.params, self.model_degree)
        else:   # manual program: per-device replicas, pure DP
            p_specs = jax.tree.map(lambda _: P(), self.params)
        self._pshard = jax.tree.map(
            lambda s: NamedSharding(mesh, s), p_specs)
        self._oshard = type(self.opt_state)(
            step=NamedSharding(mesh, P()),
            mu=jax.tree.map(lambda s: s, self._pshard),
            nu=jax.tree.map(lambda s: s, self._pshard))
        if self.grad_compress:
            self._ef_shard = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                self._grad_sync.state_specs())
        # batch shardings hoisted out of the per-step path
        self._bshard = {k: NamedSharding(mesh, s)
                        for k, s in self._batch_specs().items()}
        self._prefetch: tuple[tuple, Future] | None = None
        self._mesh_grad_fn = None

    # ------------------------------------------------------------- #
    # sharded step plumbing                                         #
    # ------------------------------------------------------------- #
    def _batch_specs(self) -> dict:
        """PartitionSpec per batch leaf: microbatch axis replicated (it
        is scanned), example axis on ``data``."""
        specs = {"labels": P(None, "data", None),
                 "weights": P(None, "data")}
        if self.cfg.frontend is not None:
            specs["embeds"] = P(None, "data", None, None)
        else:
            specs["tokens"] = P(None, "data", None)
        return specs

    def _wrap_step(self, fn):
        """The jit-able sharded step for the configured sync mode."""
        if self.sync == "shard_map":
            in_specs = [P(), P(), self._batch_specs()]
            out_specs = [P(), P(), P()]
            if self.grad_compress:
                ef = self._grad_sync.state_specs()
                in_specs.append(ef)
                out_specs.append(ef)
            return _shard_map(fn, mesh=self.mesh,
                              in_specs=tuple(in_specs),
                              out_specs=tuple(out_specs))
        return fn   # gspmd: sharding comes from jit in/out shardings

    def _cache_key(self, s_a: int) -> tuple[int, int, int]:
        """Executable-cache key: ``(data_degree, model_degree, s_a)``.
        Keying on the mesh shape (not just ``S_A``) lets the elastic
        recovery tier swap in a survivor submesh and back without ever
        invalidating warm executables — a reshape costs exactly one new
        cache entry per (shape, depth) it visits."""
        return (self.data_degree, self.model_degree, s_a)

    def _compiled(self, s_a: int, report: TrainReport | None = None):
        # Donation contract (analyzer-enforced): params, opt_state, and —
        # under int8_ef — the EF residuals are donated, and every donated
        # leaf must surface as an input/output alias in the compiled
        # module. ``python -m repro.launch.lint`` replays this jit site
        # via ``compiled_step_text`` and fails CI on any unaliased
        # donated buffer (repro.analysis donation-audit pass).
        key = self._cache_key(s_a)
        if key not in self._jitted:
            out_shardings = ((self._pshard, self._oshard, None)
                             if self.sync == "gspmd" else None)
            donate = (0, 1, 3) if self.grad_compress else (0, 1)
            self._jitted[key] = jax.jit(self._wrap_step(self._step_fn),
                                        out_shardings=out_shardings,
                                        donate_argnums=donate)
            # total_recompiles is the order-independent count (HLO
            # inspection can warm the cache outside any run); a run's
            # report counts only the compiles that run triggered
            self.total_recompiles += 1
            if report is not None:
                report.recompiles += 1
            if self.telemetry is not None:
                self.telemetry.counter("train.recompiles").inc()
        return self._jitted[key]

    # ------------------------------------------------------------- #
    # per-host input feeding                                        #
    # ------------------------------------------------------------- #
    def _batch_shapes(self, s_a: int) -> dict[str, tuple[int, ...]]:
        e = self.state.n * self.pipeline.per_type_batch
        seq = self.pipeline.seq
        shapes = {"labels": (s_a, e, seq), "weights": (s_a, e)}
        if self.cfg.frontend is not None:
            shapes["embeds"] = (s_a, e, seq, self.cfg.d_model)
        else:
            shapes["tokens"] = (s_a, e, seq)
        return shapes

    def _feed_ranges(self, s_a: int) -> list[tuple[int, int]]:
        """Example-row ranges [lo, hi) this host must materialize — the
        union of its addressable shards of the example axis."""
        shape = self._batch_shapes(s_a)["weights"]
        imap = self._bshard["weights"].addressable_devices_indices_map(shape)
        ranges = set()
        for idx in imap.values():
            sl = idx[1]
            ranges.add((sl.start or 0,
                        shape[1] if sl.stop is None else sl.stop))
        return sorted(ranges)

    def _host_slabs(self, schedule, s_a: int, step: int,
                    ranges: list[tuple[int, int]]) -> dict:
        """Materialize only this host's example rows: {(lo, hi) -> np
        batch dict}. Runs on the builder thread for the prefetched step;
        ``ranges`` is snapshotted by the caller (``_feed_ranges`` reads
        the mesh-shape-dependent batch shardings, which an elastic
        reshape rebinds)."""
        return {(lo, hi): spare_batch_rows(self.pipeline, schedule, s_a,
                                           step, lo, hi)
                for lo, hi in ranges}

    def _place_slabs(self, s_a: int, slabs: dict) -> dict:
        """Assemble the sharded global batch without ever materializing
        it: each addressable shard's callback serves a view of the slab
        covering its rows."""
        shapes = self._batch_shapes(s_a)

        def maker(key):
            shape = shapes[key]

            def cb(index):
                sl = index[1]
                lo = sl.start or 0
                hi = shape[1] if sl.stop is None else sl.stop
                for (rlo, rhi), slab in slabs.items():
                    if rlo <= lo and hi <= rhi:
                        rows = slice(lo - rlo, hi - rlo)
                        return slab[key][(index[0], rows) + tuple(index[2:])]
                raise KeyError(f"no host slab covers rows [{lo}, {hi})")

            return jax.make_array_from_callback(shape, self._bshard[key], cb)

        return {k: maker(k) for k in shapes}

    def _batch_key(self, state, step: int):
        """Prefetch identity: the batch is a pure function of (step,
        schedule). The schedule arrays are snapshotted so the builder
        thread never reads mutable trainer state."""
        stack_types, wts = state.device_schedule()
        key = (step, state.s_a, stack_types.tobytes(), wts.tobytes())
        return key, (stack_types, wts)

    def _device_batch(self, step: int | None = None, state=None) -> dict:
        state = self.state if state is None else state
        step = self.step if step is None else step
        key, schedule = self._batch_key(state, step)
        tel = self.telemetry
        hit = False
        with maybe_span(tel, "feed"):
            slabs = None
            if self._prefetch is not None:
                pkey, fut = self._prefetch
                self._prefetch = None
                if pkey == key:
                    slabs = fut.result()
                    hit = True
                # else: a failure re-planned the schedule (or the caller
                # asked for a different step) — the prefetched rows are
                # stale; drop them and build synchronously
            if slabs is None:
                slabs = self._host_slabs(schedule, state.s_a, step,
                                         self._feed_ranges(state.s_a))
            out = self._place_slabs(state.s_a, slabs)
        if tel is not None:
            tel.counter("feed.prefetch_hits" if hit
                        else "feed.prefetch_misses").inc()
        return out

    def _prefetch_next(self):
        """Double buffer: queue the NEXT step's row materialization on
        the builder thread while the current step executes on device."""
        key, schedule = self._batch_key(self.state, self.step + 1)
        self._prefetch = (key, self._feed_pool.submit(
            self._host_slabs, schedule, self.state.s_a, self.step + 1,
            self._feed_ranges(self.state.s_a)))

    def _dispatch(self, report: TrainReport):
        batch = self._device_batch()
        fn = self._compiled(self.state.s_a, report)
        if self.grad_compress:
            out = fn(self.params, self.opt_state, batch, self._ef_state)
            params, opt_state, metrics, self._ef_state = out
            result = (params, opt_state, metrics)
        else:
            result = fn(self.params, self.opt_state, batch)
        # the step is dispatched (async); overlap the next batch build
        self._prefetch_next()
        if self.telemetry is not None:
            self._observe_sync(self.telemetry)
        return result

    def _observe_sync(self, tel) -> None:
        """Publish the per-step gradient-sync wire accounting as live
        metrics: ``launch/hlo.py``'s byte audit of the compiled step,
        memoized per ``S_A`` (the executable is already compiled when
        this runs, so the one-time lowering cost per depth is the only
        overhead — steady-state steps just bump a counter). Deep mode
        adds the int8-EF residual norms, which synchronize the device."""
        key = self._cache_key(self.state.s_a)
        info = self._wire_info.get(key)
        if info is None:
            from repro.launch.hlo import collective_report
            # compiled_step_text builds its own batch — keep the live
            # run's prefetched slabs out of its reach
            saved, self._prefetch = self._prefetch, None
            try:
                text = self.compiled_step_text()
            finally:
                self._prefetch = saved
            info = self._wire_info[key] = collective_report(text)
        tel.gauge("sync.wire_bytes_per_step").set(info["total_bytes"])
        tel.gauge("sync.collectives_per_step").set(
            int(sum(info["counts"].values())))
        tel.counter("sync.wire_bytes_total").inc(info["total_bytes"])
        if tel.deep and self._ef_state is not None:
            for fam in ("err1", "err2"):
                sq = sum(float(jnp.vdot(b, b))
                         for b in self._ef_state[fam])
                tel.gauge(f"sync.ef_residual_norm.{fam}").set(sq ** 0.5)

    def run(self, *args, **kwargs):
        try:
            return super().run(*args, **kwargs)
        finally:
            # the last dispatched step speculatively built rows for a
            # step that will never execute — do not pin those slabs
            self._prefetch = None

    def close(self) -> None:
        """Release the feeding plumbing (builder thread + any pending
        prefetched slabs). The executor stays usable for HLO inspection
        but must not dispatch further steps."""
        self._prefetch = None
        self._feed_pool.shutdown(wait=False)

    # ------------------------------------------------------------- #
    # snapshot / rollback (EF residuals ride along)                 #
    # ------------------------------------------------------------- #
    def _snapshot_now(self) -> None:
        super()._snapshot_now()
        if self._ef_state is not None:
            self._ef_snapshot = jax.tree.map(np.asarray, self._ef_state)

    def _rollback(self):
        """Wipe-out restore: the snapshot tiers hand back host arrays —
        re-place them under the mesh shardings before training resumes.
        The EF residuals roll back to the same step as params (the
        untransmitted signal belongs to the discarded trajectory)."""
        step, (params, opt_state) = super()._rollback()
        if self._ef_snapshot is not None:
            self._ef_state = jax.device_put(self._ef_snapshot,
                                            self._ef_shard)
        return step, (jax.device_put(params, self._pshard),
                      jax.device_put(opt_state, self._oshard))

    # ------------------------------------------------------------- #
    # gradient oracle (mesh spelling)                               #
    # ------------------------------------------------------------- #
    def mesh_grads(self, step: int | None = None, state=None):
        """Total-batch gradient of the given (default: current) schedule
        computed BY THE MESH: the sharded forward/backward with the
        per-step gradient sync. The §3.1 oracle for mesh-vs-host
        equivalence — must match :meth:`SpareTrainer.spare_grads` (same
        params, same deterministic batch) up to all-reduce
        summation-order noise (plus one step's bounded quantization
        error when ``grad_compress`` is on — zero EF residuals, see
        ``exec/equivalence.py::int8_sweep_tolerance``)."""
        if self._mesh_grad_fn is None:
            model = self.model
            axis = "data" if self.sync == "shard_map" else None
            sync = self._grad_sync

            def total_loss(params, batch):
                def body(acc, micro):
                    return acc + weighted_loss(model, params, micro,
                                               axis_name=axis), None
                out, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                      batch)
                return out

            def grads(params, batch):
                g = jax.grad(total_loss)(params, batch)
                if axis is None:
                    return g
                if self.grad_compress:
                    return sync.sync_once(g)
                return sync(g)

            if self.sync == "shard_map":
                fn = _shard_map(grads, mesh=self.mesh,
                                in_specs=(P(), self._batch_specs()),
                                out_specs=P())
                self._mesh_grad_fn = jax.jit(fn)
            else:
                self._mesh_grad_fn = jax.jit(
                    grads, out_shardings=self._pshard)
        batch = self._device_batch(step, state)
        return self._mesh_grad_fn(self.params, batch)

    # ------------------------------------------------------------- #
    # HLO inspection (the zero-extra-collectives proof)             #
    # ------------------------------------------------------------- #
    def compiled_step_text(self, state=None) -> str:
        """Post-SPMD HLO of the step for the given (default: current)
        schedule — feed to :func:`repro.launch.hlo.collective_report` to
        count the sync collectives masked vs unmasked. Routed through
        the per-``S_A`` ``_jitted`` cache, so repeated calls (and the
        live run) share one executable per stack depth; a cache warm-up
        here counts toward ``total_recompiles`` but not toward any
        run's ``report.recompiles``."""
        state = self.state if state is None else state
        batch = self._device_batch(state=state)
        fn = self._compiled(state.s_a)
        args = [self.params, self.opt_state, batch]
        if self.grad_compress:
            args.append(self._ef_state)
        return fn.lower(*args).compile().as_text()

    def prewarm_depths(self, depths) -> None:
        """Compile the step executable for each stack depth in
        ``depths`` ahead of need. A SPARe demotion on a cyclic Golomb
        hosting typically forces ``S_A`` one deeper (the supplier
        reassignment cascades around the hosting cycle), so a
        latency-sensitive run warms both depths up front and the
        demote itself is a pure weight-table edit — zero
        run-attributed recompiles, like any mask at constant shape.
        Warm-up compiles count toward ``total_recompiles`` only (the
        :meth:`compiled_step_text` contract)."""
        import copy
        probe = copy.deepcopy(self.state)
        for s_a in sorted(set(int(d) for d in depths)):
            if not 1 <= s_a <= self.state.r:
                raise ValueError(f"stack depth {s_a} outside "
                                 f"[1, r={self.state.r}]")
            probe.s_a = s_a
            self.compiled_step_text(state=probe)

    def donated_leaves(self) -> int:
        """Flat leaf count across the step's donated argnums — the
        number of input/output aliases the donation-audit pass expects
        in :meth:`compiled_step_text`'s module header."""
        n = len(jax.tree_util.tree_leaves(self.params)) + \
            len(jax.tree_util.tree_leaves(self.opt_state))
        if self.grad_compress:
            n += len(jax.tree_util.tree_leaves(self._ef_state))
        return n

    @property
    def compiled_depths(self) -> list[int]:
        """S_A depths with a live compiled executable for the CURRENT
        mesh shape — a failure re-weight at constant S_A must not grow
        this. Executables compiled for other mesh shapes (elastic
        reshapes) live under their own keys; see :attr:`cache_keys`."""
        shape = (self.data_degree, self.model_degree)
        return sorted(s_a for (d, m, s_a) in self._jitted
                      if (d, m) == shape)

    @property
    def cache_keys(self) -> list[tuple[int, int, int]]:
        """Every live executable-cache key, ``(data, model, s_a)`` —
        the full picture across mesh shapes the run has visited."""
        return sorted(self._jitted)
