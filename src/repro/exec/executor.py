"""MeshExecutor — SPARe's Alg. 1 running on a real SPMD device mesh.

:class:`MeshExecutor` is :class:`repro.train.trainer.SpareTrainer` with
the device plane swapped from one-process emulation to a sharded program
over a ``(data, model)`` mesh (:func:`repro.launch.mesh
.make_emulated_mesh` / :func:`~repro.launch.mesh.make_production_mesh`).
Two sync spellings of the same pure ``make_train_step`` are supported:

* ``sync="shard_map"`` (default) — the §3.1 wire protocol made explicit:
  manual ``shard_map`` over the mesh, one SPARe DP group per ``data``
  slice, supplier-weighted local gradients psummed ONCE per step via
  ``weighted_all_reduce(..., axis_name="data")`` +
  :func:`~repro.dist.collectives.all_reduce_grads`. Per-device
  parameters are replicas (pure DP), which keeps the manual program
  free of tensor-parallel collectives.
* ``sync="gspmd"`` — the dry-run's production spelling: ``jit`` with
  NamedShardings, parameters/Adam moments sharded on ``model``, the
  stacked batch on ``data``; GSPMD derives the identical all-reduce
  from the batch-sharded weighted contraction. (The mixed
  manual-data/auto-model ``shard_map`` would unify the two, but XLA's
  partial-manual subgroup handling hard-crashes on scanned+remat
  programs in the pinned toolchain — ``IsManualSubgroup`` check — so
  the executor keeps the two proven paths instead.)

Failure masking is identical in both: recovery is pure weight-table
data. After ``scheme.recover`` re-plans the schedule, the next step
feeds the new ``SpareState.device_schedule()`` weights through the
batch — no resharding, no new collectives, no recompile (executables
are cached per ``S_A``). The paper's zero-extra-collectives property is
asserted on compiled HLO in ``tests/test_exec.py``, and the whole
:class:`~repro.train.injection.ScenarioInjector` bridge is inherited,
so rack/pod burst events from the scenario engine re-weight the live
mesh step mid-run.

Runs anywhere: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
fans a CPU host out into 8 emulated devices executing the same SPMD
program (partitioner, collectives, HLO) a TPU pod would run.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.data import spare_batch
from repro.launch.mesh import make_emulated_mesh
from repro.models.config import ModelConfig
from repro.train.step import make_train_step, weighted_loss
from repro.train.trainer import SpareTrainer, TrainReport

try:  # moved to jax.shard_map in newer releases
    from jax.experimental.shard_map import shard_map as _shard_map_raw
except ImportError:  # pragma: no cover - future jax
    _shard_map_raw = jax.shard_map


def _shard_map(fn, *, mesh, in_specs, out_specs):
    """shard_map across jax versions: the replication checker flag was
    renamed ``check_rep`` -> ``check_vma``; disable it under either name
    (the executor's out_specs declare replication the checker cannot
    prove through psum/custom_vjp)."""
    try:
        return _shard_map_raw(fn, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)
    except TypeError:  # pragma: no cover - newer jax
        return _shard_map_raw(fn, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)


__all__ = ["MeshExecutor", "executor_param_specs"]

_SYNCS = ("shard_map", "gspmd")


def executor_param_specs(params, model_degree: int):
    """Model-axis specs for the gspmd layout: every matrix whose last dim
    divides the TP degree is column-sharded on ``model``; everything else
    (norm scales, ragged leaves) is replicated. All leaves are replicated
    across ``data`` — that axis carries the stacked batch and its
    all-reduced gradients, exactly vanilla DP + SPARe weights."""

    def spec(leaf):
        if leaf.ndim >= 2 and leaf.shape[-1] % model_degree == 0:
            return P(*(None,) * (leaf.ndim - 1), "model")
        return P()

    return jax.tree.map(spec, params)


class MeshExecutor(SpareTrainer):
    """Drop-in :class:`SpareTrainer` whose step runs sharded on a mesh.

    Extra parameters on top of the trainer's:

    mesh: a ``(data, model)`` mesh to run on; by default an emulated one
        with ``data == n_groups`` slices (requires
        ``n_groups * model_degree`` visible devices).
    model_degree: tensor-parallel degree of the default mesh (gspmd
        sync; the manual shard_map program treats model columns as
        replicas).
    sync: ``"shard_map"`` (explicit psum) or ``"gspmd"`` (NamedShardings,
        params on the model axis) — see the module docstring.
    """

    def __init__(self, cfg: ModelConfig, *, n_groups: int, redundancy: int,
                 mesh: jax.sharding.Mesh | None = None,
                 model_degree: int = 1, sync: str = "shard_map",
                 base_lr: float = 3e-4, total_steps: int = 1000,
                 **kwargs: Any):
        if sync not in _SYNCS:
            raise ValueError(f"sync must be one of {_SYNCS}, got {sync!r}")
        if mesh is None:
            mesh = make_emulated_mesh(n_groups, model_degree)
        if "model" not in mesh.axis_names or "data" not in mesh.axis_names:
            raise ValueError(f"mesh must carry (data, model) axes, "
                             f"got {mesh.axis_names}")
        self.mesh = mesh
        self.sync = sync
        self.data_degree = mesh.shape["data"]
        self.model_degree = mesh.shape["model"]
        super().__init__(cfg, n_groups=n_groups, redundancy=redundancy,
                         base_lr=base_lr, total_steps=total_steps, **kwargs)
        examples = n_groups * self.pipeline.per_type_batch
        if examples % self.data_degree != 0:
            raise ValueError(
                f"{examples} stacked examples do not divide the data axis "
                f"({self.data_degree}); pick per_type_batch so that "
                f"N*per_type_batch % data == 0")
        # the sharded spelling of the step the parent already built: the
        # same pure function, with the named-axis gradient sync when the
        # program is manual
        self._step_fn = make_train_step(
            self.model, base_lr=base_lr, total_steps=total_steps,
            axis_name="data" if sync == "shard_map" else None)
        if sync == "gspmd":
            p_specs = executor_param_specs(self.params, self.model_degree)
        else:   # manual program: per-device replicas, pure DP
            p_specs = jax.tree.map(lambda _: P(), self.params)
        self._pshard = jax.tree.map(
            lambda s: NamedSharding(mesh, s), p_specs)
        self._oshard = type(self.opt_state)(
            step=NamedSharding(mesh, P()),
            mu=jax.tree.map(lambda s: s, self._pshard),
            nu=jax.tree.map(lambda s: s, self._pshard))
        self.params = jax.device_put(self.params, self._pshard)
        self.opt_state = jax.device_put(self.opt_state, self._oshard)
        self._mesh_grad_fn = None

    # ------------------------------------------------------------- #
    # sharded step plumbing                                         #
    # ------------------------------------------------------------- #
    def _batch_specs(self) -> dict:
        """PartitionSpec per batch leaf: microbatch axis replicated (it
        is scanned), example axis on ``data``."""
        specs = {"labels": P(None, "data", None),
                 "weights": P(None, "data")}
        if self.cfg.frontend is not None:
            specs["embeds"] = P(None, "data", None, None)
        else:
            specs["tokens"] = P(None, "data", None)
        return specs

    def _wrap_step(self, fn):
        """The jit-able sharded step for the configured sync mode."""
        if self.sync == "shard_map":
            return _shard_map(fn, mesh=self.mesh,
                              in_specs=(P(), P(), self._batch_specs()),
                              out_specs=(P(), P(), P()))
        return fn   # gspmd: sharding comes from jit in/out shardings

    def _compiled(self, s_a: int, report: TrainReport):
        if s_a not in self._jitted:
            out_shardings = ((self._pshard, self._oshard, None)
                             if self.sync == "gspmd" else None)
            self._jitted[s_a] = jax.jit(self._wrap_step(self._step_fn),
                                        out_shardings=out_shardings,
                                        donate_argnums=(0, 1))
            report.recompiles += 1
        return self._jitted[s_a]

    def _device_batch(self, step: int | None = None, state=None) -> dict:
        state = self.state if state is None else state
        step = self.step if step is None else step
        batch_np = spare_batch(self.pipeline, state, step)
        specs = self._batch_specs()
        return {k: jax.device_put(jnp.asarray(v),
                                  NamedSharding(self.mesh, specs[k]))
                for k, v in batch_np.items()}

    def _dispatch(self, report: TrainReport):
        batch = self._device_batch()
        fn = self._compiled(self.state.s_a, report)
        return fn(self.params, self.opt_state, batch)

    def _rollback(self):
        """Wipe-out restore: the snapshot tiers hand back host arrays —
        re-place them under the mesh shardings before training resumes."""
        step, (params, opt_state) = super()._rollback()
        return step, (jax.device_put(params, self._pshard),
                      jax.device_put(opt_state, self._oshard))

    # ------------------------------------------------------------- #
    # gradient oracle (mesh spelling)                               #
    # ------------------------------------------------------------- #
    def mesh_grads(self, step: int | None = None, state=None):
        """Total-batch gradient of the given (default: current) schedule
        computed BY THE MESH: the sharded forward/backward with the
        per-step gradient sync. The §3.1 oracle for mesh-vs-host
        equivalence — must match :meth:`SpareTrainer.spare_grads` (same
        params, same deterministic batch) up to all-reduce
        summation-order noise."""
        if self._mesh_grad_fn is None:
            model = self.model
            axis = "data" if self.sync == "shard_map" else None

            def total_loss(params, batch):
                def body(acc, micro):
                    return acc + weighted_loss(model, params, micro,
                                               axis_name=axis), None
                out, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                      batch)
                return out

            def grads(params, batch):
                g = jax.grad(total_loss)(params, batch)
                if axis is not None:
                    from repro.dist.collectives import all_reduce_grads
                    g = all_reduce_grads(g, axis)
                return g

            if self.sync == "shard_map":
                fn = _shard_map(grads, mesh=self.mesh,
                                in_specs=(P(), self._batch_specs()),
                                out_specs=P())
                self._mesh_grad_fn = jax.jit(fn)
            else:
                self._mesh_grad_fn = jax.jit(
                    grads, out_shardings=self._pshard)
        batch = self._device_batch(step, state)
        return self._mesh_grad_fn(self.params, batch)

    # ------------------------------------------------------------- #
    # HLO inspection (the zero-extra-collectives proof)             #
    # ------------------------------------------------------------- #
    def compiled_step_text(self, state=None) -> str:
        """Post-SPMD HLO of the step for the given (default: current)
        schedule — feed to :func:`repro.launch.hlo.collective_report` to
        count the sync collectives masked vs unmasked."""
        state = self.state if state is None else state
        batch = self._device_batch(state=state)
        out_shardings = ((self._pshard, self._oshard, None)
                         if self.sync == "gspmd" else None)
        fn = jax.jit(self._wrap_step(self._step_fn),
                     out_shardings=out_shardings)
        return fn.lower(self.params, self.opt_state, batch) \
                 .compile().as_text()

    @property
    def compiled_depths(self) -> list[int]:
        """S_A depths with a live compiled executable (cache keys) — a
        failure re-weight at constant S_A must not grow this."""
        return sorted(self._jitted)
