"""repro.exec — SPMD mesh execution of the SPARe protocol.

The emulated :class:`~repro.train.trainer.SpareTrainer` proves the
protocol; this package runs it for real: :class:`MeshExecutor` places
the model on a ``(data, model)`` mesh, executes the train step under
``shard_map`` with the §3.1 weighted psum on the wire, and applies
failure masking as pure weight-table updates — zero extra collectives,
zero recompiles per survivor set. Works on any machine via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (see README
§repro.exec).
"""
from .equivalence import (
    SurvivorCheck,
    int8_sweep_tolerance,
    recoverable_failure_sets,
    survivor_set_sweep,
    tree_max_rel_err,
)
from .executor import MeshExecutor, executor_param_specs

__all__ = [
    "MeshExecutor",
    "SurvivorCheck",
    "executor_param_specs",
    "int8_sweep_tolerance",
    "recoverable_failure_sets",
    "survivor_set_sweep",
    "tree_max_rel_err",
]
