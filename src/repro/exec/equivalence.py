"""§3.1 gradient-equivalence verification: mesh vs host, every survivor set.

The paper's core invariant says the supplier-weighted all-reduce collects
vanilla DP's exact batch gradient for *every* survivor set the recovery
controller can mask. The emulated trainer property-tests this host-side;
this module closes the loop for the real SPMD path: for each recoverable
failure set it re-plans the schedule with RECTLR, renders the weight
table, and compares the ``shard_map`` mesh gradient against the
host-side oracle of a reference :class:`~repro.train.trainer
.SpareTrainer` built from the same seed (identical params, identical
deterministic batches).
"""
from __future__ import annotations

from dataclasses import dataclass
from itertools import combinations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Rectlr, SpareState

__all__ = ["SurvivorCheck", "recoverable_failure_sets",
           "tree_max_rel_err", "survivor_set_sweep",
           "int8_sweep_tolerance"]


def int8_sweep_tolerance(dp_degree: int, kappa: float = 4.0) -> float:
    """Quantization-tolerance oracle for the §3.1 sweep under
    ``grad_compress="int8_ef"``.

    With zero EF residuals (the sweep's stateless ``sync_once``), one
    compressed step's elementwise error is bounded by the sum of the
    quantization steps: ``dp`` stage-1 scales (each ``<= kappa *
    max|g_total| / 127``, where ``kappa`` bounds the local-partial to
    total absmax ratio — partial sums can exceed their total under
    cancellation, ~<= 4 in practice for the weighted-CE gradients) plus
    one stage-2 scale, each contributing at most half a step. Relative
    to ``max|g_total|`` that is ``kappa * (dp + 1) / 254`` — ~8% at
    ``dp=4``. The *training* path is much tighter than this single-step
    bound: error feedback cancels the bias cumulatively
    (tests/test_int8_ef.py), and the sweep only certifies that the
    compressed wire protocol reduces to the §3.1 weighted sum.
    """
    return kappa * (dp_degree + 1) / 254.0


@dataclass
class SurvivorCheck:
    """One survivor set's verdict."""

    victims: tuple[int, ...]
    s_a: int
    mesh_vs_host: float       # max rel err, mesh grads vs host SPARe grads
    mesh_vs_vanilla: float    # max rel err, mesh grads vs vanilla-DP oracle

    def ok(self, tol: float) -> bool:
        return self.mesh_vs_host <= tol and self.mesh_vs_vanilla <= tol


def recoverable_failure_sets(n: int, r: int, max_failures: int | None = None):
    """Every failure set RECTLR can mask (wipe-outs excluded), as the
    state it recovers into. Yields ``(victims, recovered_state)``.

    The full enumeration is ``sum_k C(n, k)`` — fine for the test-scale
    meshes (n <= 8); cap with ``max_failures`` for larger sweeps.
    """
    cap = n - 1 if max_failures is None else min(max_failures, n - 1)
    for k in range(1, cap + 1):
        for victims in combinations(range(n), k):
            state = SpareState(n, r)
            outcome = Rectlr().on_failures(state, list(victims))
            if outcome.wipeout:
                continue
            state.assert_invariants()
            yield victims, state


def tree_max_rel_err(got, ref) -> float:
    """``max |got - ref| / max(max |ref|, 1)`` over all leaves, fp32."""
    diff = jax.tree.reduce(max, jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        got, ref))
    scale = jax.tree.reduce(max, jax.tree.map(
        lambda a: float(jnp.abs(a.astype(jnp.float32)).max()), ref))
    return diff / max(scale, 1.0)


def survivor_set_sweep(executor, reference, *, step: int = 0,
                       max_failures: int | None = None
                       ) -> list[SurvivorCheck]:
    """Run the full survivor-set enumeration through the mesh.

    ``executor`` is a :class:`repro.exec.MeshExecutor`; ``reference`` a
    :class:`~repro.train.trainer.SpareTrainer` constructed with the same
    config/seed (so both hold bit-identical parameters). For every
    recoverable failure set the mesh gradient is checked against both
    the host-side SPARe gradient under the same schedule and the
    vanilla-DP oracle.
    """
    n, r = executor.state.n, executor.state.r
    vanilla = _as_host(reference.vanilla_reference_grads(step))
    checks = []
    for victims, state in recoverable_failure_sets(n, r, max_failures):
        mesh = _as_host(executor.mesh_grads(step, state=state))
        saved = reference.state
        reference.state = state
        try:
            host = _as_host(reference.spare_grads(step))
        finally:
            reference.state = saved
        checks.append(SurvivorCheck(
            victims=victims, s_a=state.s_a,
            mesh_vs_host=tree_max_rel_err(mesh, host),
            mesh_vs_vanilla=tree_max_rel_err(mesh, vanilla)))
    return checks


def _as_host(tree):
    return jax.tree.map(np.asarray, tree)
