"""Mesh-shrink geometry + state movement for elastic recovery.

Pure helpers under :class:`repro.elastic.ElasticMeshExecutor`:

* :func:`shrink_degree` — the DP degree a survivor set can continue at.
  The new degree must divide the ORIGINAL degree: the executor's bucket
  layout is padded to the construction-time DP (``bucket_layout(...,
  pad_to=dp)``), so any divisor still tiles every bucket and the
  compressed sync's chunk math holds without re-laying-out gradients;
* :func:`survivor_submesh` — the ``(data, model)`` submesh over the kept
  physical data rows of the full mesh;
* :func:`reshard_tree` — move a pytree onto another mesh's shardings
  (``jax.device_put`` resharding transfer; bit-transparent round trip,
  proven in ``tests/test_elastic.py``);
* :func:`remap_ef_rows` — EF residuals are the one piece of state whose
  GLOBAL shape depends on the DP degree (``err1[b]`` is ``dp * B`` flat,
  one ``B``-slice per data row). Each slice follows its physical device
  row across mesh shapes; rows (re)joining the mesh start at zero
  residual (their untransmitted signal belonged to a retired trajectory).
"""
from __future__ import annotations

import jax
import numpy as np

__all__ = ["shrink_degree", "survivor_submesh", "reshard_tree",
           "remap_ef_rows"]


def shrink_degree(full_degree: int, n_survivors: int) -> int:
    """Largest divisor of ``full_degree`` that is <= ``n_survivors``
    (0 when no positive degree fits — nothing survived)."""
    best = 0
    for d in range(1, min(int(full_degree), int(n_survivors)) + 1):
        if full_degree % d == 0:
            best = d
    return best


def survivor_submesh(full_mesh: jax.sharding.Mesh,
                     rows) -> jax.sharding.Mesh:
    """Submesh over the given physical ``data`` rows of the full mesh
    (every ``model`` column of each kept row rides along)."""
    idx = np.asarray(rows, dtype=np.int64)
    if idx.size == 0:
        raise ValueError("survivor submesh needs at least one data row")
    return jax.sharding.Mesh(np.asarray(full_mesh.devices)[idx],
                             full_mesh.axis_names)


def reshard_tree(tree, shardings):
    """Place ``tree`` under ``shardings`` (a matching pytree of
    :class:`~jax.sharding.NamedSharding`), moving data across meshes.
    Values are preserved bit-for-bit — only placement changes."""
    return jax.device_put(tree, shardings)


def remap_ef_rows(ef: dict, bucket_sizes, old_rows, new_rows) -> dict:
    """Re-slot ``err1`` device-row slices from ``old_rows`` (physical
    data-row ids backing each logical row of the source layout) to
    ``new_rows`` (ditto, target layout). ``err2`` is chunk-owner state
    with a dp-independent global shape and passes through unchanged."""
    old_pos = {int(p): i for i, p in enumerate(old_rows)}
    err1 = []
    for b, size in enumerate(bucket_sizes):
        buf = np.asarray(ef["err1"][b]).reshape(len(old_pos), size)
        out = np.zeros((len(new_rows), size), np.float32)
        for i, p in enumerate(new_rows):
            j = old_pos.get(int(p))
            if j is not None:
                out[i] = buf[j]
        err1.append(out.reshape(-1))
    return {"err1": tuple(err1),
            "err2": tuple(np.asarray(e) for e in ef["err2"])}
