"""Closed-form TTT policy for unmaskable failure sets.

When RECTLR reports a wipe-out — some shard type lost every surviving
host — the run has two ways to reach the end of training:

* **restart**: pay the cluster restart outage ``t_restart``, roll back
  ``rollback_steps`` to the last snapshot, and re-run them plus the
  remaining steps at full DP speed;
* **reshape**: pay the online resharding outage ``t_reshape`` and finish
  the remaining steps degraded on a survivor submesh at DP degree
  ``dp_new`` < ``dp_full``.

Per-device load is constant across mesh shapes (each group computes the
same per-type microbatch), so a degraded step takes the same wall time
but covers only ``dp_new / dp_full`` of a full step's examples. Equal
*work* therefore costs ``dp_full / dp_new`` more degraded steps — the
paper's time-to-train trade-off reduced to one comparison:

    TTT_reshape = t_reshape + R * sps * (dp_full / dp_new)
    TTT_restart = t_restart + (rollback + R) * sps

with ``R`` remaining steps and ``sps`` seconds per (full) step. The
adaptive scheme (:meth:`repro.des.schemes.AdaptiveScheme
.decide_unmaskable`) and :class:`repro.elastic.ElasticMeshExecutor`'s
built-in fallback both evaluate exactly this estimate per event.
"""
from __future__ import annotations

__all__ = ["ttt_estimates"]


def ttt_estimates(*, dp_full: int, dp_new: int, remaining_steps: int,
                  seconds_per_step: float, rollback_steps: int = 0,
                  t_restart: float, t_reshape: float) -> dict:
    """Both candidates' time-to-train and the argmin ``action``.

    ``dp_full`` is the degree a restart comes back at (the full mesh);
    ``dp_new`` the degree the reshape would continue at (0 = cannot
    continue, forces restart). Ties go to reshape — it keeps the warm
    executable cache and loses no optimizer steps.
    """
    sps = float(seconds_per_step)
    work = float(remaining_steps) * sps
    reshape_ttt = (float(t_reshape) + work * (float(dp_full) / dp_new)
                   if dp_new > 0 else float("inf"))
    restart_ttt = float(t_restart) + \
        (float(rollback_steps) + float(remaining_steps)) * sps
    return {
        "action": "reshape" if reshape_ttt <= restart_ttt else "restart",
        "reshape_ttt": reshape_ttt,
        "restart_ttt": restart_ttt,
        "dp_full": int(dp_full),
        "dp_new": int(dp_new),
        "remaining_steps": int(remaining_steps),
        "rollback_steps": int(rollback_steps),
        "seconds_per_step": sps,
        "t_restart": float(t_restart),
        "t_reshape": float(t_reshape),
    }
