"""ElasticMeshExecutor — degraded-continue between masking and restart.

The recovery ladder so far had two rungs: SPARe masking (free — weight
table data, zero recompiles) and wipe-out restart (t_restart + rollback
rework). This executor adds the middle rung the ROADMAP names after
ElasWave / Nonuniform-TP: when RECTLR reports an UNMASKABLE failure set,
shrink the data-parallel degree onto the surviving devices and keep
training, instead of restarting the world.

Mechanics, in the order a reshape applies them:

1. **decide** — :meth:`_unmaskable_action` evaluates the closed-form TTT
   comparison (:mod:`repro.elastic.policy`) per event, preferring the
   scheme's own :meth:`~repro.des.schemes.AdaptiveScheme
   .decide_unmaskable` when the scheme implements it (the live policy
   tier of the Chameleon-style selector);
2. **shrink** — :meth:`reshape` picks the largest divisor of the
   original DP degree that fits the survivor count (divisors keep the
   construction-time bucket layout tiling — see
   :func:`repro.elastic.reshard.shrink_degree`), builds the survivor
   submesh, re-binds every mesh-shape-dependent piece of the step
   plumbing (:meth:`~repro.exec.executor.MeshExecutor._bind_mesh`), and
   starts a fresh :class:`~repro.core.state.SpareState` at the new shape;
3. **move** — params and Adam moments ``jax.device_put`` onto the
   shrunken mesh's NamedShardings (bit-transparent; shapes are mesh
   independent). EF residuals are the exception: ``err1``'s global shape
   is ``dp * B`` per bucket, so each device row's slice follows its
   physical row through :func:`~repro.elastic.reshard.remap_ef_rows`;
4. **account** — the trainer threads a ``reshape`` outcome through
   :class:`~repro.train.trainer.RecoveryEvent`, the injector's outage
   clock (``notify_outage(t_reshape, kind="reshape")`` — the arrival
   model keeps running: surviving hardware stays powered), and the
   ``launch.obs`` recovery-attribution table.

Executables for other mesh shapes stay warm — the cache is keyed on
``(data_degree, model_degree, S_A)`` — so a reshape costs exactly one
new cache entry per (shape, depth) visited, and a later global restart
(:meth:`_global_restart`) returns to the full mesh with its original
executables still compiled.

Physical vs logical ids: injectors are constructed against the FULL
cluster and keep delivering victims in that space. The executor polls
them with a physical survivor view and translates each event through
the live ``physical row -> logical group`` map; events landing on
retired (healthy-but-unused) rows dissolve to no-ops.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

from repro.core.state import SpareState
from repro.elastic.policy import ttt_estimates
from repro.elastic.reshard import (remap_ef_rows, shrink_degree,
                                   survivor_submesh)
from repro.exec.executor import MeshExecutor
from repro.models.config import ModelConfig
from repro.train.trainer import RecoveryEvent, TrainReport

__all__ = ["ElasticMeshExecutor"]


class _PhysicalView:
    """Just enough of the :class:`SpareState` survivor surface for the
    injector protocols (``poll(state)`` reads ``alive``; plain callables
    read ``survivors``), expressed in PHYSICAL group space — the full
    cluster the injector was constructed against, regardless of what
    submesh training currently runs on."""

    __slots__ = ("alive",)

    def __init__(self, alive: np.ndarray):
        self.alive = alive

    @property
    def n(self) -> int:
        return int(self.alive.size)

    @property
    def survivors(self) -> np.ndarray:
        return np.flatnonzero(self.alive)

    @property
    def failure_count(self) -> int:
        return int(self.alive.size - self.alive.sum())


class ElasticMeshExecutor(MeshExecutor):
    """:class:`MeshExecutor` with the elastic recovery tier enabled.

    Extra parameter:

    t_reshape: modeled outage seconds one online resharding costs (drain
        + re-bind + state movement on a real cluster) — what the TTT
        policy weighs against ``t_restart`` and what the injector clock
        is charged per reshape.
    """

    def __init__(self, cfg: ModelConfig, *, n_groups: int, redundancy: int,
                 t_reshape: float = 60.0, **kwargs: Any):
        super().__init__(cfg, n_groups=n_groups, redundancy=redundancy,
                         **kwargs)
        if self.data_degree != n_groups:
            raise ValueError(
                "elastic reshaping maps one SPARe group per data slice: "
                f"need data_degree == n_groups, got data={self.data_degree}"
                f" vs N={n_groups}")
        self.t_reshape = float(t_reshape)
        self._full_mesh = self.mesh
        self._full_n = int(n_groups)
        self._full_r = int(redundancy)
        # physical data row backing each logical group (logical -> phys)
        self._logical_phys = np.arange(n_groups, dtype=np.int64)
        # inverse: physical row -> logical group, -1 = retired or dead
        self._group_map = np.arange(n_groups, dtype=np.int64)
        self._phys_alive = np.ones(n_groups, dtype=bool)
        # same-shape executables are only reusable on the same devices:
        # a second reshape to the same degree but a different survivor
        # set must evict that shape's stale entries
        self._shape_devices = {
            (self.data_degree, self.model_degree):
                tuple(d.id for d in self.mesh.devices.flat)}
        self._ef_snapshot_rows = self._logical_phys.copy()
        self.reshape_count = 0
        self.policy_log: list[dict] = []

    # ------------------------------------------------------------- #
    # mesh swapping                                                 #
    # ------------------------------------------------------------- #
    def _evict_stale_executables(self, mesh: jax.sharding.Mesh) -> None:
        shape = (mesh.shape["data"], mesh.shape["model"])
        devs = tuple(d.id for d in mesh.devices.flat)
        if self._shape_devices.get(shape, devs) != devs:
            for key in [k for k in self._jitted if (k[0], k[1]) == shape]:
                del self._jitted[key]
                self._wire_info.pop(key, None)
        self._shape_devices[shape] = devs

    def _fit_redundancy(self, n_new: int) -> int:
        """Largest r <= the original redundancy a cyclic Golomb stacking
        at degree ``n_new`` supports (r(r-1) distinct non-zero residues
        must fit mod N); tiny submeshes drop to r=1 (no redundancy)."""
        for r in range(min(self._full_r, n_new), 1, -1):
            if r * (r - 1) <= n_new - 1:
                return r
        return 1

    def _swap_mesh(self, mesh: jax.sharding.Mesh, n_new: int,
                   rows: np.ndarray) -> None:
        """Re-bind onto ``mesh`` (``n_new`` data rows backed by physical
        rows ``rows``) and move every piece of training state across."""
        old_rows = self._logical_phys
        self.state = SpareState(n_new, self._fit_redundancy(n_new))
        self._evict_stale_executables(mesh)
        self._bind_mesh(mesh)
        self.params = jax.device_put(self.params, self._pshard)
        self.opt_state = jax.device_put(self.opt_state, self._oshard)
        if self._ef_state is not None:
            ef = jax.tree.map(np.asarray, self._ef_state)
            ef = remap_ef_rows(ef, self._layout.bucket_sizes,
                               old_rows, rows)
            self._ef_state = jax.device_put(ef, self._ef_shard)
        self._logical_phys = np.asarray(rows, dtype=np.int64)
        self._group_map = np.full(self._full_n, -1, dtype=np.int64)
        self._group_map[self._logical_phys] = np.arange(n_new)

    def reshape(self, victims) -> dict:
        """Shrink past ``victims`` (logical group ids of the CURRENT
        state) onto a survivor submesh and return the move summary.
        Usable directly (lint, tests) — the trainer loop reaches it
        through :meth:`_apply_reshape`."""
        victims = {int(v) for v in victims}
        for v in victims:
            if 0 <= v < self.state.n:
                self._phys_alive[int(self._logical_phys[v])] = False
        surv = [w for w in range(self.state.n)
                if self.state.alive[w] and w not in victims]
        n_new = shrink_degree(self._full_n, len(surv))
        if n_new < 1:
            raise ValueError(
                f"no survivor submesh can continue past {sorted(victims)}")
        rows = sorted(int(self._logical_phys[w]) for w in surv)[:n_new]
        mesh = survivor_submesh(self._full_mesh, rows)
        dp_before = self.state.n
        self._swap_mesh(mesh, n_new, np.asarray(rows, dtype=np.int64))
        self.reshape_count += 1
        return {"dp_before": dp_before, "dp": n_new, "rows": rows}

    def restore_full_mesh(self) -> None:
        """Back to the original ``(data, model)`` mesh at full DP —
        the global-restart path (every group comes back)."""
        self._swap_mesh(self._full_mesh, self._full_n,
                        np.arange(self._full_n, dtype=np.int64))
        self._phys_alive[:] = True

    # ------------------------------------------------------------- #
    # trainer hooks                                                 #
    # ------------------------------------------------------------- #
    def _poll_events(self, injector) -> list[list[int]]:
        # injectors live in physical space: poll them with the physical
        # survivor view, not the (possibly shrunken) logical state
        if injector is None:
            return []
        view = _PhysicalView(self._phys_alive)
        poll = getattr(injector, "poll", None)
        if poll is not None:
            return [ev.victims for ev in poll(view)]
        failed = injector(view)
        return [list(failed)] if failed else []

    def _event_victims(self, victims: list[int]) -> list[int]:
        out = []
        for p in victims:
            p = int(p)
            if not 0 <= p < self._full_n:
                continue
            self._phys_alive[p] = False
            logical = int(self._group_map[p])
            if logical >= 0:
                out.append(logical)
        return out

    def _unmaskable_action(self, victims: list[int], injector) -> str:
        dead = set(int(v) for v in victims)
        surv = [w for w in range(self.state.n)
                if self.state.alive[w] and w not in dead]
        n_new = shrink_degree(self._full_n, len(surv))
        if n_new < 1:
            return "restart"
        kw = dict(
            dp_full=self._full_n, dp_new=n_new,
            remaining_steps=max(self.total_steps - self.step, 1),
            seconds_per_step=float(getattr(injector, "seconds_per_step",
                                           0.0) or 0.0),
            rollback_steps=max(self.step - self._snapshot_step(), 0),
            t_restart=self._t_restart, t_reshape=self.t_reshape)
        decide = getattr(self.scheme, "decide_unmaskable", None)
        if decide is not None:
            action = decide(**kw)
            self.policy_log.append(dict(kw, action=action))
            return action
        est = ttt_estimates(**kw)
        self.policy_log.append(est)
        return est["action"]

    def _apply_reshape(self, event: RecoveryEvent, victims: list[int],
                       injector, report: TrainReport) -> None:
        info = self.reshape(victims)
        event.reshape = True
        event.dp_before = info["dp_before"]
        event.dp_after = info["dp"]
        event.s_a_after = self.state.s_a
        event.reshape_seconds = self.t_reshape
        notify = getattr(injector, "notify_outage", None)
        if notify is not None:
            # resharding outage elapses, but the arrival model keeps
            # running — surviving hardware stays powered throughout
            notify(self.t_reshape, kind="reshape")

    def _degraded_dp_new(self, victims: list[int]) -> int:
        """DP degree a health-driven reshape excluding the straggler
        set would continue at — the elastic option the degraded-TTT
        policy weighs against demotion."""
        dead = set(int(v) for v in victims)
        surv = [w for w in range(self.state.n)
                if self.state.alive[w] and w not in dead]
        return shrink_degree(self._full_n, len(surv))

    def _global_restart(self) -> None:
        if self.state.n != self._full_n:
            self.restore_full_mesh()
        else:
            self.state.reset()
        self._phys_alive[:] = True
        # same demotion/detector reset as the base restart path (the
        # outage swaps degraded hardware)
        self._demoted.clear()
        self._demote_snapshot = None
        self._schedule_version += 1
        if self.detector is not None:
            self.detector.reset()

    # ------------------------------------------------------------- #
    # snapshot / rollback (EF rows follow their physical devices)   #
    # ------------------------------------------------------------- #
    def _snapshot_now(self) -> None:
        super()._snapshot_now()
        self._ef_snapshot_rows = self._logical_phys.copy()

    def _rollback(self):
        if self._ef_snapshot is not None and \
                list(self._ef_snapshot_rows) != list(self._logical_phys):
            # the snapshot was taken at another mesh shape: re-slot its
            # err1 rows for the mesh the rollback restores onto
            self._ef_snapshot = remap_ef_rows(
                self._ef_snapshot, self._layout.bucket_sizes,
                self._ef_snapshot_rows, self._logical_phys)
            self._ef_snapshot_rows = self._logical_phys.copy()
        return super()._rollback()
