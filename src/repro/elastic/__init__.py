"""repro.elastic — elastic mesh reshaping as the middle recovery tier.

Between SPARe masking (free, weight-table data) and wipe-out restart
(t_restart + rollback rework) sits degraded-continue: shrink the DP
degree onto the surviving devices and keep training. See
:mod:`repro.elastic.executor` for the full mechanics and
:mod:`repro.elastic.policy` for the closed-form TTT decision.
"""
from repro.elastic.executor import ElasticMeshExecutor
from repro.elastic.policy import ttt_estimates
from repro.elastic.reshard import (remap_ef_rows, reshard_tree,
                                   shrink_degree, survivor_submesh)

__all__ = ["ElasticMeshExecutor", "ttt_estimates", "shrink_degree",
           "survivor_submesh", "reshard_tree", "remap_ef_rows"]
