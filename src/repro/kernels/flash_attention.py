"""Causal GQA flash attention as a Pallas TPU kernel.

TPU-native adaptation (not a CUDA port):

* grid = (B, H, nQ, nK) with the K dimension innermost and marked
  ``arbitrary`` — on TPU the innermost grid dims execute *sequentially*
  per core, so the online-softmax running state (m, l, acc) lives in VMEM
  scratch across K steps instead of CUDA's per-warp registers;
* BlockSpecs stream (block_q x d) query tiles and (block_k x d) KV tiles
  HBM->VMEM; the MXU sees (block_q x d) @ (d x block_k) matmuls with
  d = head_dim = 64/128 — both MXU-aligned;
* GQA is free: the K/V BlockSpec ``index_map`` maps query-head h to KV
  head ``h // group`` — no materialized ``repeat_kv``;
* causality skips strictly-upper tiles via ``pl.when`` (the block is
  still DMA'd — block-sparse grid pruning is a further optimization — but
  the MXU work is skipped, which is what dominates).

Numerics: online softmax in fp32, output cast to the query dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention_pallas"]

_NEG_INF = -1e30


def flash_attention_kernel(q_ref, k_ref, v_ref, o_ref,
                           m_scr, l_scr, acc_scr,
                           *, scale: float, block_q: int, block_k: int,
                           causal: bool, n_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (iq * block_q + block_q - 1 >= ik * block_k) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, _NEG_INF)
        m_prev = m_scr[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                       # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)              # (bq, 1)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _emit():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           *, causal: bool = True, block_q: int = 256,
                           block_k: int = 256,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, S, D); k, v: (B, KV, S, D). Returns (B, H, S, D)."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    assert h % kv == 0, f"GQA needs H % KV == 0, got {h} % {kv}"
    group = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    n_q, n_k = s // block_q, s // block_k
    scale = d ** -0.5

    kernel = functools.partial(
        flash_attention_kernel, scale=scale, block_q=block_q,
        block_k=block_k, causal=causal, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q, d), jnp.float32),   # output acc
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
