"""Fused int8 error-feedback quantize-accumulate as a Pallas TPU kernel.

The compressed all-reduce path (paper Table 1: the 20 TB gradient sync)
sends int8 + one fp32 scale per tensor. The XLA spelling of
:func:`repro.dist.collectives.compress_grad_int8` is a chain of
elementwise ops that reads the gradient from HBM three times (EF
accumulate, quantize, residual); this kernel fuses the whole pipeline so
each element is read once per pass:

* pass 1 (``absmax``): one VMEM read of ``grad`` and ``error`` per tile,
  per-tile ``max |grad + error|`` reductions (the scalar combine across
  tiles is a trivial host-side ``max``);
* pass 2 (``quantize``): re-reads the tile once and writes *both* the
  int8 payload and the fp32 residual — the EF accumulate, the rounding,
  and the residual subtraction never leave VMEM.

All arithmetic is fp32 exactly like the reference: the int8 payload and
the scale are bit-identical to :func:`repro.kernels.ref.int8_ef_ref`.
The residual is exact up to ONE fp32 ulp of the dequantized value —
compilers (XLA:CPU's LLVM backend, and potentially Mosaic) may contract
``x - q*scale`` into an FMA, which keeps the product at higher
intermediate precision; the same contraction affects the *jitted*
unfused path, so the two fused/unfused spellings agree to the same
bound (property-tested in interpret mode). The slack is absorbed by the
next step's error feedback and is ~1e5x below the scale/2 quantization
error it rides with.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["int8_ef_absmax_kernel", "int8_ef_quantize_kernel",
           "int8_ef_pallas"]

# renamed from TPUCompilerParams across jax releases; unlike the other
# kernels this one must also run interpret-mode on CPU-only wheels (the
# tier-1 EF-invariant tests), so resolve whichever name exists
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

_INT8_MAX = 127.0
_LANES = 128


def int8_ef_absmax_kernel(x_ref, e_ref, o_ref):
    x = x_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)
    o_ref[0, 0] = jnp.max(jnp.abs(x))


def int8_ef_quantize_kernel(x_ref, e_ref, scale_ref, q_ref, err_ref):
    x = x_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)
    scale = scale_ref[0, 0]
    # all-zero tensors keep scale 0 (q == 0, decompress == 0) but must
    # not divide by it — mirror the reference exactly
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -_INT8_MAX, _INT8_MAX)
    q_ref[...] = q.astype(jnp.int8)
    err_ref[...] = x - q * scale


def int8_ef_pallas(grad: jax.Array, error: jax.Array, *,
                   block_rows: int = 256, interpret: bool = False
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused EF quantization of one tensor (any shape/float dtype).

    Returns ``(q int8 [grad.shape], scale f32 scalar, new_error f32
    [grad.shape])`` with numerics identical to
    :func:`repro.dist.collectives.compress_grad_int8`.
    """
    shape = grad.shape
    n = grad.size
    x = grad.reshape(-1)
    e = error.reshape(-1)
    # tile to (rows, 128) lanes; int8 min tile is (32, 128)
    block = block_rows * _LANES
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, (0, pad))
        e = jnp.pad(e, (0, pad))
    rows = x.size // _LANES
    x2 = x.reshape(rows, _LANES)
    e2 = e.reshape(rows, _LANES)
    n_blocks = rows // block_rows

    tile = pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0))
    block_max = pl.pallas_call(
        int8_ef_absmax_kernel,
        grid=(n_blocks,),
        in_specs=[tile, tile],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x2, e2)
    scale = jnp.max(block_max) / _INT8_MAX

    q2, err2 = pl.pallas_call(
        int8_ef_quantize_kernel,
        grid=(n_blocks,),
        in_specs=[
            tile, tile,
            pl.BlockSpec((1, 1), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=(tile, tile),
        out_shape=(jax.ShapeDtypeStruct(x2.shape, jnp.int8),
                   jax.ShapeDtypeStruct(x2.shape, jnp.float32)),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, e2, scale.reshape(1, 1))

    q = q2.reshape(-1)[:n].reshape(shape)
    err = err2.reshape(-1)[:n].reshape(shape)
    return q, scale, err
