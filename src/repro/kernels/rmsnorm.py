"""Fused RMSNorm as a Pallas TPU kernel.

Memory-bound op: the win over the unfused XLA chain (square, mean,
rsqrt, mul, mul) is a single HBM read of the row tile and a single
write — the fp32 reduction and scaling stay in VMEM/VREGs. Row tiles
of (block_rows x D); D up to 8k fits VMEM comfortably at fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rmsnorm_kernel", "rmsnorm_pallas"]


def rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)              # (rows, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
                   block_rows: int = 256,
                   interpret: bool = False) -> jax.Array:
    """x: (..., D); w: (D,). Normalizes over the last axis."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    # pad rows to a block multiple
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n_blocks = x2.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(rmsnorm_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, w)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
