"""Mamba-2 SSD (state-space duality) chunk scan as a Pallas TPU kernel.

TPU-native adaptation of the SSD algorithm (Dao & Gu 2024):

* grid = (B, H, n_chunks); the chunk dimension is innermost/``arbitrary``
  so the running (P x N) state lives in VMEM scratch across chunks — the
  cross-chunk recurrence costs no HBM round-trip (the CUDA version
  materializes chunk states to global memory and runs a second kernel;
  on TPU the sequential-grid + scratch idiom fuses both passes);
* per chunk the three MXU contractions are (Q x N)@(N x Q), (Q x Q)@(Q x P)
  and (N x Q)@(Q x P) with Q = chunk 128/256, N = d_state 128, P = 64 —
  all lane-aligned;
* the group-broadcast of B/C (SSM n_groups < heads) happens in the
  BlockSpec ``index_map`` (h -> h // heads_per_group), not in memory.

Decay math is fp32 throughout; x/b/c tiles may be bf16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_kernel", "ssd_scan_pallas"]


def ssd_scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                    state_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)          # (Q, 1) — see ops.py
    b = b_ref[0, 0].astype(jnp.float32)            # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)            # (Q, N)
    a = a_ref[0]                                   # scalar fp32: -exp(A_log)

    dta = dt * a                                   # (Q, 1) log-decays
    cum = jnp.cumsum(dta, axis=0)                  # (Q, 1)
    seg = cum[chunk - 1, 0]                        # scalar: total log-decay

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j <= i
    li = cum                                       # (Q, 1)
    lj = cum.reshape(1, chunk)                     # (1, Q)
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    l = jnp.where(iq >= jq, jnp.exp(li - lj), 0.0)  # (Q, Q)

    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    w = cb * l * dt.reshape(1, chunk)              # weight on x_j
    y_intra = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: y_inter[i] = exp(cum_i) * c_i . state  (state: (P, N))
    y_inter = jax.lax.dot_general(c, state_scr[...],
                                  (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)               # (Q, P)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S <- exp(seg) S + x^T . (b * dt * exp(seg - cum))
    bw = b * (dt * jnp.exp(seg - cum))             # (Q, N)
    s_chunk = jax.lax.dot_general(x, bw, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (P,N)
    state_scr[...] = state_scr[...] * jnp.exp(seg) + s_chunk

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_scr[...]


def ssd_scan_pallas(x: jax.Array, dt: jax.Array, a: jax.Array,
                    b: jax.Array, c: jax.Array, *, chunk: int = 128,
                    interpret: bool = False
                    ) -> tuple[jax.Array, jax.Array]:
    """x: (B, H, S, P); dt: (B, H, S, 1); a: (H,) fp32 (= -exp(A_log));
    b, c: (B, G, S, N) with H % G == 0. Returns (y (B,H,S,P),
    final state (B,H,P,N) fp32)."""
    bs, h, s, p = x.shape
    g = b.shape[1]
    n = b.shape[-1]
    assert h % g == 0
    hpg = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0
    n_chunks = s // chunk

    kernel = functools.partial(ssd_scan_kernel, chunk=chunk,
                               n_chunks=n_chunks)
    y, state = pl.pallas_call(
        kernel,
        grid=(bs, h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci: (bi, hi // hpg, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci: (bi, hi // hpg, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((bs, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a, b, c)
    return y, state
