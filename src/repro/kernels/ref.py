"""Pure-jnp oracles for every Pallas kernel (the ``assert_allclose``
ground truth; deliberately naive and readable)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "ssd_scan_ref", "rmsnorm_ref",
           "int8_ef_ref"]


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """Naive full-matrix attention with GQA head grouping.

    q: (B, H, S, D); k, v: (B, KV, S, D) with H % KV == 0.
    fp32 softmax; returns (B, H, S, D) in q.dtype.
    """
    b, h, s, d = q.shape
    kv = k.shape[1]
    rep = h // kv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    scores = scores * (d ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(q.dtype), v)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                 c: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Token-by-token SSD recurrence (the definition, not the chunked
    algorithm): S_t = exp(dt_t a) S_{t-1} + dt_t b_t (x) x_t; y_t = c_t.S_t.

    x: (B, H, S, P); dt: (B, H, S); a: (H,); b, c: (B, H, S, N).
    Returns (y (B,H,S,P), final state (B,H,P,N)), fp32 math.
    """
    bs, h, s, p = x.shape
    n = b.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    af = a.astype(jnp.float32)

    def step(state, t):
        decay = jnp.exp(dtf[:, :, t] * af[None, :])              # (B,H)
        outer = jnp.einsum("bhp,bhn->bhpn", xf[:, :, t], bf[:, :, t])
        state = state * decay[..., None, None] + outer * dtf[:, :, t][..., None, None]
        y = jnp.einsum("bhpn,bhn->bhp", state, cf[:, :, t])
        return state, y

    state0 = jnp.zeros((bs, h, p, n), jnp.float32)
    final, ys = jax.lax.scan(step, state0, jnp.arange(s))
    y = ys.transpose(1, 2, 0, 3)                                  # (B,H,S,P)
    return y.astype(x.dtype), final


def int8_ef_ref(grad: jax.Array, error: jax.Array
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Int8 error-feedback quantization, fp32 throughout: the invariant
    ``q * scale + new_error == grad + error`` holds exactly.

    Returns ``(q int8, scale f32 scalar, new_error f32)``.
    """
    x = grad.astype(jnp.float32) + error.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -127.0, 127.0).astype(jnp.int8)
    return q, scale, x - q.astype(jnp.float32) * scale


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale * w.astype(jnp.float32)).astype(x.dtype)
