"""Jitted public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites run the
kernel bodies in Python on CPU (correctness) and compile to Mosaic on a
real TPU (performance). The model layers call the pure-jnp paths by
default; these ops are the drop-in hot-path replacements wired in by the
``use_pallas`` knob of the serving/training drivers on TPU deployments.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .int8_ef import int8_ef_pallas
from .rmsnorm import rmsnorm_pallas
from .ssd_scan import ssd_scan_pallas

__all__ = ["flash_attention", "ssd_scan", "rmsnorm", "int8_ef_quantize",
           "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 256,
                    block_k: int = 256,
                    interpret: bool | None = None) -> jax.Array:
    """Causal GQA flash attention. q: (B,H,S,D); k/v: (B,KV,S,D)."""
    interp = (not on_tpu()) if interpret is None else interpret
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=interp)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a_log: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int = 128,
             interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """SSD chunk scan. x: (B,H,S,P); dt: (B,H,S); a_log: (H,);
    b/c: (B,G,S,N). Returns (y, final_state)."""
    interp = (not on_tpu()) if interpret is None else interpret
    a = -jnp.exp(a_log.astype(jnp.float32))
    return ssd_scan_pallas(x, dt[..., None], a, b, c, chunk=chunk,
                           interpret=interp)


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def int8_ef_quantize(grad: jax.Array, error: jax.Array, *,
                     block_rows: int = 256,
                     interpret: bool | None = None
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused int8 EF quantize-accumulate (the compressed-reduce hot path).
    Returns ``(q int8, scale f32 scalar, new_error f32)``."""
    interp = (not on_tpu()) if interpret is None else interpret
    return int8_ef_pallas(grad, error, block_rows=block_rows,
                          interpret=interp)


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 256,
            interpret: bool | None = None) -> jax.Array:
    interp = (not on_tpu()) if interpret is None else interpret
    return rmsnorm_pallas(x, w, eps=eps, block_rows=block_rows,
                          interpret=interp)
