"""RECTLR — the SPARe reordering controller (paper Alg. 2, App. D).

Runs host-side when the all-reduce detects newly failed group(s):

* **Phase 0 — HK-FIXED.** Is the *committed* stack prefix (depth ``S_A``)
  still sufficient to collect all ``N`` shard types across survivors?
  In the fixed graph every slot is bound to one concrete type, so the
  Hopcroft-Karp feasibility test degenerates to exact coverage counting
  (each left vertex has edges only to slots holding its own type and any
  one of them completes the matching) — we implement it as the coverage
  test and property-test its equivalence with full HK.
* **Phase 1 — HK-FREE.** Smallest depth ``S* <= r`` at which a perfect
  types→slots matching exists when each group may freely permute its
  stack. Monotone in depth, so either a linear scan from ``S_A`` (paper
  Alg. 2) or binary search (paper App. D acceleration) applies. No
  feasible depth ⇒ wipe-out ⇒ flag system failure (global restart).
* **Phase 2 — MCMF.** Min-cost max-flow assignment of types to
  ``(group, slot<S*)`` with cost 0 for "slot already holds this type" and
  1 for a movement, so the reorder touches as few stacks as possible.

The controller also computes the **patch computes** (Alg. 1 line 19): shard
types whose every already-computed copy in the *current* step died with the
failing groups must be recomputed by a surviving host before the step's
all-reduce can complete.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .matching import hopcroft_karp, min_cost_assignment
from .state import SpareState

__all__ = ["Rectlr", "RectlrOutcome"]


@dataclass
class RectlrOutcome:
    """What the controller decided for one failure event."""

    wipeout: bool
    reordered: bool
    s_a_before: int
    s_a_after: int
    moves: int = 0                      # stack slots whose type changed
    patch: list[tuple[int, int]] = field(default_factory=list)  # (group, type)
    hk_free_calls: int = 0
    controller_seconds: float = 0.0

    @property
    def patch_count(self) -> int:
        return len(self.patch)


class Rectlr:
    """Stateless controller logic over a :class:`SpareState`.

    Parameters
    ----------
    binary_search: use the App.-D binary-search variant of HK-FREE
        (``O(log r)`` feasibility calls instead of ``O(r)``).
    """

    def __init__(self, binary_search: bool = False):
        self.binary_search = binary_search

    # ------------------------------------------------------------------ #
    # public entry point                                                 #
    # ------------------------------------------------------------------ #
    def on_failures(self, state: SpareState, failed: list[int] | np.ndarray) -> RectlrOutcome:
        """Process newly failed group(s) and mutate ``state`` accordingly.

        Follows Alg. 2 exactly; additionally computes the patch set for the
        interrupted step (Alg. 1 line 19) *before* committing the reorder,
        since patches are owed against the schedule that was executing when
        the failure hit.
        """
        t0 = time.perf_counter()
        failed = [int(f) for f in np.atleast_1d(np.asarray(failed))]
        s_a_before = state.s_a

        # ---- types lost from the in-flight step (for patch compute) ----
        lost_types = self._lost_supplier_types(state, failed)

        # ---- mark failures ----
        for w in failed:
            state.alive[w] = False
        if lost_types:
            state.supplier[np.asarray(lost_types, dtype=np.int64)] = (-1, -1)

        # ---- wipe-out short-circuit (some type has no surviving host) ----
        if state.wiped_types().size > 0:
            return RectlrOutcome(
                wipeout=True, reordered=False,
                s_a_before=s_a_before, s_a_after=s_a_before,
                controller_seconds=time.perf_counter() - t0,
            )

        # ---- patch compute for the interrupted step ----
        patch = self._assign_patches(state, lost_types)

        # ---- Phase 0: HK-FIXED on the committed prefix ----
        if bool(state.prefix_coverage(state.s_a).all()):
            self._reassign_suppliers_fixed(state)
            return RectlrOutcome(
                wipeout=False, reordered=False,
                s_a_before=s_a_before, s_a_after=state.s_a,
                patch=patch,
                controller_seconds=time.perf_counter() - t0,
            )

        # ---- Phase 1: HK-FREE — minimal feasible depth ----
        s_star, hk_calls = self._min_feasible_depth(state)
        if s_star is None:
            # Hall violation at every depth <= r: wipe-out by feasibility
            # (possible only via pathological multi-group Hall witnesses;
            # per Thm. 4.2 these are vanishingly rare — but handled).
            return RectlrOutcome(
                wipeout=True, reordered=False,
                s_a_before=s_a_before, s_a_after=s_a_before,
                hk_free_calls=hk_calls,
                controller_seconds=time.perf_counter() - t0,
            )

        # ---- Phase 2: MCMF minimal-movement reorder at depth S* ----
        if bool(state.prefix_coverage(s_star).all()):
            # zero-movement fast path: the existing order already covers all
            # types at depth S* — the min-cost assignment is the identity
            # (cost 0), so MCMF is skipped and only suppliers re-designate.
            state.s_a = s_star
            self._reassign_suppliers_fixed(state)
            moves = 0
        else:
            moves = self._reorder_min_movement(state, s_star)
            state.s_a = s_star
        return RectlrOutcome(
            wipeout=False, reordered=True,
            s_a_before=s_a_before, s_a_after=s_star,
            moves=moves, patch=patch, hk_free_calls=hk_calls,
            controller_seconds=time.perf_counter() - t0,
        )

    # ------------------------------------------------------------------ #
    # Phase 0 helpers                                                    #
    # ------------------------------------------------------------------ #
    @staticmethod
    def _lost_supplier_types(state: SpareState, failed: list[int]) -> list[int]:
        """Types whose designated supplier for the in-flight step belongs to
        a newly failed group. These partial gradients were lost mid-step."""
        mask = np.isin(state.supplier[:, 0], np.asarray(failed, dtype=np.int64))
        return [int(i) for i in np.flatnonzero(mask)]

    @staticmethod
    def _assign_patches(state: SpareState, lost_types: list[int]) -> list[tuple[int, int]]:
        """Pick a surviving host for each lost type (patch compute).

        Prefers a survivor that *already computed the type* in its committed
        prefix this step (then the "patch" is free — just re-designate the
        supplier); otherwise chooses the least-loaded surviving host, which
        must compute one extra stack before the step's all-reduce.
        """
        patch: list[tuple[int, int]] = []
        extra_load = np.zeros(state.n, dtype=np.int64)
        for i in lost_types:
            hosts = state.hosts[i]
            live_hosts = hosts[state.alive[hosts]]
            assert live_hosts.size > 0, "caller guarantees no wipe-out here"
            # free re-designation: a live host already has i in its prefix?
            redesignated = False
            for w in live_hosts:
                js = np.flatnonzero(state.stacks[w, : state.s_a] == i)
                if js.size:
                    state.supplier[i] = (int(w), int(js[0]))
                    redesignated = True
                    break
            if redesignated:
                continue
            # otherwise: actual patch compute on the least-loaded live host
            w = int(live_hosts[np.argmin(extra_load[live_hosts])])
            extra_load[w] += 1
            patch.append((w, i))
            # supplier slot: conceptually an extra slot beyond the prefix;
            # it becomes consistent again after Phase 1/2 commit. Mark the
            # supplier as the patching group at its existing slot for i.
            j = int(np.flatnonzero(state.stacks[w] == i)[0])
            state.supplier[i] = (w, j) if j < state.s_a else (-1, -1)
        return patch

    @staticmethod
    def _reassign_suppliers_fixed(state: SpareState) -> None:
        """After Phase-0 success: every type has >= 1 alive prefix slot;
        designate one supplier per type (keep existing when still valid)."""
        # vectorized: which suppliers are still valid?
        w = state.supplier[:, 0]
        j = state.supplier[:, 1]
        valid = (w >= 0)
        if valid.any():
            wv = np.where(valid, w, 0)
            jv = np.where(valid, j, 0)
            valid &= state.alive[wv] & (jv < state.s_a)
            valid &= state.stacks[wv, jv] == np.arange(state.n)
        need = np.flatnonzero(~valid)
        if need.size == 0:
            return
        # build type -> (group, slot) map from alive prefixes in one pass
        alive_groups = state.survivors
        prefix = state.stacks[alive_groups, : state.s_a]       # (A, s)
        type_to_w = np.full(state.n, -1, dtype=np.int64)
        type_to_j = np.full(state.n, -1, dtype=np.int64)
        gg = np.repeat(alive_groups, state.s_a)
        jj = np.tile(np.arange(state.s_a), alive_groups.size)
        # reversed so the FIRST occurrence wins after overwrite
        type_to_w[prefix.ravel()[::-1]] = gg[::-1]
        type_to_j[prefix.ravel()[::-1]] = jj[::-1]
        assert (type_to_w[need] >= 0).all(), \
            "phase-0 coverage promised a prefix slot for every type"
        state.supplier[need, 0] = type_to_w[need]
        state.supplier[need, 1] = type_to_j[need]

    # ------------------------------------------------------------------ #
    # Phase 1 — HK-FREE                                                  #
    # ------------------------------------------------------------------ #
    def _min_feasible_depth(self, state: SpareState) -> tuple[int | None, int]:
        """Smallest ``S in [S_A, r]`` admitting a perfect free matching.

        Fast path per depth: if the *current* order already covers every
        type at depth ``s`` (vectorized check), the identity assignment is a
        perfect matching and HK is skipped — the common case right after a
        single failure, keeping the controller sub-10ms at N=1000.
        """
        lo, hi = state.s_a, state.r
        calls = 0

        def feasible(s: int) -> bool:
            nonlocal calls
            if bool(state.prefix_coverage(s).all()):
                return True
            calls += 1
            return self._feasible(state, s)

        if self.binary_search:
            # find any feasible point first (monotone predicate)
            if not feasible(hi):
                return None, calls
            while lo < hi:
                mid = (lo + hi) // 2
                if feasible(mid):
                    hi = mid
                else:
                    lo = mid + 1
            return lo, calls
        for s in range(lo, hi + 1):
            if feasible(s):
                return s, calls
        return None, calls

    @staticmethod
    def _feasible(state: SpareState, s: int) -> bool:
        """Perfect matching of N types onto survivors × s slots (free perm).

        Slots within one group are interchangeable under free permutation,
        so we match onto groups with capacity ``s`` by exploding each
        surviving group into ``s`` right-vertices.
        """
        survivors = state.survivors
        if survivors.size * s < state.n:
            return False  # capacity bound c(k) (Hall necessary condition)
        pos = -np.ones(state.n, dtype=np.int64)
        pos[survivors] = np.arange(survivors.size)
        adj: list[list[int]] = []
        for i in range(state.n):
            row = []
            for w in state.hosts[i]:
                p = pos[w]
                if p >= 0:
                    base = int(p) * s
                    row.extend(range(base, base + s))
            adj.append(row)
        size, _, _ = hopcroft_karp(adj, state.n, survivors.size * s)
        return size == state.n

    # ------------------------------------------------------------------ #
    # Phase 2 — MCMF                                                     #
    # ------------------------------------------------------------------ #
    @staticmethod
    def _reorder_min_movement(state: SpareState, s_star: int) -> int:
        """Reorder stacks so depth-``s_star`` prefixes cover all types,
        moving as few slots as possible; commit suppliers. Returns the
        number of slots whose assigned type changed."""
        survivors = state.survivors
        pos = -np.ones(state.n, dtype=np.int64)
        pos[survivors] = np.arange(survivors.size)

        adj_cost: list[list[tuple[int, int]]] = []
        initial: list[int] = [-1] * state.n   # zero-cost jump-start matching
        for i in range(state.n):
            row: list[tuple[int, int]] = []
            for w in state.hosts[i]:
                p = pos[w]
                if p < 0:
                    continue
                for t in range(s_star):
                    slot = int(p) * s_star + t
                    if state.stacks[w, t] == i:
                        row.append((slot, 0))
                        if initial[i] == -1:
                            initial[i] = slot   # "stay" edge (unique per slot)
                    else:
                        row.append((slot, 1))
            adj_cost.append(row)
        matched, total_cost, match_l = min_cost_assignment(
            adj_cost, state.n, survivors.size * s_star, initial_match_l=initial
        )
        assert matched == state.n, "phase-1 feasibility promised a perfect matching"

        # apply the assignment group by group
        want: dict[int, dict[int, int]] = {int(w): {} for w in survivors}
        for i in range(state.n):
            v = match_l[i]
            w = int(survivors[v // s_star])
            t = v % s_star
            want[w][t] = i

        moves = 0
        for w, slot_map in want.items():
            row = state.stacks[w]
            new_row = np.full(state.r, -1, dtype=row.dtype)
            used = set()
            for t, i in slot_map.items():
                new_row[t] = i
                used.add(int(i))
            # remaining hosted types fill remaining slots in current order
            rest = [int(x) for x in row if int(x) not in used]
            free_slots = [t for t in range(state.r) if new_row[t] == -1]
            for t, x in zip(free_slots, rest):
                new_row[t] = x
            moves += int((new_row != row).sum())
            state.stacks[w] = new_row

        # commit suppliers from the matching
        for i in range(state.n):
            v = match_l[i]
            w = int(survivors[v // s_star])
            t = v % s_star
            state.supplier[i] = (w, t)
        return moves
