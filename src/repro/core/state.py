"""SPARe protocol state (paper Alg. 1 context).

:class:`SpareState` holds everything the SPARe training loop tracks between
steps: the cyclic-Golomb placement, per-group *persistent local stack orders*
``stk[w]`` (a permutation of the group's type set ``T_w``), the survivor
set, the committed *all-reduce stack* ``S_A``, and the designated supplier
of each shard type (which (group, slot) contributes that type's partial
gradient to the weighted all-reduce).

The state is deliberately a plain host-side object (NumPy only): SPARe's
control plane runs on the coordinator between device steps — it never enters
the compiled SPMD program. The device-side view of this state is the
``(weights, stack order)`` pair produced by :meth:`supplier_weights` /
:meth:`device_schedule`, which the trainer feeds to the jitted train step.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .golomb import host_sets, type_sets

__all__ = ["SpareState"]


@dataclass
class SpareState:
    """Mutable SPARe bookkeeping for one training job.

    Attributes
    ----------
    n: data-parallel degree (number of model-parallel groups and shard types).
    r: redundancy degree (stacks hosted per group).
    hosts: ``(N, r)`` — ``hosts[i]`` = groups hosting shard type ``i``.
    types: ``(N, r)`` — ``types[w]`` = shard types hosted by group ``w``.
    stacks: ``(N, r)`` — current *stack order*; ``stacks[w][j]`` is the type
        group ``w`` computes at stack depth ``j``. Row ``w`` is always a
        permutation of ``types[w]``.
    alive: ``(N,)`` bool survivor mask.
    s_a: committed all-reduce stack depth ``S_A`` (paper: default 1).
    supplier: ``(N, 2)`` — ``supplier[i] = (w, j)``: the designated group and
        stack slot contributing type ``i``'s partial gradient. ``(-1, -1)``
        when the type is currently unassigned (transient, mid-recovery).
    """

    n: int
    r: int
    hosts: np.ndarray = field(init=False)
    types: np.ndarray = field(init=False)
    stacks: np.ndarray = field(init=False)
    alive: np.ndarray = field(init=False)
    s_a: int = field(init=False, default=1)
    supplier: np.ndarray = field(init=False)

    def __post_init__(self) -> None:
        if not (1 <= self.r <= self.n):
            raise ValueError(f"need 1 <= r <= N, got r={self.r}, N={self.n}")
        self.hosts = host_sets(self.n, self.r)
        self.types = type_sets(self.n, self.r)
        self.reset()

    # ------------------------------------------------------------------ #
    # lifecycle                                                          #
    # ------------------------------------------------------------------ #
    def reset(self) -> None:
        """Global restart (Alg. 1 line 13): all groups active, original
        stack order (stack 0 covers all N types by cyclic rotation),
        all-reduce stack back to 1."""
        self.stacks = self.types.copy()
        self.alive = np.ones(self.n, dtype=bool)
        self.s_a = 1
        # default supplier: type i at (group i, slot 0) — stacks[i][0] == i
        self.supplier = np.stack(
            [np.arange(self.n), np.zeros(self.n, dtype=np.int64)], axis=1
        )

    # ------------------------------------------------------------------ #
    # views                                                              #
    # ------------------------------------------------------------------ #
    @property
    def survivors(self) -> np.ndarray:
        """Indices of active groups (``U_k``)."""
        return np.flatnonzero(self.alive)

    @property
    def failure_count(self) -> int:
        return int(self.n - self.alive.sum())

    def surviving_host_counts(self) -> np.ndarray:
        """``(N,)`` — number of surviving hosts per type; 0 = wiped out."""
        return self.alive[self.hosts].sum(axis=1)

    def wiped_types(self) -> np.ndarray:
        return np.flatnonzero(self.surviving_host_counts() == 0)

    def prefix_coverage(self, s: int | None = None) -> np.ndarray:
        """``(N,)`` bool — is type ``i`` present in some alive group's first
        ``s`` stacks? (HK-FIXED reduces to this coverage test because in the
        *fixed* graph each slot is bound to exactly one type — see App. D.)"""
        s = self.s_a if s is None else s
        covered = np.zeros(self.n, dtype=bool)
        prefix = self.stacks[self.alive, :s]
        covered[prefix.ravel()] = True
        return covered

    def assert_invariants(self) -> None:
        """Cheap structural sanity — used by property tests after every
        controller action."""
        assert 1 <= self.s_a <= self.r, f"S_A={self.s_a} out of [1, {self.r}]"
        # each stack row is a permutation of the group's type set
        assert np.array_equal(np.sort(self.stacks, axis=1), np.sort(self.types, axis=1)), (
            "stack rows must remain permutations of their type sets"
        )
        # each type's supplier (when set) is an alive host with the type in
        # its committed prefix
        for i in range(self.n):
            w, j = self.supplier[i]
            if w < 0:
                continue
            assert self.alive[w], f"type {i} supplied by dead group {w}"
            assert j < self.s_a, f"type {i} supplied beyond S_A ({j} >= {self.s_a})"
            assert self.stacks[w, j] == i, (
                f"supplier slot mismatch: stacks[{w},{j}]={self.stacks[w, j]} != {i}"
            )

    # ------------------------------------------------------------------ #
    # device-facing schedule                                             #
    # ------------------------------------------------------------------ #
    def device_schedule(self) -> tuple[np.ndarray, np.ndarray]:
        """Return ``(stack_types, weights)`` for the SPMD train step.

        ``stack_types``: ``(N, S_A)`` int — shard type computed by group
        ``w`` at stack slot ``j`` (the data pipeline gathers microbatches by
        these ids; rows of dead groups are kept for shape stability but
        carry zero weight).

        ``weights``: ``(N, S_A)`` float — ``1/N`` where ``(w, j)`` is the
        designated supplier of its type, else ``0``. The weighted
        ``psum`` over the data axis then reproduces the logical gradient
        ``ḡ = (1/N) Σ_i g_i`` exactly — reordering changes suppliers, never
        the collected gradient (paper §3.1 invariant).
        """
        stack_types = self.stacks[:, : self.s_a].copy()
        weights = np.zeros((self.n, self.s_a), dtype=np.float64)
        for i in range(self.n):
            w, j = self.supplier[i]
            if w >= 0:
                weights[w, j] = 1.0 / self.n
        return stack_types, weights

    def supplier_weights(self) -> np.ndarray:
        _, weights = self.device_schedule()
        return weights
