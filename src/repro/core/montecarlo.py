"""Monte-Carlo validation of Thms. 4.1 / 4.2 (paper App. C, Tables 4-6).

Emulates trails of random independent group failures over the cyclic-Golomb
placement and measures, per trial:

* ``F`` — failure count at first wipe-out (validates ``mu(N, r)``);
* the minimal feasible all-reduce stack ``S(U_k)`` after each failure
  (validates the Eq. 6 lower bound of ``S_bar``).

Feasibility at depth ``s`` is maintained *incrementally* with
:class:`repro.core.matching.IncrementalMatcher` — rebuilding Hopcroft-Karp
from scratch for each of the ~700 failures x 1000 trials at N=1000 would
dominate the run time; equivalence of the incremental matcher with full HK
is property-tested in ``tests/test_matching.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .golomb import host_sets
from .matching import IncrementalMatcher
from .theory import capacity

__all__ = ["McResult", "run_trial", "run_montecarlo"]


@dataclass
class McResult:
    n: int
    r: int
    trials: int
    mean_failures: float           # Monte-Carlo E[F]
    mean_stack: float              # Monte-Carlo E[S(U_k)] averaged over k
    failures: list[int] = field(default_factory=list, repr=False)
    stacks_per_k: list[float] = field(default_factory=list, repr=False)


def run_trial(n: int, r: int, rng: np.random.Generator,
              hosts: np.ndarray | None = None) -> tuple[int, list[int]]:
    """One failure trail: kill groups in a uniformly random order until the
    first wipe-out; record the minimal feasible depth after each failure.

    Returns ``(F, depths)`` where ``depths[k]`` is ``S(U_{k+1})`` — the depth
    needed after the ``(k+1)``-th failure (``len(depths) == F - 1``; the
    ``F``-th failure is the wipe-out itself, at which no depth is feasible).
    """
    if hosts is None:
        hosts = host_sets(n, r)
    order = rng.permutation(n)
    host_alive = np.full(n, r, dtype=np.int64)  # surviving hosts per type

    matcher = IncrementalMatcher(hosts, n, depth=1)
    ok = matcher.initialise()
    assert ok, "depth-1 matching must exist before any failure (cyclic cover)"

    depths: list[int] = []
    for k, w in enumerate(order, start=1):
        w = int(w)
        # wipe-out check first (cheap counter update)
        types_of_w = np.flatnonzero((hosts == w).any(axis=1))
        host_alive[types_of_w] -= 1
        if (host_alive[types_of_w] == 0).any():
            return k, depths
        displaced = matcher.fail_group(w)
        depth = matcher.min_feasible_depth(displaced, r)
        assert depth is not None, "no wipe-out but infeasible at depth r"
        # the matcher's depth only grows; c(k) says the true minimum may be
        # smaller than the matcher's sticky depth — rebuild when the
        # capacity bound is lower than what we are currently using.
        c_k = capacity(k, n)
        if depth > c_k:
            fresh = IncrementalMatcher(hosts, n, depth=c_k)
            fresh.alive = matcher.alive.copy()
            fresh.cap = [c_k if a else 0 for a in fresh.alive]
            if fresh.initialise():
                depth = c_k
                matcher = fresh
            else:
                d2 = c_k
                while d2 < depth:
                    d2 += 1
                    fresh2 = IncrementalMatcher(hosts, n, depth=d2)
                    fresh2.alive = matcher.alive.copy()
                    fresh2.cap = [d2 if a else 0 for a in fresh2.alive]
                    if fresh2.initialise():
                        matcher = fresh2
                        depth = d2
                        break
        depths.append(depth)
    return n, depths  # all groups failed without wipe-out (r = N corner)


def run_montecarlo(n: int, r: int, trials: int = 200, seed: int = 0) -> McResult:
    """Paper App. C experiment: ``trials`` independent failure trails."""
    import sys
    # Kuhn eviction chains recurse one frame per displaced type; at
    # N=1000, r~26 the worst chain exceeds CPython's default 1000 frames
    if sys.getrecursionlimit() < 4 * n + 100:
        sys.setrecursionlimit(4 * n + 100)
    rng = np.random.default_rng(seed)
    hosts = host_sets(n, r)
    failures: list[int] = []
    stack_means: list[float] = []
    for _ in range(trials):
        f, depths = run_trial(n, r, rng, hosts)
        failures.append(f)
        if depths:
            stack_means.append(float(np.mean(depths)))
    return McResult(
        n=n, r=r, trials=trials,
        mean_failures=float(np.mean(failures)),
        mean_stack=float(np.mean(stack_means)) if stack_means else 1.0,
        failures=failures,
        stacks_per_k=stack_means,
    )
