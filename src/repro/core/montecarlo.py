"""Monte-Carlo validation of Thms. 4.1 / 4.2 (paper App. C, Tables 4-6).

Emulates trails of random group failures over the cyclic-Golomb
placement and measures, per trial:

* ``F`` — failure count at first wipe-out (validates ``mu(N, r)``);
* the minimal feasible all-reduce stack ``S(U_k)`` after each failure
  event (validates the Eq. 6 lower bound of ``S_bar``).

Victims default to a uniformly random kill order (the paper's App. C
assumption) but may instead be drawn from any
:class:`repro.scenarios.models.FailureModel` over a
:class:`repro.scenarios.topology.ClusterTopology` — rack/pod bursts then
arrive as *batches* of simultaneous kills, and the wipe-out / stack
accounting sees the whole blast radius at once.

Feasibility at depth ``s`` is maintained *incrementally* with
:class:`repro.core.matching.IncrementalMatcher` — rebuilding Hopcroft-
Karp from scratch for each of the ~700 failures x 1000 trials at N=1000
would dominate the run time; equivalence of the incremental matcher with
full HK is property-tested in ``tests/test_matching.py``. (The matcher's
eviction chains are iterative, so no ``sys.setrecursionlimit`` games are
needed at any N.)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from .golomb import host_sets
from .matching import IncrementalMatcher
from .theory import capacity

__all__ = ["McResult", "run_trial", "run_montecarlo"]


@dataclass
class McResult:
    n: int
    r: int
    trials: int
    mean_failures: float           # MC E[F] over wiped-out trials (NaN if 0)
    mean_stack: float              # Monte-Carlo E[S(U_k)] averaged over k
    censored: int = 0              # trials that never wiped out (F > N)
    failures: list[int] = field(default_factory=list, repr=False)
    stacks_per_k: list[float] = field(default_factory=list, repr=False)


def run_trial(n: int, r: int, rng: np.random.Generator,
              hosts: np.ndarray | None = None,
              kill_batches: Sequence[Sequence[int]] | None = None,
              ) -> tuple[int | None, list[int]]:
    """One failure trail: kill groups until the first wipe-out; record
    the minimal feasible depth after each failure event.

    ``kill_batches`` is an ordered sequence of simultaneous-kill groups
    (one inner list per failure event — rack/pod bursts kill several at
    once); the default is the single-kill uniform random order of the
    paper's App. C. Returns ``(F, depths)`` where ``F`` counts
    *individual* group failures up to and including the wipe-out and
    ``depths[j]`` is the feasible stack after the ``(j+1)``-th surviving
    event. ``F is None`` flags the censored corner: the kill sequence
    ran out without a wipe-out (for the default full permutation that is
    the r ~ N edge; custom ``kill_batches`` may stop earlier). The old
    behavior of returning ``n`` silently deflated ``mean_failures``.
    """
    if hosts is None:
        hosts = host_sets(n, r)
    if kill_batches is None:
        kill_batches = [[int(w)] for w in rng.permutation(n)]
    host_alive = np.full(n, r, dtype=np.int64)  # surviving hosts per type

    matcher = IncrementalMatcher(hosts, n, depth=1)
    ok = matcher.initialise()
    assert ok, "depth-1 matching must exist before any failure (cyclic cover)"

    depths: list[int] = []
    k = 0
    for batch in kill_batches:
        displaced: list[int] = []
        fresh_kills = 0
        for w in batch:
            w = int(w)
            if not matcher.alive[w]:
                continue
            fresh_kills += 1
            k += 1
            # wipe-out check first (cheap counter update)
            types_of_w = np.flatnonzero((hosts == w).any(axis=1))
            host_alive[types_of_w] -= 1
            if (host_alive[types_of_w] == 0).any():
                return k, depths
            displaced.extend(matcher.fail_group(w))
        if fresh_kills == 0:
            continue
        depth = matcher.min_feasible_depth(displaced, r)
        assert depth is not None, "no wipe-out but infeasible at depth r"
        # the matcher's depth only grows; c(k) says the true minimum may be
        # smaller than the matcher's sticky depth — rebuild when the
        # capacity bound is lower than what we are currently using.
        c_k = capacity(k, n)
        if depth > c_k:
            fresh = IncrementalMatcher(hosts, n, depth=c_k)
            fresh.alive = matcher.alive.copy()
            fresh.cap = [c_k if a else 0 for a in fresh.alive]
            if fresh.initialise():
                depth = c_k
                matcher = fresh
            else:
                d2 = c_k
                while d2 < depth:
                    d2 += 1
                    fresh2 = IncrementalMatcher(hosts, n, depth=d2)
                    fresh2.alive = matcher.alive.copy()
                    fresh2.cap = [d2 if a else 0 for a in fresh2.alive]
                    if fresh2.initialise():
                        matcher = fresh2
                        depth = d2
                        break
        depths.append(depth)
    return None, depths  # every group failed without wipe-out (r = N corner)


def run_montecarlo(n: int, r: int, trials: int = 200, seed: int = 0,
                   failure_model=None, topology=None) -> McResult:
    """Paper App. C experiment: ``trials`` independent failure trails.

    With a ``failure_model`` (spec dict, name, or instance — see
    :func:`repro.scenarios.models.model_from_spec`) victims are drawn by
    blast radius over ``topology`` instead of uniformly; each trial
    re-samples the model's event stream. Censored trials (no wipe-out)
    are excluded from ``mean_failures`` and counted in ``censored``.
    """
    rng = np.random.default_rng(seed)
    hosts = host_sets(n, r)
    failures: list[int] = []
    stack_means: list[float] = []
    censored = 0
    for _ in range(trials):
        batches = None
        if failure_model is not None:
            from ..scenarios.models import sample_kill_batches
            batches = sample_kill_batches(failure_model, n, rng,
                                          topology=topology)
        f, depths = run_trial(n, r, rng, hosts, kill_batches=batches)
        if f is None:
            censored += 1
        else:
            failures.append(f)
        if depths:
            stack_means.append(float(np.mean(depths)))
    return McResult(
        n=n, r=r, trials=trials,
        # all-censored => no wipe-out ever observed: NaN, not a silent n
        mean_failures=float(np.mean(failures)) if failures else float("nan"),
        mean_stack=float(np.mean(stack_means)) if stack_means else 1.0,
        censored=censored,
        failures=failures,
        stacks_per_k=stack_means,
    )
