"""Bipartite matching + min-cost max-flow primitives for RECTLR (App. D).

Implemented from scratch (no external graph dependency):

* :func:`hopcroft_karp` — maximum bipartite matching in O(E sqrt(V)),
  used by HK-FIXED (Phase 0) and HK-FREE (Phase 1) feasibility checks.
* :class:`IncrementalMatcher` — maintains a type→slot matching across
  failure events, repairing only the assignments invalidated by the newly
  failed group (single Kuhn augmentations). Used by the Monte-Carlo driver
  where thousands of sequential failures would make full HK rebuilds the
  bottleneck. Equivalence with full HK is property-tested.
* :func:`min_cost_assignment` — min-cost max-cardinality assignment via
  successive shortest augmenting paths with 0-1 BFS (costs are {0,1}:
  0 = "type keeps its current slot", 1 = "type moves"). Used by MCMF
  (Phase 2) minimal-movement reordering.
"""
from __future__ import annotations

from collections import deque
from typing import Sequence

__all__ = [
    "hopcroft_karp",
    "IncrementalMatcher",
    "min_cost_assignment",
]

_INF = float("inf")


def hopcroft_karp(
    adj: Sequence[Sequence[int]], n_left: int, n_right: int
) -> tuple[int, list[int], list[int]]:
    """Maximum bipartite matching.

    Parameters
    ----------
    adj: adjacency list; ``adj[u]`` lists right-vertices reachable from
        left-vertex ``u``. Left vertices are shard types; right vertices are
        (surviving group, stack slot) pairs flattened to ints.

    Returns
    -------
    (size, match_l, match_r): matching cardinality, left→right assignment
    (-1 when unmatched) and right→left inverse.
    """
    match_l = [-1] * n_left
    match_r = [-1] * n_right
    dist = [0] * n_left

    def bfs() -> bool:
        q: deque[int] = deque()
        found = False
        for u in range(n_left):
            if match_l[u] == -1:
                dist[u] = 0
                q.append(u)
            else:
                dist[u] = -1
        while q:
            u = q.popleft()
            for v in adj[u]:
                w = match_r[v]
                if w == -1:
                    found = True
                elif dist[w] == -1:
                    dist[w] = dist[u] + 1
                    q.append(w)
        return found

    def dfs(u: int) -> bool:
        # iterative DFS to avoid Python recursion limits at N ~ 1e3
        stack: list[tuple[int, int]] = [(u, 0)]
        path: list[tuple[int, int]] = []
        while stack:
            node, idx = stack.pop()
            nbrs = adj[node]
            advanced = False
            while idx < len(nbrs):
                v = nbrs[idx]
                idx += 1
                w = match_r[v]
                if w == -1:
                    # augment along path + (node, v)
                    match_l[node] = v
                    match_r[v] = node
                    for pn, pv in reversed(path):
                        match_l[pn] = pv
                        match_r[pv] = pn
                    return True
                if dist[w] == dist[node] + 1:
                    stack.append((node, idx))
                    path.append((node, v))
                    stack.append((w, 0))
                    advanced = True
                    break
            if not advanced:
                dist[node] = -1
                if path and stack:
                    path.pop()
                elif path:
                    path.pop()
        return False

    size = 0
    while bfs():
        for u in range(n_left):
            if match_l[u] == -1 and dfs(u):
                size += 1
    return size, match_l, match_r


class IncrementalMatcher:
    """Maintain a perfect matching of types onto (group, slot) capacity slots
    while groups fail one at a time.

    Right vertices are dynamic: a *group* ``w`` with capacity ``s`` exposes
    slots ``w*s_max + t`` for ``t < s``. For Monte-Carlo we only need
    feasibility at a given depth ``s`` (free permutation within groups), so
    capacity per surviving group is simply ``s``; we model it as group
    capacities rather than exploded slots for speed.
    """

    def __init__(self, hosts, n: int, depth: int):
        # hosts: (N, r) array-like; hosts[i] = groups hosting type i
        self.n = n
        self.hosts = [list(map(int, row)) for row in hosts]
        self.depth = depth
        self.alive = [True] * n
        self.cap = [depth] * n          # remaining capacity per group
        self.assign = [-1] * n          # type -> group
        self.load: list[list[int]] = [[] for _ in range(n)]  # group -> types

    def set_depth(self, depth: int) -> None:
        """Raise (or lower) per-group capacity; lowering may require rebuild."""
        if depth < self.depth:
            raise ValueError("capacity decrease not supported; rebuild instead")
        delta = depth - self.depth
        self.depth = depth
        if delta:
            for w in range(self.n):
                if self.alive[w]:
                    self.cap[w] += delta

    def _try_assign(self, i: int, visited: list[bool]) -> bool:
        """Kuhn augmenting step: place type ``i``, evicting via alternating
        paths if needed. ``visited`` marks groups explored this attempt."""
        for w in self.hosts[i]:
            if not self.alive[w] or visited[w]:
                continue
            visited[w] = True
            if self.cap[w] > 0:
                self.cap[w] -= 1
                self.assign[i] = w
                self.load[w].append(i)
                return True
        for w in self.hosts[i]:
            if not self.alive[w] or not visited[w]:
                continue
            # try to evict one of w's current types elsewhere
            for j in list(self.load[w]):
                if self._try_assign_evict(j, visited, banned=w):
                    self.load[w].remove(j)
                    self.assign[i] = w
                    self.load[w].append(i)
                    return True
        return False

    def _try_assign_evict(self, i: int, visited: list[bool], banned: int) -> bool:
        """Iterative alternating-path search (same traversal order as the
        natural recursion, but eviction chains grow one frame per displaced
        type — at N=1000 that exceeds CPython's default recursion limit,
        so the stack is explicit)."""
        # frame: [type, banned group, next host index, current eviction
        #         group (or -1), load snapshot, next load index]
        frames = [[i, banned, 0, -1, None, 0]]
        result: bool | None = None
        while frames:
            f = frames[-1]
            ftype, fban, _, fw, floads, fli = f
            if result is True:
                # child relocated floads[fli] out of fw: take its slot
                j = floads[fli]
                self.load[fw].remove(j)
                self.assign[ftype] = fw
                self.load[fw].append(ftype)
                frames.pop()
                continue                    # result stays True: unwind
            if result is False:
                f[5] = fli = fli + 1        # next eviction candidate
                result = None
            if fw >= 0:
                if fli < len(floads):
                    frames.append([floads[fli], fw, 0, -1, None, 0])
                    continue
                f[3] = fw = -1              # loads exhausted: scan on
            hosts_i = self.hosts[ftype]
            progressed = False
            while f[2] < len(hosts_i):
                w = hosts_i[f[2]]
                f[2] += 1
                if w == fban or not self.alive[w] or visited[w]:
                    continue
                visited[w] = True
                if self.cap[w] > 0:
                    self.cap[w] -= 1
                    self.assign[ftype] = w
                    self.load[w].append(ftype)
                    frames.pop()
                    result = True
                    progressed = True
                    break
                # full group: suspend here and try evicting its types
                f[3] = w
                f[4] = list(self.load[w])
                f[5] = 0
                progressed = True
                break
            if not progressed:
                frames.pop()
                result = False
        return bool(result)

    def initialise(self) -> bool:
        """Build the initial matching (depth slots per group)."""
        ok = True
        for i in range(self.n):
            visited = [False] * self.n
            if not self._try_assign(i, visited):
                ok = False
                break
        return ok

    def fail_group(self, w: int) -> list[int]:
        """Mark group ``w`` failed; return the displaced types (unassigned)."""
        if not self.alive[w]:
            return []
        self.alive[w] = False
        displaced = self.load[w]
        self.load[w] = []
        self.cap[w] = 0
        for i in displaced:
            self.assign[i] = -1
        return displaced

    def repair(self, displaced: list[int]) -> list[int]:
        """Re-place displaced types. Returns the list that could NOT be placed
        at the current depth (empty = feasible at current depth)."""
        stuck = []
        for i in displaced:
            visited = [False] * self.n
            if not self._try_assign(i, visited):
                stuck.append(i)
        return stuck

    def min_feasible_depth(self, displaced: list[int], r: int) -> int | None:
        """HK-FREE scan: smallest depth <= r at which all types place.

        Monotone in depth (App. D), so after each capacity bump we only retry
        the still-stuck types. Returns None on wipe-out.
        """
        stuck = self.repair(displaced)
        while stuck:
            if self.depth >= r:
                return None
            self.set_depth(self.depth + 1)
            stuck = self.repair(stuck)
        return self.depth


def min_cost_assignment(
    adj_cost: Sequence[Sequence[tuple[int, int]]],
    n_left: int,
    n_right: int,
    initial_match_l: Sequence[int] | None = None,
) -> tuple[int, int, list[int]]:
    """Min-cost max-cardinality bipartite assignment (small integer costs).

    ``adj_cost[u]`` lists ``(v, cost)`` edges. Successive shortest augmenting
    paths; each augmentation finds a shortest path in the residual graph via
    SPFA (label-correcting Bellman-Ford — residual back edges carry negative
    costs but an extreme matching admits no negative cycle).

    ``initial_match_l`` may seed a *zero-cost* partial matching (RECTLR's
    "stay" edges: types already sitting in a valid slot of their own). A
    zero-cost matching is trivially extreme (minimum cost among matchings of
    its cardinality), so SSP stays exact while only the displaced types need
    augmentation — the controller becomes O(displaced x E) per failure event
    instead of O(N x E).

    Returns ``(matched, total_cost, match_l)``.
    """
    match_l = [-1] * n_left
    match_r = [-1] * n_right
    matched = 0
    total_cost = 0
    if initial_match_l is not None:
        for u, v in enumerate(initial_match_l):
            if v >= 0:
                assert match_r[v] == -1, "initial matching must be injective"
                match_l[u] = v
                match_r[v] = u
                matched += 1

    cost_of = [dict(row) for row in adj_cost]

    for src in range(n_left):
        if match_l[src] != -1:
            continue
        # SPFA shortest alternating path from src to any free right vertex.
        dist_l = [_INF] * n_left
        dist_r = [_INF] * n_right
        par_r = [-1] * n_right   # right v reached from left par_r[v]
        dist_l[src] = 0.0
        q: deque[int] = deque([src])
        in_q = [False] * n_left
        in_q[src] = True
        while q:
            u = q.popleft()
            in_q[u] = False
            du = dist_l[u]
            for v, c in adj_cost[u]:
                nd = du + c
                if nd < dist_r[v]:
                    dist_r[v] = nd
                    par_r[v] = u
                    w = match_r[v]
                    if w != -1:
                        nd2 = nd - cost_of[w][v]   # residual back edge
                        if nd2 < dist_l[w]:
                            dist_l[w] = nd2
                            if not in_q[w]:
                                q.append(w)
                                in_q[w] = True
        best_v, best_d = -1, _INF
        for v in range(n_right):
            if match_r[v] == -1 and dist_r[v] < best_d:
                best_d, best_v = dist_r[v], v
        if best_v == -1:
            continue  # src cannot be matched at all
        # augment: walk parents back to src, flipping matched edges
        v = best_v
        while True:
            u = par_r[v]
            prev_v = match_l[u]   # the right vertex u was matched to (-1 @src)
            match_l[u] = v
            match_r[v] = u
            if u == src:
                break
            v = prev_v
        matched += 1
        total_cost += int(best_d)
    return matched, total_cost, match_l
