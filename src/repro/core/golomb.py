"""Cyclic Golomb-ruler shard placement (paper Def. B.1, Lemma B.2).

A SPARe scheme ``(N, r)`` distributes ``N`` shard *types* across ``N``
model-parallel groups with redundancy ``r`` using an optimal Golomb ruler
``G_r = {g_0=0, ..., g_{r-1}}`` interpreted cyclically modulo ``N``:

    H_i = {(i - g) mod N : g in G_r}     (host set of type i)
    T_w = {(w + g) mod N : g in G_r}     (type set of group w)

The ruler property — all pairwise differences distinct — carries to Z_N
whenever ``N >= 2*g_{r-1} + 1``, and then guarantees ``|H_i ∩ H_j| <= 1``
for i != j (Lemma B.2): no two shard types share more than one host, which
makes wipe-out events of different types nearly independent (the Poisson
approximation underlying Thm. 4.1).

This module provides verified optimal rulers for ``r <= 27`` (covering the
paper's full sweep: N=200 up to r=12, N=600 up to r=20, N=1000 up to r=26)
plus a greedy modular Sidon-set fallback for configurations where the table
ruler does not fit modulo ``N``.
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np

__all__ = [
    "OPTIMAL_RULERS",
    "is_cyclic_golomb",
    "golomb_ruler",
    "host_sets",
    "type_sets",
    "max_redundancy",
    "validate_placement",
]

# Optimal Golomb rulers (marks), OGR project / OEIS A003022 canonical forms.
# OPTIMAL_RULERS[r] has r marks, first 0, minimal last mark. Every entry is
# re-verified by tests (all pairwise differences distinct as integers).
OPTIMAL_RULERS: dict[int, tuple[int, ...]] = {
    1: (0,),
    2: (0, 1),
    3: (0, 1, 3),
    4: (0, 1, 4, 6),
    5: (0, 1, 4, 9, 11),
    6: (0, 1, 4, 10, 12, 17),
    7: (0, 1, 4, 10, 18, 23, 25),
    8: (0, 1, 4, 9, 15, 22, 32, 34),
    9: (0, 1, 5, 12, 25, 27, 35, 41, 44),
    10: (0, 1, 6, 10, 23, 26, 34, 41, 53, 55),
    11: (0, 1, 4, 13, 28, 33, 47, 54, 64, 70, 72),
    12: (0, 2, 6, 24, 29, 40, 43, 55, 68, 75, 76, 85),
    13: (0, 2, 5, 25, 37, 43, 59, 70, 85, 89, 98, 99, 106),
    14: (0, 4, 6, 20, 35, 52, 59, 77, 78, 86, 89, 99, 122, 127),
    15: (0, 4, 20, 30, 57, 59, 62, 76, 100, 111, 123, 136, 144, 145, 151),
    16: (0, 1, 4, 11, 26, 32, 56, 68, 76, 115, 117, 134, 150, 163, 168, 177),
    17: (0, 5, 7, 17, 52, 56, 67, 80, 81, 100, 122, 138, 159, 165, 168, 191,
         199),
    18: (0, 2, 10, 22, 53, 56, 82, 83, 89, 98, 130, 148, 153, 167, 188, 192,
         205, 216),
    19: (0, 1, 6, 25, 32, 72, 100, 108, 120, 130, 153, 169, 187, 190, 204,
         231, 233, 242, 246),
    20: (0, 1, 8, 11, 68, 77, 94, 116, 121, 156, 158, 179, 194, 208, 212,
         228, 240, 253, 259, 283),
    21: (0, 2, 24, 56, 77, 82, 83, 95, 129, 144, 179, 186, 195, 255, 265,
         285, 293, 296, 310, 329, 333),
    22: (0, 1, 9, 14, 43, 70, 106, 122, 124, 128, 159, 179, 204, 223, 253,
         263, 270, 291, 330, 341, 353, 356),
    23: (0, 3, 7, 17, 61, 66, 91, 99, 114, 159, 171, 199, 200, 226, 235, 246,
         277, 316, 329, 348, 350, 366, 372),
    24: (0, 9, 33, 37, 38, 97, 122, 129, 140, 142, 152, 191, 205, 208, 252,
         278, 286, 326, 332, 353, 368, 384, 403, 425),
    25: (0, 12, 29, 39, 72, 91, 146, 157, 160, 161, 166, 191, 207, 214, 258,
         290, 316, 354, 372, 394, 396, 431, 459, 467, 480),
    26: (0, 1, 33, 83, 104, 110, 124, 163, 185, 200, 203, 249, 251, 258, 314,
         318, 343, 356, 386, 430, 440, 456, 464, 475, 487, 492),
    27: (0, 3, 15, 41, 66, 95, 97, 106, 142, 152, 220, 221, 225, 242, 295,
         330, 338, 354, 382, 388, 402, 415, 486, 504, 523, 546, 553),
}


def is_cyclic_golomb(marks: tuple[int, ...] | list[int], n: int) -> bool:
    """True iff all pairwise differences of ``marks`` are distinct and
    non-zero modulo ``n`` (i.e. ``marks`` is a Sidon / B_2 set in Z_n).

    This is the exact property Lemma B.2 needs: it implies
    ``|H_i ∩ H_j| <= 1`` for every pair of distinct shard types.
    """
    marks = list(marks)
    r = len(marks)
    if len(set(m % n for m in marks)) != r:
        return False
    diffs: set[int] = set()
    for a in range(r):
        for b in range(r):
            if a == b:
                continue
            d = (marks[a] - marks[b]) % n
            if d == 0 or d in diffs:
                return False
            diffs.add(d)
    return True


def _greedy_sidon_mod(r: int, n: int) -> tuple[int, ...] | None:
    """Greedy (Mian–Chowla style) Sidon set of size ``r`` in Z_n.

    Fallback for (N, r) where the optimal line ruler does not embed
    cyclically. Returns None if the greedy scan exhausts Z_n first.
    """
    marks = [0]
    diffs: set[int] = set()
    for cand in range(1, n):
        new_diffs = []
        ok = True
        for m in marks:
            d1 = (cand - m) % n
            d2 = (m - cand) % n
            if d1 == 0 or d2 == 0 or d1 in diffs or d2 in diffs or d1 == d2:
                ok = False
                break
            new_diffs.append(d1)
            new_diffs.append(d2)
        # also check the new differences don't collide with each other
        if ok and len(set(new_diffs)) != len(new_diffs):
            ok = False
        if ok:
            marks.append(cand)
            diffs.update(new_diffs)
            if len(marks) == r:
                return tuple(marks)
    return None


@lru_cache(maxsize=None)
def golomb_ruler(r: int, n: int) -> tuple[int, ...]:
    """Return a ruler of ``r`` marks that is cyclically Golomb modulo ``n``.

    Preference order: (1) the optimal ruler table (minimal span — loosest
    ``N >= 2*g_max + 1`` embedding constraint, matching the paper's choice),
    (2) greedy modular Sidon fallback.

    Raises ValueError when no such set can exist
    (pigeonhole: ``r*(r-1) > n - 1``) or the fallback fails.
    """
    if r < 1:
        raise ValueError(f"redundancy r must be >= 1, got {r}")
    if r == 1:
        return (0,)
    if r * (r - 1) > n - 1:
        raise ValueError(
            f"no cyclic Golomb ruler with r={r} marks exists mod N={n}: "
            f"needs r(r-1)={r*(r-1)} distinct non-zero residues, "
            f"only {n-1} available. Reduce r or increase N."
        )
    table = OPTIMAL_RULERS.get(r)
    if table is not None and is_cyclic_golomb(table, n):
        return table
    greedy = _greedy_sidon_mod(r, n)
    if greedy is not None and is_cyclic_golomb(greedy, n):
        return greedy
    raise ValueError(f"could not construct cyclic Golomb ruler for r={r}, N={n}")


def host_sets(n: int, r: int) -> np.ndarray:
    """Host sets H_i (paper Eq. 10) as an int array of shape (N, r).

    ``host_sets(n, r)[i]`` lists the groups hosting shard type ``i``.
    """
    g = np.asarray(golomb_ruler(r, n), dtype=np.int64)
    types = np.arange(n, dtype=np.int64)[:, None]
    return (types - g[None, :]) % n


def type_sets(n: int, r: int) -> np.ndarray:
    """Type sets T_w (paper Eq. 11) as an int array of shape (N, r).

    ``type_sets(n, r)[w]`` lists the shard types hosted by group ``w``.
    The default local stack order of group ``w`` is exactly this row:
    stack j computes type ``(w + g_j) mod N`` — stack 0 covers all N types
    (cyclic rotation), so the no-failure all-reduce stack is 1.
    """
    g = np.asarray(golomb_ruler(r, n), dtype=np.int64)
    groups = np.arange(n, dtype=np.int64)[:, None]
    return (groups + g[None, :]) % n


def max_redundancy(n: int) -> int:
    """Largest r this module can place for a given N (used by config checks)."""
    best = 1
    for r in range(2, min(len(OPTIMAL_RULERS) + 1, n)):
        try:
            golomb_ruler(r, n)
            best = r
        except ValueError:
            break
    return best


def validate_placement(n: int, r: int) -> None:
    """Assert the Lemma B.2 invariant |H_i ∩ H_j| <= 1 for all i != j.

    O(N * r^2) via the difference-set argument: two types i != j share two
    hosts iff some difference repeats; we check directly on host sets for
    defence in depth (tests call this for every config).
    """
    h = host_sets(n, r)
    # membership matrix: M[i, w] = 1 iff group w hosts type i
    m = np.zeros((n, n), dtype=np.int8)
    rows = np.repeat(np.arange(n), r)
    m[rows, h.ravel()] = 1
    overlap = m @ m.T  # overlap[i, j] = |H_i ∩ H_j|
    np.fill_diagonal(overlap, 0)
    worst = int(overlap.max()) if n > 1 else 0
    if worst > 1:
        raise AssertionError(
            f"placement invariant violated for N={n}, r={r}: "
            f"two types share {worst} hosts"
        )
