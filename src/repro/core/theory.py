"""Closed-form SPARe theory (paper Sec. 4, Thms 4.1-4.3, Eqs. 1-2, 7-8).

Everything here is a pure function of ``(N, r)`` and the system timing
parameters — no simulation. The Monte-Carlo module and the DES validate
these formulas (paper App. C reports <= 1.13 % MAPE on ``mu`` and 0.60 %
on the average all-reduce stack; our tests reproduce those bands).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = [
    "mu",
    "mu_poisson_sum",
    "capacity",
    "patch_probability",
    "s_bar",
    "s_bar_lower",
    "tc_star",
    "availability_star",
    "SystemTimes",
    "j_normalized",
    "r_star",
    "replication_mu",
]


# --------------------------------------------------------------------- #
# Thm. 4.1 — endurable failure count                                    #
# --------------------------------------------------------------------- #
def mu(n: int, r: int) -> float:
    """Average failure count before first wipe-out (Eq. 3):
    ``mu(N, r) ~= Gamma(1/r)/r * N^(1 - 1/r)``."""
    if r < 1:
        raise ValueError("r >= 1 required")
    if r == 1:
        return 1.0  # a single failure wipes its only host
    return math.gamma(1.0 / r) / r * n ** (1.0 - 1.0 / r)


def mu_poisson_sum(n: int, r: int) -> float:
    """The pre-asymptotic Poisson sum (Eq. 4 middle form):
    ``sum_k exp(-N (k/N)^r)`` — tighter at small N, used by tests to bound
    the Gamma closed form."""
    return sum(math.exp(-n * (k / n) ** r) for k in range(n))


def replication_mu(n: int, r: int) -> float:
    """Endurable failures of *traditional replication* with the same layout
    statistics (Ferreira et al. 2011): identical asymptotics to Eq. 3 —
    SPARe matches replication's availability (paper Sec. 4.1)."""
    return mu(n, r)


# --------------------------------------------------------------------- #
# Thm. 4.2 — computation overhead                                        #
# --------------------------------------------------------------------- #
def capacity(k: int, n: int) -> int:
    """Capacity lower bound ``c(k) = ceil(N / (N - k))`` of the all-reduce
    stack at ``k`` failures."""
    if k >= n:
        raise ValueError("k < N required")
    return -(-n // (n - k))  # ceil division


def patch_probability(k: int, n: int) -> float:
    """``rho_k = max(0, 2N - n_k) / n_k`` with ``n_k = c(k)(N-k)``:
    first-order probability that a failure at count ``k`` hits a singleton
    type and forces a patch compute."""
    n_k = capacity(k, n) * (n - k)
    return max(0, 2 * n - n_k) / n_k


def s_bar(n: int, r: int) -> float:
    """Average computation overhead before first wipe-out (Eq. 5):
    ``(1/floor(mu)) * sum_{k<floor(mu)} (c(k) + rho_k)``."""
    m = int(mu(n, r))
    m = max(m, 1)
    return sum(capacity(k, n) + patch_probability(k, n) for k in range(m)) / m


def s_bar_lower(n: int, r: int) -> float:
    """Idealistic lower bound (Eq. 6) — no patch computes (early failure
    detection): ``(1/floor(mu)) * sum_k c(k)``."""
    m = int(mu(n, r))
    m = max(m, 1)
    return sum(capacity(k, n) for k in range(m)) / m


# --------------------------------------------------------------------- #
# Eqs. 1-2 — availability-optimal checkpointing (Saxena et al. 2024)    #
# --------------------------------------------------------------------- #
def tc_star(t_f: float, t_s: float, t_r: float) -> float:
    """Optimal checkpointing period (Eq. 1):
    ``T_c* = T_s + sqrt(T_s^2 + 2 T_s (T_f + T_r))``."""
    return t_s + math.sqrt(t_s * t_s + 2.0 * t_s * (t_f + t_r))


def availability_star(t_f: float, t_s: float, t_r: float) -> float:
    """Maximal availability at ``T_c*`` (Eq. 2)."""
    t_c = tc_star(t_f, t_s, t_r)
    return (t_f - t_f * t_s / t_c) / (t_f + t_c / 2.0 + t_r)


# --------------------------------------------------------------------- #
# Eq. 7 / Thm. 4.3 — joint optimization                                  #
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class SystemTimes:
    """Fixed timing parameters (paper Table 1 defaults for 600k H100)."""

    mtbf_node: float = 300.0     # m — system MTBF on *node* failures [s]
    t_save: float = 60.0         # T_s — checkpoint save time [s]
    t_restart: float = 3600.0    # T_r — global restart latency [s]


def j_normalized(r: int, n: int, times: SystemTimes = SystemTimes()) -> float:
    """Normalized time-to-train ``J(r) = S_bar(N,r) / A*(mu(N,r) m)`` (Eq. 7)."""
    t_f = mu(n, r) * times.mtbf_node
    a = availability_star(t_f, times.t_save, times.t_restart)
    return s_bar(n, r) / a


def r_star(n: int) -> int:
    """Optimal redundancy (Eq. 8): ``r* ~= floor(log2 N + 0.833)``."""
    return int(math.floor(math.log2(n) + 0.833))


def r_star_search(
    n: int, times: SystemTimes = SystemTimes(), r_max: int | None = None
) -> int:
    """Numerical argmin of J(r) — used to cross-check Eq. 8 and to pick the
    deployed redundancy for a concrete parameter set (the paper notes the
    closed form drifts by +-1-2 under Weibull failures)."""
    r_max = r_max or max(2, int(2 * math.log2(n)) + 4)
    best_r, best_j = 2, float("inf")
    for r in range(2, r_max + 1):
        if r * (r - 1) > n - 1:
            break  # no cyclic Golomb ruler can exist (pigeonhole)
        j = j_normalized(r, n, times)
        if j < best_j:
            best_r, best_j = r, j
    return best_r
