"""SPARe core — the paper's primary contribution as a composable module.

Layers:

* :mod:`repro.core.golomb`    — cyclic Golomb-ruler shard placement (Def. B.1)
* :mod:`repro.core.matching`  — Hopcroft-Karp / incremental matching / MCMF
* :mod:`repro.core.state`     — Alg. 1 protocol state (stacks, survivors, S_A)
* :mod:`repro.core.rectlr`    — Alg. 2 reordering controller (3 phases)
* :mod:`repro.core.theory`    — Thms. 4.1-4.3 closed forms, Eqs. 1-2, 7-8
* :mod:`repro.core.montecarlo`— App. C validation driver
"""
from .golomb import golomb_ruler, host_sets, type_sets, validate_placement
from .rectlr import Rectlr, RectlrOutcome
from .state import SpareState
from .theory import (
    SystemTimes,
    availability_star,
    capacity,
    j_normalized,
    mu,
    r_star,
    s_bar,
    s_bar_lower,
    tc_star,
)

__all__ = [
    "golomb_ruler", "host_sets", "type_sets", "validate_placement",
    "SpareState", "Rectlr", "RectlrOutcome",
    "mu", "s_bar", "s_bar_lower", "capacity", "tc_star",
    "availability_star", "j_normalized", "r_star", "SystemTimes",
]
