from .checkpoint import (CheckpointManager, restore_checkpoint,
                         save_checkpoint, sweep_stale_tmp)

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint",
           "sweep_stale_tmp"]
