"""Multi-tier checkpointing with the availability-optimal interval.

Tiers:
  * **in-memory snapshot** — a host-side reference to the last good
    (params, opt_state) pytree. SPARe rolls back to this on wipe-out
    without touching storage (GEMINI-style; restart cost modeled by the
    DES, not paid here).
  * **disk** — npz-sharded pytree + JSON manifest, written by a
    background thread (training continues during the save; the manifest
    is committed last, so a crash mid-write leaves the previous
    checkpoint intact).

The save *interval* comes from Eq. 1 (Saxena et al.): the trainer calls
:meth:`CheckpointManager.maybe_save` with the wall clock and we decide
against ``T_c*`` computed from the SPARe-extended failure interval
``T_f = mu(N, r) * m`` — checkpointing co-designed with the redundancy,
exactly the paper's SPARe+CKPT.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np

from repro.core.theory import mu, tc_star

__all__ = ["save_checkpoint", "restore_checkpoint", "sweep_stale_tmp",
           "CheckpointManager"]


def _tmp_dir(directory: Path, step: int) -> Path:
    """Staging directory for one save. Dot-prefixed so a crash leftover
    can never match the ``step_*`` glob that ``restore_checkpoint`` and
    ``CheckpointManager._gc`` scan (a leftover ``step_00000100.tmp``
    used to make ``int("00000100.tmp")`` raise on every later restore)."""
    return directory / f".tmp_step_{step:08d}"


def sweep_stale_tmp(directory: str | Path) -> list[Path]:
    """Clean up crash leftovers from interrupted saves.

    ``.tmp_step_*`` staging dirs and the legacy ``step_*.tmp`` form are
    removed (a crash may have left them half-written). A ``.old_step_*``
    dir is a *complete* checkpoint parked by the overwrite-safe commit:
    if the crash hit between parking the old copy and committing the new
    one, the committed name is missing — rename the parked copy back
    (the promised "crash leaves the previous checkpoint intact") instead
    of deleting the only good copy. Returns the paths removed.
    """
    d = Path(directory)
    stale = [p for p in d.glob(".tmp_step_*") if p.is_dir()]
    stale += [p for p in d.glob("step_*.tmp") if p.is_dir()]
    stale += _recover_parked(d)
    for p in stale:
        shutil.rmtree(p, ignore_errors=True)
    return stale


def _recover_parked(d: Path) -> list[Path]:
    """Heal the overwrite-commit crash window: a ``.old_step_*`` dir is
    a complete checkpoint parked before the new copy committed. If the
    committed name is missing, rename the park back; otherwise return it
    as junk for the caller to delete."""
    junk = []
    for p in d.glob(".old_step_*"):
        if not p.is_dir():
            continue
        committed = d / p.name[len(".old_"):]
        if committed.exists():
            junk.append(p)              # commit finished; park is junk
        else:
            p.rename(committed)         # recover the previous checkpoint
    return junk


def _flatten_with_names(tree: Any) -> list[tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", e))))
            for e in path)
        out.append((name, np.asarray(leaf)))
    return out


def save_checkpoint(directory: str | Path, step: int, tree: Any, *,
                    clock=time.time) -> Path:
    """Write one checkpoint: <dir>/step_<n>/{shard_*.npz, manifest.json}.

    bfloat16 (an ml_dtypes extension numpy can't serialize) is stored as a
    uint16 bit-view with the true dtype recorded in the manifest.

    ``clock`` supplies the manifest's provenance timestamp (wall clock by
    default). It is the ONLY nondeterministic input: with a fixed clock,
    re-saving the same tree is byte-identical — npz payloads included —
    which is what lets restore tests and content-addressed storage
    compare checkpoints by bytes.
    """
    d = Path(directory) / f"step_{step:08d}"
    tmp = _tmp_dir(Path(directory), step)
    if tmp.exists():                    # leftover of an interrupted save
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten_with_names(tree)
    names = [n for n, _ in flat]
    dtypes = []
    stored = {}
    for n, a in flat:
        dtypes.append(str(a.dtype))
        if a.dtype.itemsize == 2 and a.dtype.kind == "V" or \
                str(a.dtype) == "bfloat16":
            a = a.view(np.uint16)
        stored[n] = a
    np.savez(tmp / "shard_0.npz", **stored)
    manifest = {
        "step": step,
        "leaves": names,
        "dtypes": dtypes,
        "time": clock(),
        "format": "npz-v1",
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    # overwrite-safe commit: re-saving a step after a rollback replaces
    # the old directory (plain rename onto a non-empty dir raises). The
    # old copy is parked under a dot-prefixed name first so the commit
    # point stays a single rename; a crash inside the park->commit
    # window is healed by sweep_stale_tmp, which renames the parked
    # complete copy back — either the old or the new checkpoint
    # survives, never a half-written one.
    if d.exists():
        old = d.with_name(f".old_{d.name}")
        if old.exists():
            shutil.rmtree(old)
        d.rename(old)
        tmp.rename(d)                   # atomic commit
        shutil.rmtree(old)
    else:
        tmp.rename(d)                   # atomic commit
    return d


def restore_checkpoint(directory: str | Path, tree_like: Any,
                       step: int | None = None) -> tuple[int, Any]:
    """Restore the latest (or given) step into the structure of
    ``tree_like``. Works across parallelism layouts: leaves are stored
    full-size (universal-checkpoint style) and resharded on load by
    device_put with the caller's shardings."""
    d = Path(directory)
    # only committed checkpoints parse: staging dirs are dot-prefixed
    # now, but leftovers from older versions (``step_<n>.tmp``) must not
    # break the scan either
    by_step = {int(p.name.split("_")[1]): p for p in d.glob("step_*")
               if p.is_dir() and p.name.split("_")[1].isdigit()}
    # a save that crashed inside the overwrite-commit window leaves the
    # previous (complete) checkpoint parked under ``.old_step_*``; read
    # it in place — renaming here could race a concurrent in-flight
    # async save's own commit (sweep_stale_tmp heals the name on the
    # next CheckpointManager init)
    for p in d.glob(".old_step_*"):
        s = p.name.rsplit("_", 1)[1]
        if p.is_dir() and s.isdigit() and int(s) not in by_step:
            by_step[int(s)] = p
    if not by_step:
        raise FileNotFoundError(f"no checkpoints under {d}")
    step = step if step is not None else max(by_step)
    if step not in by_step:
        raise FileNotFoundError(f"no checkpoint for step {step} under {d}")
    cdir = by_step[step]
    data = np.load(cdir / "shard_0.npz")
    manifest = json.loads((cdir / "manifest.json").read_text())
    names = manifest["leaves"]
    dtypes = manifest.get("dtypes", [None] * len(names))
    flat, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(flat) == len(names), (
        f"checkpoint has {len(names)} leaves, model expects {len(flat)}")
    import ml_dtypes
    restored = []
    for n, dt, leaf in zip(names, dtypes, flat):
        a = data[n]
        if dt == "bfloat16":
            a = a.view(ml_dtypes.bfloat16)
        restored.append(np.asarray(a, dtype=leaf.dtype).reshape(leaf.shape))
    return step, jax.tree_util.tree_unflatten(treedef, restored)


class CheckpointManager:
    """Async two-tier manager with the Eq.-1 optimal interval.

    Background-save failures are never silent: the worker retries once
    (after ``retry_backoff`` seconds — transient storage hiccups are the
    common case), and a save that still fails is captured and re-raised
    from the next :meth:`wait` or :meth:`maybe_save` call on the
    training thread. ``saves`` counts only checkpoints that durably
    committed, and a failed save rewinds ``_last_save_wall`` so the
    interval clock re-arms immediately.

    ``clock`` stamps manifest provenance (wall time); ``monotonic``
    drives the save-interval decision — inject a fake for deterministic
    :meth:`due` tests, exactly like ``clock=`` for byte-stable saves.
    """

    def __init__(self, directory: str | Path, *, n_groups: int,
                 redundancy: int, mtbf: float, t_save: float,
                 t_restart: float, keep: int = 3, clock=time.time,
                 monotonic=time.monotonic, retry_backoff: float = 0.1):
        self.directory = Path(directory)
        self.clock = clock              # manifest provenance timestamps
        self.monotonic = monotonic      # save-interval clock (injectable)
        self.retry_backoff = float(retry_backoff)
        if self.directory.exists():
            sweep_stale_tmp(self.directory)  # crash leftovers from prior runs
        self.keep = keep
        t_f = mu(n_groups, redundancy) * mtbf
        self.interval = tc_star(t_f, t_save, t_restart)
        self._last_save_wall = self.monotonic()
        self._thread: threading.Thread | None = None
        self._outcome: dict[str, Any] | None = None
        self._save_error: BaseException | None = None
        self._snapshot: tuple[int, Any] | None = None
        self.saves = 0                  # committed checkpoints only
        self.save_failures = 0          # saves that failed even the retry

    # ---------------- in-memory tier ---------------- #
    def snapshot(self, step: int, tree: Any) -> None:
        """Host-DRAM snapshot (GEMINI-style memory tier). Must be a real
        copy: the train step donates its inputs, so holding device-array
        references would hand back deleted buffers after a rollback."""
        self._snapshot = (step, jax.tree.map(np.asarray, tree))

    def rollback(self) -> tuple[int, Any]:
        assert self._snapshot is not None, "no snapshot taken yet"
        return self._snapshot

    # ---------------- disk tier ---------------- #
    def due(self, now: float | None = None) -> bool:
        self._fold()    # a finished failed save rewinds the clock here
        now = self.monotonic() if now is None else now
        return (now - self._last_save_wall) >= self.interval

    def maybe_save(self, step: int, tree: Any, *, block: bool = False,
                   force: bool = False) -> bool:
        if not force and not self.due():
            return False
        self.wait()                     # one in-flight save at a time;
        #                                 re-raises a prior failed save
        host_tree = jax.tree.map(np.asarray, tree)   # device -> host copy
        # advance the interval clock at dispatch so due() cannot refire
        # while this save is in flight; a failure rewinds it (in _fold)
        prev_wall, self._last_save_wall = self._last_save_wall, \
            self.monotonic()
        # one-shot result channel: the worker writes ONLY this local
        # dict; all manager bookkeeping (`saves`, `save_failures`, the
        # interval rewind) folds in on the training thread after join
        outcome: dict[str, Any] = {"prev_wall": prev_wall}

        def work():
            try:
                try:
                    save_checkpoint(self.directory, step, host_tree,
                                    clock=self.clock)
                except Exception:
                    time.sleep(self.retry_backoff)   # transient hiccup?
                    save_checkpoint(self.directory, step, host_tree,
                                    clock=self.clock)
                self._gc()
            except BaseException as e:   # noqa: BLE001 - surfaced on wait()
                outcome["error"] = e
                return
            outcome["ok"] = True

        self._outcome = outcome
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if block:
            self.wait()
        return True

    def _fold(self) -> None:
        """Fold a *finished* background save's outcome into the manager
        (non-blocking): `saves` counts durable commits, never optimistic
        dispatches; a failure rewinds the interval clock so :meth:`due`
        re-arms, and parks the error for :meth:`wait` to raise."""
        t = self._thread
        if t is None or t.is_alive():
            return
        t.join()
        self._thread = None
        outcome, self._outcome = self._outcome, None
        if outcome is None:
            return
        if "error" in outcome:
            self._save_error = outcome["error"]
            self.save_failures += 1
            self._last_save_wall = outcome["prev_wall"]
        elif outcome.get("ok"):
            self.saves += 1

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
        self._fold()
        if self._save_error is not None:
            err, self._save_error = self._save_error, None
            raise RuntimeError(
                "background checkpoint save failed "
                "(original attempt and one retry)") from err

    def _gc(self) -> None:
        dirs = sorted(p for p in self.directory.glob("step_*")
                      if p.name.split("_")[1].isdigit())
        for old in dirs[: -self.keep]:
            for f in old.iterdir():
                f.unlink()
            old.rmdir()

    def restore_latest(self, tree_like: Any) -> tuple[int, Any]:
        self.wait()
        return restore_checkpoint(self.directory, tree_like)
