"""Cluster topology model: groups -> hosts -> racks -> pods -> DCI domains.

The DES abstracts a cluster as ``N`` data-parallel groups of ``M``
model-sharded accelerators (paper Table 1: 600k H100 at N=600 means
1000 GPUs per group). Physically those GPUs live on hosts packed into
racks, racks into pods, pods into datacenter-interconnect (DCI) domains
— and production failures respect *that* hierarchy, not the logical
group numbering: a PDU trip takes a rack, a cooling event takes a pod,
a fiber cut takes a DCI domain (Kokolis et al. 2025 report rack- and
pod-level co-failures dominating downtime at 100k+ scale).

:class:`ClusterTopology` maps the hierarchy with a contiguous layout —
group ``g`` occupies hosts ``[g*H, (g+1)*H)``, rack ``k`` holds hosts
``[k*R, (k+1)*R)``, and so on — which is exactly how the production
mesh in :mod:`repro.launch.mesh` lays DP slices along the ``pod`` and
``data`` axes (the ``pod`` axis crosses the DCI boundary). Everything
is integer arithmetic on demand: a 600k-GPU preset costs nothing to
instantiate, and instances are frozen/hashable/picklable so campaign
cells can carry them across process boundaries.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = ["ClusterTopology", "TOPOLOGY_PRESETS", "topology_from_spec"]

#: failure scopes ordered from smallest to largest blast radius
SCOPES = ("group", "host", "rack", "pod", "dci")


@dataclass(frozen=True)
class ClusterTopology:
    """Contiguous group -> host -> rack -> pod -> DCI layout.

    ``hosts_per_group`` is the model-parallel span of one DP group (how
    many hosts its M shards occupy); the remaining fields describe the
    physical packaging. Defaults give a small, rack-dominated layout
    suitable for the N=200..1000 DES scales.
    """

    n_groups: int
    hosts_per_group: int = 1
    hosts_per_rack: int = 8
    racks_per_pod: int = 16
    pods_per_dci: int = 4
    gpus_per_host: int = 8

    def __post_init__(self):
        for f in ("n_groups", "hosts_per_group", "hosts_per_rack",
                  "racks_per_pod", "pods_per_dci", "gpus_per_host"):
            if getattr(self, f) < 1:
                raise ValueError(f"{f} must be >= 1")

    # ------------------------------------------------------------- #
    # sizes                                                         #
    # ------------------------------------------------------------- #
    @property
    def n_hosts(self) -> int:
        return self.n_groups * self.hosts_per_group

    @property
    def n_racks(self) -> int:
        return math.ceil(self.n_hosts / self.hosts_per_rack)

    @property
    def n_pods(self) -> int:
        return math.ceil(self.n_racks / self.racks_per_pod)

    @property
    def n_dcis(self) -> int:
        return math.ceil(self.n_pods / self.pods_per_dci)

    @property
    def total_gpus(self) -> int:
        return self.n_hosts * self.gpus_per_host

    # ------------------------------------------------------------- #
    # downward maps (containment)                                   #
    # ------------------------------------------------------------- #
    def hosts_of_group(self, g: int) -> range:
        return range(g * self.hosts_per_group, (g + 1) * self.hosts_per_group)

    def rack_of_host(self, h: int) -> int:
        return h // self.hosts_per_rack

    def pod_of_rack(self, k: int) -> int:
        return k // self.racks_per_pod

    def dci_of_pod(self, q: int) -> int:
        return q // self.pods_per_dci

    def group_of_host(self, h: int) -> int:
        return h // self.hosts_per_group

    def racks_of_group(self, g: int) -> range:
        first = self.rack_of_host(g * self.hosts_per_group)
        last = self.rack_of_host((g + 1) * self.hosts_per_group - 1)
        return range(first, last + 1)

    # ------------------------------------------------------------- #
    # upward maps (blast radii)                                     #
    # ------------------------------------------------------------- #
    def _groups_of_host_span(self, h0: int, h1: int) -> list[int]:
        """Groups with at least one host in ``[h0, h1)``."""
        g0 = h0 // self.hosts_per_group
        g1 = (h1 - 1) // self.hosts_per_group
        return [g for g in range(g0, g1 + 1) if g < self.n_groups]

    def groups_in_rack(self, k: int) -> list[int]:
        h0 = k * self.hosts_per_rack
        return self._groups_of_host_span(h0, min(h0 + self.hosts_per_rack,
                                                 self.n_hosts))

    def groups_in_pod(self, q: int) -> list[int]:
        k0 = q * self.racks_per_pod
        h0 = k0 * self.hosts_per_rack
        h1 = (k0 + self.racks_per_pod) * self.hosts_per_rack
        return self._groups_of_host_span(h0, min(h1, self.n_hosts))

    def groups_in_dci(self, d: int) -> list[int]:
        q0 = d * self.pods_per_dci
        h0 = q0 * self.racks_per_pod * self.hosts_per_rack
        h1 = ((q0 + self.pods_per_dci) * self.racks_per_pod
              * self.hosts_per_rack)
        return self._groups_of_host_span(h0, min(h1, self.n_hosts))

    def blast_radius(self, g: int, scope: str) -> list[int]:
        """All groups co-located with group ``g`` at the given scope —
        the simultaneous-failure set when that domain fails."""
        if scope in ("group", "host"):
            return [g]
        groups: set[int] = set()
        if scope == "rack":
            for k in self.racks_of_group(g):
                groups.update(self.groups_in_rack(k))
        elif scope == "pod":
            pods = {self.pod_of_rack(k) for k in self.racks_of_group(g)}
            for q in pods:
                groups.update(self.groups_in_pod(q))
        elif scope == "dci":
            dcis = {self.dci_of_pod(self.pod_of_rack(k))
                    for k in self.racks_of_group(g)}
            for d in dcis:
                groups.update(self.groups_in_dci(d))
        else:
            raise ValueError(f"unknown scope {scope!r}; have {SCOPES}")
        return sorted(groups)

    def resolve(self, scope: str, loc: int) -> list[int]:
        """Trace-event resolution: groups killed by a failure of
        ``scope``-level location ``loc``. Locations wrap modulo the
        domain count so traces recorded on other cluster shapes replay
        portably."""
        if scope == "group":
            return [loc % self.n_groups]
        if scope == "host":
            return [self.group_of_host(loc % self.n_hosts)]
        if scope == "rack":
            return self.groups_in_rack(loc % self.n_racks)
        if scope == "pod":
            return self.groups_in_pod(loc % self.n_pods)
        if scope == "dci":
            return self.groups_in_dci(loc % self.n_dcis)
        raise ValueError(f"unknown scope {scope!r}; have {SCOPES}")

    # ------------------------------------------------------------- #
    # constructors                                                  #
    # ------------------------------------------------------------- #
    @classmethod
    def for_gpu_count(cls, total_gpus: int, n_groups: int,
                      gpus_per_host: int = 8, hosts_per_rack: int = 8,
                      racks_per_pod: int = 16,
                      pods_per_dci: int = 4) -> "ClusterTopology":
        """Size the hierarchy from a GPU budget (paper Table 1 scales:
        e.g. 600k GPUs over N=600 groups => 125 hosts per group)."""
        hosts_per_group = max(1, total_gpus // (n_groups * gpus_per_host))
        return cls(n_groups=n_groups, hosts_per_group=hosts_per_group,
                   hosts_per_rack=hosts_per_rack, racks_per_pod=racks_per_pod,
                   pods_per_dci=pods_per_dci, gpus_per_host=gpus_per_host)

    @classmethod
    def from_mesh(cls, multi_pod: bool = False) -> "ClusterTopology":
        """The production-mesh layout of :mod:`repro.launch.mesh`
        (without importing jax): single-pod (16, 16) => 16 DP groups in
        one pod; multi-pod (2, 16, 16) => 32 DP groups, the ``pod``
        axis crossing the DCI boundary (one pod per DCI domain)."""
        if multi_pod:
            return cls(n_groups=32, hosts_per_group=4, hosts_per_rack=8,
                       racks_per_pod=8, pods_per_dci=1, gpus_per_host=4)
        return cls(n_groups=16, hosts_per_group=4, hosts_per_rack=8,
                   racks_per_pod=8, pods_per_dci=1, gpus_per_host=4)


#: paper-scale presets (Table 1 N-points at 100k-600k GPUs)
TOPOLOGY_PRESETS: dict[str, dict] = {
    "100k": dict(total_gpus=100_000, n_groups=200),
    "200k": dict(total_gpus=200_000, n_groups=200),
    "360k": dict(total_gpus=360_000, n_groups=600),
    "600k": dict(total_gpus=600_000, n_groups=600),
    "1m":   dict(total_gpus=1_000_000, n_groups=1000),
}


def topology_from_spec(spec, n_groups: int | None = None) -> ClusterTopology:
    """Build a topology from a preset name, kwargs dict, or instance.

    ``None`` gives the default small layout for ``n_groups`` (which is
    then required). Dict specs may carry ``preset`` plus overrides.
    """
    if isinstance(spec, ClusterTopology):
        return spec
    if spec is None:
        if n_groups is None:
            raise ValueError("n_groups required when spec is None")
        return ClusterTopology(n_groups=n_groups)
    if isinstance(spec, str):
        if spec not in TOPOLOGY_PRESETS:
            raise KeyError(f"unknown topology preset {spec!r}; "
                           f"have {sorted(TOPOLOGY_PRESETS)}")
        return ClusterTopology.for_gpu_count(**TOPOLOGY_PRESETS[spec])
    if isinstance(spec, dict):
        kw = dict(spec)
        preset = kw.pop("preset", None)
        if preset is not None:
            base = dict(TOPOLOGY_PRESETS[preset])
            base.update(kw)
            return ClusterTopology.for_gpu_count(**base)
        if "total_gpus" in kw:
            return ClusterTopology.for_gpu_count(**kw)
        kw.setdefault("n_groups", n_groups)
        if kw["n_groups"] is None:
            raise ValueError("n_groups required in topology spec")
        return ClusterTopology(**kw)
    raise TypeError(f"cannot build a topology from {spec!r}")
