"""Campaign runner: declarative scenario grids, process-parallel, deterministic.

A *campaign* sweeps scheme x scale x redundancy x failure-regime x seed
and aggregates every cell's :class:`repro.des.SimResult` into byte-stable
CSV/JSON artifacts. It replaces the serial benchmark loops:

* grids are declarative (:class:`CampaignSpec` or JSON files — see
  ``python -m repro.launch.campaign``);
* cells fan out across a ``ProcessPoolExecutor``; each cell derives its
  RNG seed from a SHA-256 of its own key (:func:`cell_seed`), so a
  4-worker run is byte-identical to a 1-worker run of the same grid;
* wall-clock timings are reported separately (stderr / ``timing`` keys)
  and never enter the deterministic artifacts.

Cells are plain dicts (picklable, JSON-serializable); the worker entry
point :func:`run_cell` is module-level so the pool can import it.
"""
from __future__ import annotations

import csv
import dataclasses
import hashlib
import io
import json
import time
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass, field
from pathlib import Path

from ..des import DESParams, get_scheme
from ..des.engine import run_scheme
from .models import model_from_spec
from .topology import topology_from_spec

__all__ = [
    "ScenarioCell", "CampaignSpec", "CAMPAIGN_PRESETS",
    "cell_seed", "run_cell", "run_campaign", "parallel_map",
    "aggregate", "ranking_by_regime", "save_artifacts",
    "TRAINER_REGIME_MODELS", "trainer_regime_cells", "run_trainer_cell",
    "elastic_regime_cells", "run_elastic_cell",
    "gray_regime_cells", "run_gray_cell",
]

#: SimResult fields copied into each cell's result row (all deterministic)
RESULT_FIELDS = ("wall", "committed", "t0", "steps_done", "node_failures",
                 "wipeouts", "ckpt_count", "total_stacks", "patches",
                 "mode_switches")
DERIVED_FIELDS = ("ttt_norm", "availability", "avg_stacks")

#: cells without per-scheme redundancy (the r grid does not apply)
_R_FREE_SCHEMES = ("ckpt_only",)


# ------------------------------------------------------------------ #
# cells                                                              #
# ------------------------------------------------------------------ #
def _canon(obj) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def cell_key(cell: dict) -> str:
    """Canonical identity of a cell: every field that affects its
    simulation, in sorted-key JSON (stable across processes/platforms).
    ``base_seed`` is excluded — it salts the seed hash separately, so a
    raw ``spec.cells()`` dict and the same cell inside ``run_campaign``
    hash identically."""
    ident = {k: cell[k] for k in sorted(cell)
             if k not in ("label", "base_seed")}
    return _canon(ident)


def cell_seed(cell: dict, base_seed: int = 0) -> int:
    """Deterministic per-cell RNG seed: SHA-256 of the cell key, folded
    with the grid's seed axis. Independent of worker count, execution
    order, and ``PYTHONHASHSEED``."""
    digest = hashlib.sha256(
        f"{cell_key(cell)}|{base_seed}".encode()).digest()
    return int.from_bytes(digest[:8], "little") & 0x7FFF_FFFF


@dataclass
class ScenarioCell:
    """One point of the grid (kept as a dataclass for discoverability;
    the pool ships the ``as_dict`` form)."""

    scheme: str
    n: int
    model: dict
    seed: int
    steps: int
    r: int | None = None
    scheme_kwargs: dict = field(default_factory=dict)
    mtbf: float | None = None
    topology: object = None
    t_c: float | None = None
    max_wall: float | None = None

    def as_dict(self) -> dict:
        d = {"scheme": self.scheme, "n": self.n, "model": self.model,
             "seed": self.seed, "steps": self.steps}
        if self.r is not None:
            d["r"] = self.r
        if self.scheme_kwargs:
            d["scheme_kwargs"] = dict(self.scheme_kwargs)
        if self.mtbf is not None:
            d["mtbf"] = self.mtbf
        if self.topology is not None:
            topo = self.topology
            if dataclasses.is_dataclass(topo) and not isinstance(topo, type):
                topo = dataclasses.asdict(topo)   # JSON/key-stable form
            d["topology"] = topo
        if self.t_c is not None:
            d["t_c"] = self.t_c
        if self.max_wall is not None:
            d["max_wall"] = self.max_wall
        return d


@dataclass
class CampaignSpec:
    """Declarative grid: the cross product of every axis, with the ``r``
    axis skipped for redundancy-free schemes (``ckpt_only``).

    ``schemes`` entries are names or ``(name, kwargs)`` pairs; ``models``
    entries are ``{"kind": ..., "label": ..., **kwargs}`` specs
    (``label`` names the regime in artifacts and rankings).
    """

    name: str
    schemes: list = field(default_factory=lambda: ["spare"])
    ns: list[int] = field(default_factory=lambda: [200])
    rs: list[int] = field(default_factory=lambda: [9])
    models: list = field(default_factory=lambda: [{"kind": "weibull"}])
    seeds: list[int] = field(default_factory=lambda: [0])
    steps: int = 400
    mtbf: float | None = None
    topology: object = None
    base_seed: int = 0

    def cells(self) -> list[dict]:
        out = []
        for scheme in self.schemes:
            if isinstance(scheme, (tuple, list)):
                sname, skw = scheme[0], dict(scheme[1])
            else:
                sname, skw = scheme, {}
            if "r" in skw:                  # pinned r beats the r axis
                rs = [skw.pop("r")]
            elif sname in _R_FREE_SCHEMES:
                rs = [None]
            else:
                rs = self.rs
            for n in self.ns:
                for model in self.models:
                    spec = model if isinstance(model, dict) \
                        else {"kind": model}
                    for r in rs:
                        for seed in self.seeds:
                            cell = ScenarioCell(
                                scheme=sname, n=n, model=dict(spec),
                                seed=seed, steps=self.steps, r=r,
                                scheme_kwargs=skw, mtbf=self.mtbf,
                                topology=self.topology).as_dict()
                            cell["base_seed"] = self.base_seed
                            out.append(cell)
        return out

    @classmethod
    def from_json(cls, path: str | Path) -> "CampaignSpec":
        data = json.loads(Path(path).read_text())
        data.setdefault("name", Path(path).stem)
        return cls(**data)


# ------------------------------------------------------------------ #
# execution                                                          #
# ------------------------------------------------------------------ #
def run_cell(cell: dict) -> dict:
    """Worker entry point: simulate one cell, return a flat result dict.

    The only nondeterministic key is ``elapsed_s`` (wall-clock), which
    :func:`aggregate` strips from the artifacts.
    """
    params_kw = {"n": cell["n"], "steps": cell["steps"]}
    if cell.get("mtbf") is not None:
        params_kw["mtbf"] = cell["mtbf"]
    p = DESParams(**params_kw)
    topo = topology_from_spec(cell.get("topology"), n_groups=cell["n"]) \
        if cell.get("topology") is not None else None
    model = model_from_spec(cell["model"])
    skw = dict(cell.get("scheme_kwargs") or {})
    if cell.get("r") is not None:
        skw.setdefault("r", cell["r"])
    scheme = get_scheme(cell["scheme"], **skw)

    seed = cell_seed(cell, base_seed=cell.get("base_seed", 0))
    t0 = time.perf_counter()
    res = run_scheme(scheme, p, seed=seed, t_c=cell.get("t_c"),
                     max_wall=cell.get("max_wall"),
                     failure_model=model, topology=topo)
    elapsed = time.perf_counter() - t0

    row = {
        "key": cell_key(cell),
        "scheme": cell["scheme"],
        "n": cell["n"],
        "r": cell.get("r"),
        "model": cell["model"].get("label", cell["model"]["kind"]),
        "seed": cell["seed"],
        "cell_seed": seed,
    }
    for f in RESULT_FIELDS:
        row[f] = getattr(res, f)
    for f in DERIVED_FIELDS:
        row[f] = getattr(res, f)
    row["elapsed_s"] = elapsed
    return row


def run_campaign(cells: list[dict], jobs: int = 1,
                 base_seed: int | None = None) -> list[dict]:
    """Run every cell, serially (``jobs <= 1``) or across a process
    pool. Results are ordered by cell key, so the output is independent
    of worker count and completion order. ``base_seed`` overrides each
    cell's own salt when given; ``None`` keeps what the grid set."""
    if base_seed is not None:
        cells = [dict(c, base_seed=base_seed) for c in cells]
    if jobs <= 1:
        results = [run_cell(c) for c in cells]
    else:
        with ProcessPoolExecutor(max_workers=jobs) as ex:
            results = list(ex.map(run_cell, cells, chunksize=1))
    results.sort(key=lambda r: r["key"])
    return results


def parallel_map(fn, argtuples: list[tuple], jobs: int = 1) -> list:
    """Order-preserving (possibly process-parallel) starmap for
    non-campaign workloads — e.g. the Monte-Carlo benchmark cells."""
    if jobs <= 1:
        return [fn(*args) for args in argtuples]
    with ProcessPoolExecutor(max_workers=jobs) as ex:
        futs = [ex.submit(fn, *args) for args in argtuples]
        return [f.result() for f in futs]


# ------------------------------------------------------------------ #
# aggregation / artifacts                                            #
# ------------------------------------------------------------------ #
_CSV_COLUMNS = ("scheme", "n", "r", "model", "seed", "cell_seed",
                *RESULT_FIELDS, *DERIVED_FIELDS)


def _fmt(v) -> str:
    if isinstance(v, float):
        return repr(v)              # full precision, deterministic
    if v is None:
        return ""
    return str(v)


def aggregate(results: list[dict]) -> tuple[str, dict]:
    """Deterministic artifacts: ``(csv_text, json_obj)``. Timings are
    excluded — identical grids give identical bytes at any ``--jobs``."""
    buf = io.StringIO()
    w = csv.writer(buf, lineterminator="\n")
    w.writerow(_CSV_COLUMNS)
    for row in results:
        w.writerow([_fmt(row[c]) for c in _CSV_COLUMNS])
    clean = [{k: v for k, v in row.items() if k != "elapsed_s"}
             for row in results]
    obj = {
        "cells": clean,
        "ranking": ranking_by_regime(results),
    }
    return buf.getvalue(), obj


def ranking_by_regime(results: list[dict]) -> dict:
    """Per ``(n, model)`` regime: schemes ranked by mean normalized
    time-to-train over seeds (and r points) — the regime-dependent
    policy ordering the adaptive scheme must track."""
    groups: dict[tuple, dict[str, list[float]]] = {}
    for row in results:
        regime = (row["n"], row["model"])
        groups.setdefault(regime, {}).setdefault(
            row["scheme"], []).append(row["ttt_norm"])
    out = {}
    for (n, model), by_scheme in sorted(groups.items()):
        scored = sorted(
            ((sum(v) / len(v), s) for s, v in by_scheme.items()))
        out[f"n={n}/{model}"] = [
            {"scheme": s, "mean_ttt_norm": score} for score, s in scored]
    return out


def save_artifacts(name: str, results: list[dict],
                   outdir: str | Path | None = None) -> tuple[Path, Path]:
    """Write ``<name>.csv`` + ``<name>.json`` under ``outdir`` (default:
    ``benchmarks/results/``). Returns the two paths."""
    if outdir is None:
        outdir = Path(__file__).resolve().parents[3] \
            / "benchmarks" / "results"
    outdir = Path(outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    csv_text, obj = aggregate(results)
    csv_path = outdir / f"{name}.csv"
    json_path = outdir / f"{name}.json"
    csv_path.write_text(csv_text)
    json_path.write_text(_canon(obj) + "\n")
    return csv_path, json_path


# ------------------------------------------------------------------ #
# live-trainer cells (the injection-bridge sweep)                    #
# ------------------------------------------------------------------ #
#: the three PR-2 regimes at trainer scale: MTBFs sized so a tiny
#: (~40-step, ~64 s/step) run sees several events, including
#: multi-group rack bursts; the trace regime replays the HSDP-style
#: storm log compressed to the same horizon
TRAINER_REGIME_MODELS = [
    {"kind": "weibull", "label": "weibull", "mtbf": 350.0},
    {"kind": "correlated", "label": "rack_burst", "scope": "rack",
     "burst_prob": 0.5, "mtbf": 450.0},
    {"kind": "trace", "label": "trace_rackstorm",
     "trace": "meta_hsdp_rackstorm", "time_scale": 0.1},
]


def trainer_regime_cells(arch: str = "qwen2.5-3b", n: int = 8, r: int = 3,
                         steps: int = 40, seq: int = 32,
                         per_type_batch: int = 1,
                         models: list | None = None, topology=None,
                         seconds_per_step: float | None = None,
                         base_seed: int = 0,
                         trace_dir: str | None = None) -> list[dict]:
    """The live-trainer campaign preset: one cell per failure regime,
    tiny config, rack-dominated topology (2 hosts/group, 4 hosts/rack =>
    2 groups per rack, so rack kills are genuine multi-group batches).
    ``topology`` may be a preset name or a spec dict. ``trace_dir``
    turns telemetry on per cell (one Perfetto trace per regime)."""
    if topology is None:
        topology = {"n_groups": n, "hosts_per_group": 2,
                    "hosts_per_rack": 4}
    cells = []
    for model in (models if models is not None else TRAINER_REGIME_MODELS):
        cell = {
            "kind": "trainer", "arch": arch, "n": n, "r": r,
            "steps": steps, "seq": seq, "per_type_batch": per_type_batch,
            "model": dict(model),
            "topology": (dict(topology) if isinstance(topology, dict)
                         else topology),
            "seed": 0, "base_seed": base_seed,
        }
        if seconds_per_step is not None:
            cell["seconds_per_step"] = seconds_per_step
        if trace_dir is not None:
            label = model.get("label", model["kind"])
            cell["trace"] = str(Path(trace_dir) / f"{label}.trace.json")
        cells.append(cell)
    return cells


def run_trainer_cell(cell: dict) -> dict:
    """Worker entry point for live-trainer cells: drive the real
    :class:`repro.train.trainer.SpareTrainer` through the cell's failure
    regime via the injection bridge, verifying the §3.1 gradient
    invariant after every successful recovery. ``cell["trace"]`` (a
    path) turns telemetry on and dumps the run's Perfetto trace there
    (metrics snapshot alongside at ``<trace>.metrics.json``)."""
    from ..configs import smoke_config
    from ..train.injection import ScenarioInjector
    from ..train.trainer import SpareTrainer

    seed = cell_seed(cell, base_seed=cell.get("base_seed", 0))
    cfg = smoke_config(cell.get("arch", "qwen2.5-3b")).scaled(grad_accum=1)
    topo = topology_from_spec(cell.get("topology"), n_groups=cell["n"])
    injector = ScenarioInjector(
        cell["model"], topo, n_groups=cell["n"],
        seconds_per_step=cell.get("seconds_per_step"), seed=seed)
    tel = None
    if cell.get("trace"):
        from ..obs import Telemetry
        tel = Telemetry()
    trainer = SpareTrainer(
        cfg, n_groups=cell["n"], redundancy=cell["r"],
        seq=cell.get("seq", 32),
        per_type_batch=cell.get("per_type_batch", 1), seed=seed,
        total_steps=cell["steps"], telemetry=tel)
    t0 = time.perf_counter()
    rep = trainer.run(cell["steps"], injector=injector,
                      verify_equivalence=cell.get("verify", True))
    elapsed = time.perf_counter() - t0
    if tel is not None:
        tel.dump_trace(cell["trace"])
        tel.metrics.dump(str(cell["trace"]) + ".metrics.json")
    return {
        "key": cell_key(cell),
        "model": cell["model"].get("label", cell["model"]["kind"]),
        "n": cell["n"], "r": cell["r"],
        "steps_done": rep.steps_done,
        "failures": rep.failures,
        "wipeouts": rep.wipeouts,
        "reorders": rep.reorders,
        "patches": rep.patches,
        "recovery_events": len(rep.events),
        "multi_group_events": rep.multi_group_events,
        "rollback_steps": rep.rollback_steps,
        "max_grad_check_err": rep.max_grad_check_err,
        "final_s_a": int(trainer.state.s_a),
        "loss_first": rep.losses[0] if rep.losses else None,
        "loss_last": rep.losses[-1] if rep.losses else None,
        "elapsed_s": elapsed,
    }


# ------------------------------------------------------------------ #
# elastic cells (mask vs reshape vs restart on the live mesh)        #
# ------------------------------------------------------------------ #
def elastic_regime_cells(arch: str = "qwen2.5-3b", n: int = 8, r: int = 2,
                         steps: int = 24, fail_step: int = 8,
                         seq: int = 32, per_type_batch: int = 2,
                         model_degree: int = 1,
                         seconds_per_step: float = 64.0,
                         t_reshape: float = 60.0,
                         t_restart: float = 3600.0,
                         snapshot_every: int = 10,
                         grad_compress: str | None = "int8_ef",
                         trace_dir: str | None = None) -> list[dict]:
    """The third-regime campaign: the SAME deterministic failure clock
    hits three recovery tiers on the live emulated mesh.

    * ``mask`` — a single-group kill at ``fail_step``: RECTLR masks it,
      training continues at full DP (the free tier);
    * ``reshape`` — an adjacent-pair kill (unmaskable at r=2, every
      adjacent pair is a wiping set) on the elastic executor: the TTT
      policy continues degraded on a survivor submesh;
    * ``restart`` — the identical unmaskable kill on the plain executor:
      wipe-out rollback + modeled cluster restart, the only pre-elastic
      option.

    All arms run the adaptive scheme (pinned to SPARe masking) so the
    reshape decision flows through
    :meth:`~repro.des.schemes.AdaptiveScheme.decide_unmaskable`.
    """
    arms = [
        ("mask", [0], True),
        ("reshape", [0, 1], True),
        ("restart", [0, 1], False),
    ]
    cells = []
    for arm, victims, elastic in arms:
        cell = {
            "kind": "elastic", "arm": arm, "arch": arch, "n": n, "r": r,
            "steps": steps, "fail_step": fail_step, "victims": victims,
            "elastic": elastic, "seq": seq,
            "per_type_batch": per_type_batch,
            "model_degree": model_degree,
            "seconds_per_step": seconds_per_step,
            "t_reshape": t_reshape, "t_restart": t_restart,
            "snapshot_every": snapshot_every,
            "grad_compress": grad_compress,
        }
        if trace_dir is not None:
            cell["trace"] = str(Path(trace_dir) / f"{arm}.trace.json")
        cells.append(cell)
    return cells


def run_elastic_cell(cell: dict) -> dict:
    """Worker entry point for elastic cells: one deterministic failure
    burst through one recovery tier, with the work-normalized TTT the
    arms are compared on.

    ``work_units`` counts committed FULL-batch step equivalents: a step
    at DP degree d contributes ``d / n`` (degraded steps cover fewer
    examples), wiped-out steps contribute nothing. ``ttt_s`` is the
    modeled time to ``steps`` work units: the injector clock (outages
    included) plus the remaining deficit at the end-state rate.
    """
    from ..configs import smoke_config
    from ..des import get_scheme
    from ..elastic import ElasticMeshExecutor
    from ..exec import MeshExecutor
    from ..train.injection import ScriptedInjector

    cfg = smoke_config(cell.get("arch", "qwen2.5-3b")).scaled(grad_accum=1)
    tel = None
    if cell.get("trace"):
        from ..obs import Telemetry
        tel = Telemetry()
    n, steps = cell["n"], cell["steps"]
    sps = cell["seconds_per_step"]
    kw = dict(n_groups=n, redundancy=cell["r"],
              model_degree=cell.get("model_degree", 1),
              seq=cell.get("seq", 32),
              per_type_batch=cell.get("per_type_batch", 2),
              total_steps=steps, t_restart=cell.get("t_restart", 3600.0),
              grad_compress=cell.get("grad_compress"),
              scheme=get_scheme("adaptive", r=cell["r"], initial="spare"),
              telemetry=tel)
    if cell["elastic"]:
        ex = ElasticMeshExecutor(cfg, t_reshape=cell["t_reshape"], **kw)
    else:
        ex = MeshExecutor(cfg, **kw)
    inj = ScriptedInjector({cell["fail_step"]: list(cell["victims"])},
                           seconds_per_step=sps)
    t0 = time.perf_counter()
    rep = ex.run(steps, injector=inj,
                 snapshot_every=cell.get("snapshot_every", 10))
    elapsed = time.perf_counter() - t0

    # committed work: degraded steps pro-rated, wiped steps discounted
    work = float(rep.steps_done)
    for e in rep.events:
        if e.reshape:
            work -= (steps - e.step) * (1.0 - e.dp_after / n)
        if e.wipeout:
            work -= e.rollback_depth
    dp_end = int(ex.state.n)
    deficit = max(float(steps) - work, 0.0)
    ttt = inj.clock + deficit * sps * (n / dp_end)

    if tel is not None:
        tel.dump_trace(cell["trace"])
        tel.metrics.dump(str(cell["trace"]) + ".metrics.json")
    row = {
        "key": cell_key(cell),
        "arm": cell["arm"],
        "n": n, "r": cell["r"],
        "dp_final": dp_end,
        "steps_done": rep.steps_done,
        "failures": rep.failures,
        "wipeouts": rep.wipeouts,
        "reshapes": rep.reshapes,
        "recompiles": rep.recompiles,
        "compiled_entries": len(ex.cache_keys),
        "rollback_steps": rep.rollback_steps,
        "outage_s": inj.outage_seconds,
        "elapsed_model_s": inj.clock,
        "work_units": work,
        "ttt_s": ttt,
        "policy": (ex.policy_log[-1] if getattr(ex, "policy_log", None)
                   else None),
        "loss_first": rep.losses[0] if rep.losses else None,
        "loss_last": rep.losses[-1] if rep.losses else None,
        "elapsed_s": elapsed,
    }
    ex.close()
    return row


# ------------------------------------------------------------------ #
# gray-failure cells (tolerate vs demote under the same fail-slow)   #
# ------------------------------------------------------------------ #
def gray_regime_cells(arch: str = "qwen2.5-3b", n: int = 8, r: int = 2,
                      steps: int = 32, slow_group: int = 0,
                      slow_factor: float = 3.0, slow_step: int = 4,
                      heal_step: int = 16, seq: int = 32,
                      per_type_batch: int = 2, model_degree: int = 1,
                      seconds_per_step: float = 64.0,
                      t_restart: float = 3600.0,
                      snapshot_every: int = 10,
                      trace_dir: str | None = None) -> list[dict]:
    """The gray-failure campaign: the SAME scripted fail-slow episode
    (one DP group degraded ``slow_factor`` x for poll windows
    ``[slow_step, heal_step)``) through two mitigation arms on the live
    emulated mesh.

    * ``tolerate`` — no detector: every synchronous step stretches to
      the straggler's pace (the barrier makes one slow group everyone's
      problem);
    * ``demote`` — a :class:`repro.health.StragglerDetector` flags the
      group, the adaptive scheme's ``decide_degraded`` picks proactive
      SPARe demotion (a pure weight-table edit), and the group is
      re-admitted bit-identically once the episode heals.

    Both arms run the adaptive scheme pinned to SPARe masking; no group
    ever actually dies, so any TTT gap is pure gray-failure handling.
    """
    arms = [("tolerate", False), ("demote", True)]
    cells = []
    for arm, detect in arms:
        cell = {
            "kind": "gray", "arm": arm, "arch": arch, "n": n, "r": r,
            "steps": steps, "detect": detect,
            "slow_group": slow_group, "slow_factor": slow_factor,
            "slow_step": slow_step, "heal_step": heal_step,
            "seq": seq, "per_type_batch": per_type_batch,
            "model_degree": model_degree,
            "seconds_per_step": seconds_per_step,
            "t_restart": t_restart, "snapshot_every": snapshot_every,
        }
        if trace_dir is not None:
            cell["trace"] = str(Path(trace_dir) / f"{arm}.trace.json")
        cells.append(cell)
    return cells


def run_gray_cell(cell: dict) -> dict:
    """Worker entry point for gray cells: one scripted fail-slow episode
    through one mitigation arm, returning everything the acceptance
    gates check — flag/demote/re-admit step indices, the post-demotion
    step windows (throughput restoration), run-attributed recompiles
    with both stacking depths pre-warmed (demotion at r=2 flips S_A
    1 -> 2, and the gate freezes recompiles at zero), and whether the
    re-admitted weight table is bit-identical to a never-demoted one.

    ``ttt_s`` is the injector clock at run end plus any residual work
    deficit at the healthy rate — with no kills in the script it is
    exactly the sum of the (inflation-stretched) step windows.
    """
    import numpy as np

    from ..configs import smoke_config
    from ..core.state import SpareState
    from ..des import get_scheme
    from ..exec import MeshExecutor
    from ..train.injection import ScriptedInjector

    cfg = smoke_config(cell.get("arch", "qwen2.5-3b")).scaled(grad_accum=1)
    tel = None
    if cell.get("trace"):
        from ..obs import Telemetry
        tel = Telemetry()
    n, steps = cell["n"], cell["steps"]
    sps = cell["seconds_per_step"]
    det = None
    if cell["detect"]:
        from ..health import StragglerDetector
        det = StragglerDetector(n)
    ex = MeshExecutor(
        cfg, n_groups=n, redundancy=cell["r"],
        model_degree=cell.get("model_degree", 1),
        seq=cell.get("seq", 32),
        per_type_batch=cell.get("per_type_batch", 2),
        total_steps=steps, t_restart=cell.get("t_restart", 3600.0),
        scheme=get_scheme("adaptive", r=cell["r"], initial="spare"),
        telemetry=tel, detector=det)
    # warm every stacking depth a demotion can reach BEFORE the run:
    # run-attributed recompiles must stay frozen at zero through the
    # demote -> re-admit round trip (the no-recompile acceptance gate)
    ex.prewarm_depths(range(1, cell["r"] + 1))
    inj = ScriptedInjector(
        {}, seconds_per_step=sps,
        slow_schedule={cell["slow_step"]: [
            (cell["slow_group"], cell["slow_factor"], cell["heal_step"])]},
        n_groups=n)
    t0 = time.perf_counter()
    rep = ex.run(steps, injector=inj,
                 snapshot_every=cell.get("snapshot_every", 10))
    elapsed = time.perf_counter() - t0

    demote_steps = [e.step for e in rep.events if e.demote]
    readmit_steps = [e.step for e in rep.events if e.readmit]
    flag_step = None
    if det is not None:
        flag_step = next((r.step for r in det.reports if len(r.flagged)),
                         None)
    # step windows while demoted-but-still-slow: demotion lands in the
    # health tick after `demote_step` completes, so the first window it
    # can deflate is the next poll
    post = []
    if demote_steps:
        post = inj.window_log[demote_steps[0] + 1:cell["heal_step"]]
    # re-admitted weight table vs a never-demoted run: SPARe recovery is
    # pure state, so bit-identical state => bit-identical schedule
    ref = SpareState(n, cell["r"])
    readmit_identical = bool(
        np.array_equal(ex.state.stacks, ref.stacks)
        and np.array_equal(ex.state.alive, ref.alive)
        and int(ex.state.s_a) == int(ref.s_a)
        and np.array_equal(ex.state.supplier, ref.supplier))

    work = float(rep.steps_done)
    for e in rep.events:
        if e.wipeout:
            work -= e.rollback_depth
    deficit = max(float(steps) - work, 0.0)
    ttt = inj.clock + deficit * sps

    if tel is not None:
        tel.dump_trace(cell["trace"])
        tel.metrics.dump(str(cell["trace"]) + ".metrics.json")
    row = {
        "key": cell_key(cell),
        "arm": cell["arm"],
        "n": n, "r": cell["r"],
        "steps_done": rep.steps_done,
        "demotes": rep.demotes,
        "readmits": rep.readmits,
        "flag_step": flag_step,
        "demote_step": demote_steps[0] if demote_steps else None,
        "readmit_step": readmit_steps[0] if readmit_steps else None,
        "post_demote_window_max": max(post) if post else None,
        "healthy_window_s": sps,
        "recompiles": rep.recompiles,
        "total_recompiles": ex.total_recompiles,
        "compiled_entries": len(ex.cache_keys),
        "readmit_identical": readmit_identical,
        "wipeouts": rep.wipeouts,
        "ttt_s": ttt,
        "health_actions": [h["action"] for h in ex.health_log
                           if h["action"] != "tolerate"],
        "loss_first": rep.losses[0] if rep.losses else None,
        "loss_last": rep.losses[-1] if rep.losses else None,
        "elapsed_s": elapsed,
    }
    ex.close()
    return row


# ------------------------------------------------------------------ #
# presets                                                            #
# ------------------------------------------------------------------ #
#: three failure regimes of the acceptance sweep: a quiet memoryless
#: cluster, a bursty Weibull storm, and spatially-correlated rack kills
REGIME_MODELS = [
    {"kind": "poisson", "label": "quiet_poisson", "mtbf": 30_000.0},
    {"kind": "weibull", "label": "bursty_weibull", "shape": 0.55,
     "mtbf": 300.0},
    {"kind": "correlated", "label": "rack_kill", "scope": "rack",
     "burst_prob": 0.25, "mtbf": 600.0},
]

CAMPAIGN_PRESETS: dict[str, CampaignSpec] = {
    # 2x2 CI smoke: two schemes x two regimes
    "smoke": CampaignSpec(
        name="campaign_smoke",
        schemes=["spare", "replication"],
        ns=[200], rs=[4],
        models=[{"kind": "weibull", "label": "weibull"},
                {"kind": "correlated", "label": "rack_kill",
                 "burst_prob": 0.25}],
        seeds=[0], steps=250,
    ),
    # balanced 16-cell grid for the parallel-speedup check
    "quick": CampaignSpec(
        name="campaign_quick",
        schemes=["spare", "replication"],
        ns=[200], rs=[4, 9],
        models=[{"kind": "weibull", "label": "weibull"},
                {"kind": "correlated", "label": "rack_kill",
                 "burst_prob": 0.25}],
        seeds=[0, 1], steps=600,
    ),
    # the adaptive acceptance sweep: every scheme across three regimes
    "regimes": CampaignSpec(
        name="campaign_regimes",
        schemes=["ckpt_only", ("replication", {"r": 2}), "spare",
                 "adaptive"],
        ns=[200], rs=[9],
        models=REGIME_MODELS,
        seeds=[0, 1, 2], steps=600,
    ),
    # paper-scale sweep (hours on CPU): Table-1 N points, full horizons
    "paper": CampaignSpec(
        name="campaign_paper",
        schemes=["ckpt_only", ("replication", {"r": 2}), "spare",
                 "adaptive"],
        ns=[200, 600, 1000], rs=[4, 9, 12],
        models=REGIME_MODELS + [
            {"kind": "trace", "label": "meta_hsdp_rackstorm",
             "trace": "meta_hsdp_rackstorm"},
            {"kind": "diurnal", "label": "diurnal_maintenance",
             "maintenance_start": 10_800.0},
        ],
        seeds=[0, 1, 2], steps=10_000,
    ),
}
