"""Scenario engine: cluster topology, failure models, campaign runner.

The paper validates its claims under i.i.d. renewal failures with
uniformly-random victims (Sec. 5), but production traces show failures
that are spatially correlated (rack/pod co-failures), bursty, and
time-varying. This package generalizes the single
:class:`repro.des.failures.FailureProcess` stream into a pluggable
:class:`FailureModel` protocol drawn over an explicit
:class:`ClusterTopology` (groups -> hosts -> racks -> pods -> DCI
domains), plus a declarative, process-parallel campaign runner that
sweeps scheme x scale x failure-regime grids deterministically.

Layers:

* :mod:`repro.scenarios.topology` — the cluster layout model and the
  100k-600k-GPU presets (paper Table 1 scale).
* :mod:`repro.scenarios.models`  — the ``FailureModel`` protocol and the
  built-in streams: ``weibull`` / ``poisson`` renewal baselines
  (bit-for-bit compatible with the legacy ``FailureProcess``),
  ``correlated`` rack/pod burst kills, ``diurnal`` rate modulation with
  maintenance windows, ``trace`` JSONL replay (bundled synthetic traces
  shaped like published cluster logs), and ``superposed`` mixtures.
* :mod:`repro.scenarios.campaign` — scenario grids fanned out across a
  ``ProcessPoolExecutor`` with deterministic per-cell seeding,
  aggregated into byte-stable CSV/JSON artifacts
  (CLI: ``python -m repro.launch.campaign``).
"""
from .topology import ClusterTopology, TOPOLOGY_PRESETS, topology_from_spec
from .models import (
    FailureModel,
    RenewalModel,
    PoissonModel,
    CorrelatedModel,
    DiurnalModel,
    TraceReplayModel,
    SuperposedModel,
    SlowdownModel,
    FailSlowModel,
    FlakyLinkModel,
    get_failure_model,
    list_failure_models,
    register_failure_model,
    model_from_spec,
    bundled_traces,
    load_trace,
    sample_kill_batches,
    bind_model,
    drain_event_window,
    drain_slow_window,
    to_step_events,
)
from .campaign import (
    CampaignSpec,
    ScenarioCell,
    CAMPAIGN_PRESETS,
    cell_seed,
    run_cell,
    run_campaign,
    parallel_map,
    aggregate,
    ranking_by_regime,
    save_artifacts,
)

__all__ = [
    "ClusterTopology", "TOPOLOGY_PRESETS", "topology_from_spec",
    "FailureModel", "RenewalModel", "PoissonModel", "CorrelatedModel",
    "DiurnalModel", "TraceReplayModel", "SuperposedModel",
    "SlowdownModel", "FailSlowModel", "FlakyLinkModel",
    "get_failure_model", "list_failure_models", "register_failure_model",
    "model_from_spec", "bundled_traces", "load_trace", "sample_kill_batches",
    "bind_model", "drain_event_window", "drain_slow_window", "to_step_events",
    "CampaignSpec", "ScenarioCell", "CAMPAIGN_PRESETS", "cell_seed",
    "run_cell", "run_campaign", "parallel_map", "aggregate",
    "ranking_by_regime", "save_artifacts",
]
