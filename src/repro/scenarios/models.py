"""Pluggable failure models: composable streams over a cluster topology.

The legacy DES injects failures from one renewal stream
(:class:`repro.des.failures.FailureProcess`) and picks victims uniformly
among survivors. Production failure logs disagree on all three axes the
paper's claims are sensitive to (Sec. 5, App. C/E): failures are
*spatially correlated* (rack/pod co-failures), *bursty*, and
*time-varying* (diurnal load, maintenance windows). This module
generalizes injection into a :class:`FailureModel` protocol the engine
(:class:`repro.des.engine.SimClock`) and the Monte-Carlo driver
(:func:`repro.core.montecarlo.run_montecarlo`) both consume:

``bind(p, rng, topology)``
    attach the run's parameters, RNG, and cluster topology (once per
    simulation — must fully reset model state so instances are reusable).
``next_arrival(now, alive, n)``
    absolute time of the next failure *event* (which may kill several
    groups at once).
``draw_victims(now, dead)``
    the groups killed by the event at ``now`` (already-dead groups are
    filtered by the caller as well, for safety).
``reset(now, alive, n)``
    re-arm after a global restart; returns the next arrival time.

Registered models (``get_failure_model`` / campaign ``kind`` keys):

* ``weibull`` / ``poisson`` — single-victim renewal baselines,
  bit-for-bit compatible with the legacy ``FailureProcess`` at fixed
  seeds (same RNG-draw order: one interval draw per event, one uniform
  victim choice).
* ``correlated`` — renewal arrivals whose events escalate, with
  configurable probability, from a single group to the victim's whole
  rack / pod / DCI domain (blast-radius kills).
* ``diurnal`` — wraps any base model, modulating its rate by a sinusoid
  (period/amplitude/peak) plus an optional daily maintenance window.
* ``trace`` — JSONL trace replay through the topology; three synthetic
  traces shaped like published cluster logs ship in ``traces/``.
* ``superposed`` — superposition of independent component streams
  (e.g. quiet Poisson background + rare pod kills).
* ``fail_slow`` / ``flaky_link`` — *gray-failure* streams
  (:class:`SlowdownModel`): arrivals open slowdown episodes that
  inflate victims' per-step time instead of killing them — persistent
  (degraded NIC / thermal throttle) or self-healing (flaky links) —
  consumed by the injector's slow channel and the
  :mod:`repro.health` straggler detector.
"""
from __future__ import annotations

import json
import math
from pathlib import Path

import numpy as np

from ..des.failures import FailureProcess
from .topology import ClusterTopology, topology_from_spec

__all__ = [
    "FailureModel", "RenewalModel", "PoissonModel", "CorrelatedModel",
    "RackBurstModel", "DiurnalModel", "TraceReplayModel", "SuperposedModel",
    "SlowdownModel", "FailSlowModel", "FlakyLinkModel",
    "register_failure_model", "get_failure_model", "list_failure_models",
    "model_from_spec", "bundled_traces", "load_trace", "sample_kill_batches",
    "bind_model", "drain_event_window", "drain_slow_window", "to_step_events",
]

TRACES_DIR = Path(__file__).parent / "traces"


# ------------------------------------------------------------------ #
# protocol + registry                                                #
# ------------------------------------------------------------------ #
class FailureModel:
    """Base class for pluggable failure streams (see module docstring)."""

    #: registry key / campaign spec ``kind``
    name: str = "base"

    def bind(self, p, rng: np.random.Generator,
             topology: ClusterTopology | None = None) -> None:
        """Attach run state; must fully reset internal state."""
        self.p = p
        self.rng = rng
        self.n = p.n
        self.topology = topology

    def next_arrival(self, now: float, alive: int, n: int) -> float:
        raise NotImplementedError

    def draw_victims(self, now: float, dead: set[int]) -> list[int]:
        raise NotImplementedError

    def reset(self, now: float, alive: int, n: int) -> float:
        """Re-arm after a global restart (full capacity restored)."""
        return self.next_arrival(now, alive, n)

    # ---------------------------------------------------------- #
    def _uniform_victim(self, dead: set[int]) -> int | None:
        candidates = [w for w in range(self.n) if w not in dead]
        if not candidates:
            return None
        return int(self.rng.choice(candidates))


_MODEL_REGISTRY: dict[str, type[FailureModel]] = {}


def register_failure_model(cls: type[FailureModel]):
    """Class decorator: register ``cls`` under ``cls.name``."""
    if not cls.name or cls.name == "base":
        raise ValueError(f"{cls.__name__} must set a unique `name`")
    _MODEL_REGISTRY[cls.name] = cls
    return cls


def get_failure_model(name: str, **kwargs) -> FailureModel:
    """Instantiate a registered model: ``get_failure_model("correlated",
    scope="rack", burst_prob=0.2)``."""
    try:
        cls = _MODEL_REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown failure model {name!r}; "
                       f"registered: {list_failure_models()}") from None
    return cls(**kwargs)


def list_failure_models() -> list[str]:
    return sorted(_MODEL_REGISTRY)


def model_from_spec(spec) -> FailureModel:
    """Build a model from a kind string, ``{"kind": ..., **kwargs}``
    dict (the picklable campaign-cell form), or an existing instance."""
    if isinstance(spec, FailureModel):
        return spec
    if spec is None:
        return RenewalModel()
    if isinstance(spec, str):
        return get_failure_model(spec)
    if isinstance(spec, dict):
        kw = dict(spec)
        kw.pop("label", None)          # campaign display name, not a kwarg
        kind = kw.pop("kind")
        return get_failure_model(kind, **kw)
    raise TypeError(f"cannot build a failure model from {spec!r}")


# ------------------------------------------------------------------ #
# renewal baselines (legacy-parity)                                  #
# ------------------------------------------------------------------ #
@register_failure_model
class RenewalModel(FailureModel):
    """Single-victim renewal stream — the legacy behavior, verbatim.

    With no overrides this draws *exactly* the sequence the pre-scenario
    :class:`SimClock` drew (one Weibull/exponential interval per event
    via ``FailureProcess``, then one uniform ``rng.choice`` victim), so
    the scheme-parity tests against :mod:`repro.des._legacy` stay
    bit-for-bit. Constructor kwargs override the corresponding
    :class:`repro.des.params.DESParams` fields.
    """

    name = "weibull"
    _law: str | None = None

    def __init__(self, mtbf: float | None = None, shape: float | None = None,
                 law: str | None = None,
                 scale_with_survivors: bool | None = None):
        self.mtbf = mtbf
        self.shape = shape
        self.law = law if law is not None else self._law
        self.scale_with_survivors = scale_with_survivors

    def bind(self, p, rng, topology=None) -> None:
        super().bind(p, rng, topology)
        self.proc = FailureProcess(
            self.mtbf if self.mtbf is not None else p.mtbf,
            self.shape if self.shape is not None else p.weibull_shape,
            rng,
            law=self.law if self.law is not None else p.failure_law,
            scale_with_survivors=(
                p.scale_rate_with_survivors
                if self.scale_with_survivors is None
                else self.scale_with_survivors),
        )

    def next_arrival(self, now: float, alive: int, n: int) -> float:
        return self.proc.next_arrival(now, alive, n)

    def draw_victims(self, now: float, dead: set[int]) -> list[int]:
        v = self._uniform_victim(dead)
        return [] if v is None else [v]


@register_failure_model
class PoissonModel(RenewalModel):
    """Memoryless renewal baseline (exponential inter-arrivals)."""

    name = "poisson"
    _law = "exponential"


# ------------------------------------------------------------------ #
# spatially-correlated burst kills                                   #
# ------------------------------------------------------------------ #
@register_failure_model
class CorrelatedModel(RenewalModel):
    """Rack/pod/DCI burst kills over renewal arrivals.

    Each arrival draws a uniform seed victim, then escalates: with
    probability ``scope_probs[scope]`` (evaluated largest scope first)
    the event kills every *alive* group in the seed's blast radius at
    that scope. ``burst_prob``/``scope`` is shorthand for a single-entry
    ``scope_probs``. Models the rack- and pod-level co-failures that
    dominate downtime in production logs (Kokolis et al. 2025).
    """

    name = "correlated"

    def __init__(self, scope: str = "rack", burst_prob: float = 0.15,
                 scope_probs: dict[str, float] | None = None, **renewal_kw):
        super().__init__(**renewal_kw)
        self.scope_probs = dict(scope_probs) if scope_probs else \
            {scope: burst_prob}
        if sum(self.scope_probs.values()) > 1.0:
            raise ValueError("scope escalation probabilities exceed 1")

    def bind(self, p, rng, topology=None) -> None:
        super().bind(p, rng, topology)
        self.topo = topology_from_spec(topology, n_groups=p.n)

    def draw_victims(self, now: float, dead: set[int]) -> list[int]:
        v = self._uniform_victim(dead)
        if v is None:
            return []
        u = float(self.rng.random())
        acc = 0.0
        # largest blast radius first, so "pod" wins over "rack" draws
        for scope in ("dci", "pod", "rack"):
            prob = self.scope_probs.get(scope, 0.0)
            if prob <= 0.0:
                continue
            acc += prob
            if u < acc:
                blast = self.topo.blast_radius(v, scope)
                return [w for w in blast if w not in dead]
        return [v]


@register_failure_model
class RackBurstModel(CorrelatedModel):
    """Every arrival is a full-rack kill — the Kokolis-style rackstorm
    regime as a one-word preset (``--failure-model rack_burst``): a
    uniform seed victim always escalates to its whole rack's alive
    groups. Equivalent to ``{"kind": "correlated", "scope": "rack",
    "burst_prob": 1.0}``; renewal kwargs (``mtbf``, ``shape``...) pass
    through."""

    name = "rack_burst"

    def __init__(self, **renewal_kw):
        super().__init__(scope="rack", burst_prob=1.0, **renewal_kw)


# ------------------------------------------------------------------ #
# diurnal / maintenance-window rate modulation                       #
# ------------------------------------------------------------------ #
@register_failure_model
class DiurnalModel(FailureModel):
    """Time-varying hazard: wraps a base model and rescales its
    inter-arrival intervals by ``1 / rate_factor(now)``.

    ``rate_factor`` is a sinusoid of the wall clock — period one day by
    default, ``amplitude`` in [0, 1), peaking at fraction ``peak`` of
    the period — optionally multiplied by ``maintenance_factor`` inside
    a daily ``[maintenance_start, maintenance_start + maintenance_len)``
    window (elevated failure discovery during maintenance, as cluster
    logs show). The factor is evaluated at the interval's start — the
    standard piecewise-constant thinning approximation, exact as the
    interval shrinks relative to the period.
    """

    name = "diurnal"

    def __init__(self, base=None, period: float = 86_400.0,
                 amplitude: float = 0.5, peak: float = 0.5,
                 maintenance_start: float | None = None,
                 maintenance_len: float = 7_200.0,
                 maintenance_factor: float = 4.0):
        if not 0.0 <= amplitude < 1.0:
            raise ValueError("amplitude must be in [0, 1)")
        self.base = base
        self.period = period
        self.amplitude = amplitude
        self.peak = peak
        self.maintenance_start = maintenance_start
        self.maintenance_len = maintenance_len
        self.maintenance_factor = maintenance_factor

    def bind(self, p, rng, topology=None) -> None:
        super().bind(p, rng, topology)
        self.inner = model_from_spec(self.base)
        self.inner.bind(p, rng, topology)

    def rate_factor(self, t: float) -> float:
        phase = (t / self.period) - self.peak
        f = 1.0 + self.amplitude * math.cos(2.0 * math.pi * phase)
        if self.maintenance_start is not None:
            tod = t % self.period
            if (self.maintenance_start <= tod
                    < self.maintenance_start + self.maintenance_len):
                f *= self.maintenance_factor
        return max(f, 1e-9)

    def next_arrival(self, now: float, alive: int, n: int) -> float:
        interval = self.inner.next_arrival(now, alive, n) - now
        return now + interval / self.rate_factor(now)

    def draw_victims(self, now: float, dead: set[int]) -> list[int]:
        return self.inner.draw_victims(now, dead)

    def reset(self, now: float, alive: int, n: int) -> float:
        interval = self.inner.reset(now, alive, n) - now
        return now + interval / self.rate_factor(now)


# ------------------------------------------------------------------ #
# trace replay                                                       #
# ------------------------------------------------------------------ #
def bundled_traces() -> list[str]:
    """Names of the synthetic traces shipped with the package."""
    return sorted(f.stem for f in TRACES_DIR.glob("*.jsonl"))


def load_trace(name_or_path: str | Path) -> list[dict]:
    """Load a JSONL trace — one event per line:
    ``{"t": <seconds>, "scope": "host"|"rack"|"pod"|"dci"|"group",
    "loc": <int>}`` (extra keys ignored). Bundled traces resolve by
    bare name (see :func:`bundled_traces`)."""
    path = Path(name_or_path)
    if not path.exists():
        candidate = TRACES_DIR / f"{name_or_path}.jsonl"
        if not candidate.exists():
            raise FileNotFoundError(
                f"no trace file {name_or_path!r}; bundled: {bundled_traces()}")
        path = candidate
    events = []
    with path.open() as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            ev = json.loads(line)
            events.append({"t": float(ev["t"]), "scope": ev["scope"],
                           "loc": int(ev["loc"])})
    if not events:
        raise ValueError(f"trace {path} has no events")
    events.sort(key=lambda e: e["t"])
    return events


@register_failure_model
class TraceReplayModel(FailureModel):
    """Replay a recorded failure log through the topology.

    ``trace`` is a bundled-trace name, a path, or an in-memory event
    list. Event times stretch by ``time_scale``; with ``loop=True``
    (default) the trace wraps around with a cumulative offset once
    exhausted, so any training horizon is covered. Events that fall
    inside a global-restart outage are skipped — those failures hit a
    system that was already down.
    """

    name = "trace"

    def __init__(self, trace="meta_hsdp_rackstorm", loop: bool = True,
                 time_scale: float = 1.0):
        self.trace = trace
        self.loop = loop
        self.time_scale = time_scale

    def bind(self, p, rng, topology=None) -> None:
        super().bind(p, rng, topology)
        self.topo = topology_from_spec(topology, n_groups=p.n)
        events = (self.trace if isinstance(self.trace, list)
                  else load_trace(self.trace))
        self._events = events
        self._times = [e["t"] * self.time_scale for e in events]
        # wrap period: trace span plus one mean gap, so the loop seam
        # does not create a double event
        span = self._times[-1] - self._times[0]
        gap = span / max(len(events) - 1, 1)
        self._period = self._times[-1] + max(gap, 1e-9)
        self._i = 0
        self._offset = 0.0

    def _event_time(self, i: int) -> float:
        return self._times[i] + self._offset

    def next_arrival(self, now: float, alive: int, n: int) -> float:
        while True:
            if self._i >= len(self._events):
                if not self.loop:
                    return math.inf
                self._i = 0
                self._offset += self._period
            t = self._event_time(self._i)
            if t < now:            # event landed during an outage: skip
                self._i += 1
                continue
            return t

    def draw_victims(self, now: float, dead: set[int]) -> list[int]:
        ev = self._events[self._i]
        self._i += 1
        return [w for w in self.topo.resolve(ev["scope"], ev["loc"])
                if w not in dead]


# ------------------------------------------------------------------ #
# superposition                                                      #
# ------------------------------------------------------------------ #
@register_failure_model
class SuperposedModel(FailureModel):
    """Superposition of independent component streams: the next event is
    the earliest component arrival; only the fired component re-draws.

    ``components`` is a list of model specs, e.g. a quiet Poisson
    background plus rare correlated pod kills::

        {"kind": "superposed", "components": [
            {"kind": "poisson", "mtbf": 2000.0},
            {"kind": "correlated", "scope": "pod", "burst_prob": 1.0,
             "mtbf": 50000.0}]}
    """

    name = "superposed"

    def __init__(self, components: list):
        if not components:
            raise ValueError("superposed model needs >= 1 component")
        self.components = components

    def bind(self, p, rng, topology=None) -> None:
        super().bind(p, rng, topology)
        self.models = [model_from_spec(s) for s in self.components]
        for m in self.models:
            m.bind(p, rng, topology)
        self._next: list[float] | None = None
        self._fired = 0

    def _arm(self, now: float, alive: int, n: int) -> float:
        self._next = [m.next_arrival(now, alive, n) for m in self.models]
        return self._pick()

    def _pick(self) -> float:
        assert self._next is not None
        k = min(range(len(self._next)), key=self._next.__getitem__)
        self._fired = k
        return self._next[k]

    def next_arrival(self, now: float, alive: int, n: int) -> float:
        if self._next is None:
            return self._arm(now, alive, n)
        self._next[self._fired] = \
            self.models[self._fired].next_arrival(now, alive, n)
        return self._pick()

    def draw_victims(self, now: float, dead: set[int]) -> list[int]:
        return self.models[self._fired].draw_victims(now, dead)

    def reset(self, now: float, alive: int, n: int) -> float:
        for m in self.models:
            m.reset(now, alive, n)
        return self._arm(now, alive, n)


# ------------------------------------------------------------------ #
# fail-slow (gray-failure) streams                                   #
# ------------------------------------------------------------------ #
class SlowdownModel(FailureModel):
    """Base class for *fail-slow* streams: degraded NICs, thermal
    throttling, flaky links. Unlike fail-stop models these never kill a
    group — each arrival opens a slowdown *episode* that inflates the
    victims' per-step time by a multiplicative ``factor`` until the
    episode's ``until`` time (``math.inf`` for persistent degradation
    that only a repair/restart clears). Because every collective is
    synchronous, one slowed group drags the whole step down to its
    pace — which is exactly what SPARe demotion (a weight-table edit)
    buys back.

    Same registry / ``bind`` contract as :class:`FailureModel`; the
    extra hook is :meth:`draw_episode`. Arrivals are exponential with
    mean ``mtbs`` (mean time between slowdowns) — slow events track
    component count, not survivor count, so no survivor scaling.
    """

    #: marks the model as a slowdown (not kill) stream for the injector
    degrades = True
    name = "slow-base"

    #: mean seconds between slowdown episodes
    mtbs: float = 3600.0

    def next_arrival(self, now: float, alive: int, n: int) -> float:
        return now + float(self.rng.exponential(self.mtbs))

    def draw_victims(self, now: float, dead: set[int]) -> list[int]:
        return []                      # slow streams never kill

    def draw_episode(self, now: float, slowed: set[int],
                     ) -> tuple[list[int], float, float]:
        """Return ``(groups, factor, until)`` for the episode at ``now``.
        ``until`` is the absolute end time (``math.inf`` = persistent)."""
        raise NotImplementedError

    # ---------------------------------------------------------- #
    def _seed_victim(self, slowed: set[int]) -> int:
        # prefer groups not already degraded so episodes spread out;
        # one rng.choice either way keeps the draw order fixed
        fresh = [w for w in range(self.n) if w not in slowed]
        return int(self.rng.choice(fresh if fresh else list(range(self.n))))

    def _draw_factor(self, lo: float, hi: float) -> float:
        # log-uniform in [lo, hi]; always one rng.random() draw so the
        # stream stays deterministic even when lo == hi
        u = float(self.rng.random())
        if hi <= lo:
            return float(lo)
        return float(math.exp(math.log(lo) + u * (math.log(hi) - math.log(lo))))


@register_failure_model
class FailSlowModel(SlowdownModel):
    """Persistent per-group degradation (degraded NIC / thermal
    throttle): each arrival slows one group — or, with ``scope`` set,
    the seed's whole blast radius (a bad ToR switch slows its rack) —
    by a log-uniform factor in ``[factor_min, factor_max]``, forever
    (until an external repair: demotion + later restart, or the
    injector's outage reset).
    """

    name = "fail_slow"

    def __init__(self, mtbs: float = 3600.0, factor_min: float = 2.0,
                 factor_max: float = 4.0, scope: str | None = None):
        if factor_min < 1.0:
            raise ValueError("slowdown factors must be >= 1")
        self.mtbs = mtbs
        self.factor_min = factor_min
        self.factor_max = factor_max
        self.scope = scope

    def bind(self, p, rng, topology=None) -> None:
        super().bind(p, rng, topology)
        self.topo = topology_from_spec(topology, n_groups=p.n)

    def draw_episode(self, now, slowed):
        v = self._seed_victim(slowed)
        factor = self._draw_factor(self.factor_min, self.factor_max)
        groups = (list(self.topo.blast_radius(v, self.scope))
                  if self.scope else [v])
        return groups, factor, math.inf


@register_failure_model
class FlakyLinkModel(FailSlowModel):
    """Intermittent flaky-link episodes: like :class:`FailSlowModel`
    but each episode heals on its own after an exponential duration
    with mean ``episode_len`` seconds (link retraining, transient
    congestion). Draw order per event: victim, factor, duration.
    """

    name = "flaky_link"

    def __init__(self, mtbs: float = 1800.0, episode_len: float = 600.0,
                 factor_min: float = 1.5, factor_max: float = 3.0,
                 scope: str | None = None):
        super().__init__(mtbs=mtbs, factor_min=factor_min,
                         factor_max=factor_max, scope=scope)
        self.episode_len = episode_len

    def draw_episode(self, now, slowed):
        groups, factor, _ = super().draw_episode(now, slowed)
        duration = float(self.rng.exponential(self.episode_len))
        return groups, factor, now + duration


# ------------------------------------------------------------------ #
# event-stream adapters                                              #
# ------------------------------------------------------------------ #
def drain_event_window(model: FailureModel, next_fail: float, end: float,
                       dead: set[int], alive: int, n: int,
                       ) -> tuple[list[tuple[float, list[int]]], float, int]:
    """Harvest every failure event with arrival time ``<= end``.

    The one victim-batching loop shared by the DES clock
    (:meth:`repro.des.engine.SimClock.advance`) and the live trainer
    bridge (:class:`repro.train.injection.ScenarioInjector`): per event,
    one ``draw_victims`` call (already-dead victims filtered) followed by
    one ``next_arrival`` re-arm — exactly the RNG-draw order the legacy
    parity tests pin down.

    ``dead`` is mutated in place; returns ``(events, next_fail, alive)``
    where ``events`` is one ``(arrival_time, victims)`` entry per event
    that killed at least one live group.
    """
    events: list[tuple[float, list[int]]] = []
    while next_fail <= end and alive > 0:
        victims: list[int] = []
        for v in model.draw_victims(next_fail, dead):
            if v in dead:
                continue
            dead.add(v)
            alive -= 1
            victims.append(v)
        if victims:
            events.append((next_fail, victims))
        next_fail = model.next_arrival(next_fail, max(alive, 1), n)
    return events, next_fail, alive


def drain_slow_window(model: SlowdownModel, next_slow: float, end: float,
                      slowed: set[int],
                      ) -> tuple[list[tuple[float, list[int], float, float]],
                                 float]:
    """Harvest every slowdown episode with arrival time ``<= end`` —
    the fail-slow counterpart of :func:`drain_event_window`, with the
    same pinned RNG discipline: per event one ``draw_episode`` call
    followed by one ``next_arrival`` re-arm.

    ``slowed`` (the groups currently degraded, mutated in place) only
    biases victim selection; overlap resolution — max factor wins,
    episodes extend — is the caller's (the injector keeps per-group
    ``(factor, until)`` state and expires entries itself).

    Returns ``(episodes, next_slow)`` where each episode is
    ``(arrival_time, groups, factor, until)``.
    """
    episodes: list[tuple[float, list[int], float, float]] = []
    while next_slow <= end:
        groups, factor, until = model.draw_episode(next_slow, slowed)
        if groups:
            episodes.append((next_slow, list(groups), factor, until))
            slowed.update(groups)
        next_slow = model.next_arrival(next_slow, model.n, model.n)
    return episodes, next_slow


def bind_model(model, n: int, rng: np.random.Generator,
               topology=None, params=None):
    """Coerce specs and bind a model for an ``n``-group system: returns
    ``(model, params, topology)`` with ``params.n`` forced to ``n`` and
    the topology validated against it (a mismatched layout would resolve
    blast radii to group ids outside ``[0, n)``). The one entry point
    shared by :func:`to_step_events` and the live trainer bridge."""
    from ..des.params import DESParams

    model = model_from_spec(model)
    p = params if params is not None else DESParams(n=n)
    if p.n != n:
        p = p.with_(n=n)
    topology = topology_from_spec(topology, n_groups=n)
    if topology.n_groups != n:
        raise ValueError(f"topology has n_groups={topology.n_groups} "
                         f"but the event stream targets n_groups={n}")
    model.bind(p, rng, topology)
    return model, p, topology


def to_step_events(model, n: int, *, seconds_per_step: float,
                   max_steps: int, rng: np.random.Generator,
                   topology: ClusterTopology | None = None,
                   params=None) -> list[tuple[int, list[int]]]:
    """Open-loop step-clock view of a failure model: bind it and map its
    arrival stream onto the trainer's step counter, resolving blast radii
    to DP-group victim batches.

    Returns ``[(step_index, victims), ...]`` for every event landing in
    ``[0, max_steps * seconds_per_step)``, where ``step_index ==
    floor(arrival / seconds_per_step)`` — the step whose all-reduce
    detects the failure. Groups stay dead for the rest of the horizon
    (no restarts), so this is the planning/analysis view; the *closed*
    loop — where wipe-outs restore capacity and re-arm the model — is
    :class:`repro.train.injection.ScenarioInjector`.
    """
    if seconds_per_step <= 0:
        raise ValueError("seconds_per_step must be positive")
    model, _, _ = bind_model(model, n, rng, topology=topology,
                             params=params)
    dead: set[int] = set()
    horizon = max_steps * seconds_per_step
    first = model.next_arrival(0.0, n, n)
    events, _, _ = drain_event_window(model, first, horizon, dead, n, n)
    return [(int(t // seconds_per_step), victims)
            for t, victims in events if t < horizon]


# ------------------------------------------------------------------ #
# Monte-Carlo bridge                                                 #
# ------------------------------------------------------------------ #
def sample_kill_batches(model, n: int, rng: np.random.Generator,
                        topology: ClusterTopology | None = None,
                        max_events: int | None = None) -> list[list[int]]:
    """Time-free victim sampling for the Monte-Carlo driver: bind the
    model and drain its event stream into an ordered list of kill
    batches (one list per simultaneous-failure event) until every group
    has failed. If the stream dries up first (finite non-looping
    trace), the remaining groups fail one-by-one in uniform random
    order so every trial reaches wipe-out.
    """
    from ..des.params import DESParams

    model = model_from_spec(model)
    model.bind(DESParams(n=n), rng, topology)
    max_events = max_events if max_events is not None else 50 * n
    dead: set[int] = set()
    batches: list[list[int]] = []
    t = model.next_arrival(0.0, n, n)
    events = 0
    # bound *iterations*, not non-empty batches: a looping trace whose
    # locations never cover all n groups yields empty draws forever
    while len(dead) < n and t != math.inf and events < max_events:
        events += 1
        victims = [v for v in model.draw_victims(t, dead) if v not in dead]
        if victims:
            batches.append(victims)
            dead.update(victims)
        t = model.next_arrival(t, max(n - len(dead), 1), n)
    if len(dead) < n:
        for w in rng.permutation(n):
            w = int(w)
            if w not in dead:
                batches.append([w])
                dead.add(w)
    return batches
