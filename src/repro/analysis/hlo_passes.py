"""HLO passes over compiled (post-SPMD) programs.

All four passes consume the artifacts :mod:`repro.launch.hlo` already
parses, so they run identically on a dryrun matrix cell at 512 emulated
devices and on a live :class:`~repro.exec.executor.MeshExecutor`:

``collective-schedule-determinism``
    The §3.1 tentpole invariant, generalized from the test fixture:
    every RECTLR-recoverable survivor set's compiled step must carry the
    byte-identical collective schedule of the healthy step at the same
    ``S_A`` (:func:`schedule_determinism_executor`), and a cell program
    must (a) keep the SPARe weight table a live entry parameter — a
    constant-folded or pruned weight input means masking changed (or
    never reached) the program — and (b) compile to the same schedule
    twice (:func:`schedule_determinism_cell`).

``donation-audit``
    Cross-checks ``donate_argnums`` declarations against the module's
    ``input_output_alias`` table. A donated-but-unaliased buffer is a
    silent 2x memory cost on params/opt/EF state: jax deletes the
    caller's buffer either way, but XLA allocates a fresh output.

``hot-path-purity``
    No host round-trips or fp64 in a step program: infeed/outfeed,
    send/recv, host callbacks (``CustomCall`` into python), stateful
    device RNG, and any ``f64``/``c128`` instruction are violations.

``wire-dtype-policy``
    The compressed sync's int8 payloads move through all-to-all /
    all-gather only — a reducing collective (all-reduce,
    reduce-scatter) over a narrow int dtype silently overflows at high
    DP degree, so any <= 16-bit integer reduction is a violation. EF
    residual state must stay fp32 (checked on the executor's state
    specs, where dtypes are visible).
"""
from __future__ import annotations

import re

from repro.analysis.core import Violation
from repro.launch.hlo import HloCost, analyze_hlo, parse_module

__all__ = ["HLO_PASSES", "donation_audit", "hot_path_purity",
           "parse_input_output_alias", "entry_param_shapes",
           "schedule_determinism_cell", "schedule_determinism_executor",
           "wire_dtype_policy"]

_ALIAS_ENTRY_RE = re.compile(r"\{[\d\s,]*\}:\s*\(\s*(\d+)\s*,\s*\{[\d\s,]*\}")
_INT_REDUCE_DTYPES = {"s4", "u4", "s8", "u8", "s16", "u16", "pred"}
_HOST_OPS = {"infeed", "outfeed", "send", "recv", "send-done", "recv-done",
             "rng-get-and-update-state"}
_CALLBACK_TARGET_RE = re.compile(
    r'custom_call_target="([^"]*(?:callback|py_func|host)[^"]*)"', re.I)
_WIDE_RE = re.compile(r"\b(f64|c128)\[")


# ------------------------------------------------------------------ #
# donation audit                                                     #
# ------------------------------------------------------------------ #
def parse_input_output_alias(hlo_text: str) -> list[int]:
    """Aliased entry-parameter numbers from the module header's
    ``input_output_alias={ {out}: (param, {path}, kind), ... }``."""
    header = hlo_text.splitlines()[0] if hlo_text else ""
    m = re.search(r"input_output_alias=\{", header)
    if not m:
        return []
    # balance braces from the opening one
    depth, i = 1, m.end()
    while i < len(header) and depth:
        if header[i] == "{":
            depth += 1
        elif header[i] == "}":
            depth -= 1
        i += 1
    blob = header[m.end(): i - 1]
    return sorted(int(g) for g in _ALIAS_ENTRY_RE.findall(blob))


def entry_param_shapes(hlo_text: str) -> list[str]:
    """Per-device entry parameter shapes (layout annotations stripped)
    from ``entry_computation_layout={(p0, p1, ...)->...}``."""
    header = hlo_text.splitlines()[0] if hlo_text else ""
    m = re.search(r"entry_computation_layout=\{\((.*?)\)->", header)
    if not m:
        return []
    shapes = re.findall(r"([a-z0-9]+\[[\d,]*\])", m.group(1))
    return shapes


def donation_audit(hlo_text: str, donated_leaves: int, tag: str,
                   donated_range: tuple[int, int] | None = None
                   ) -> list[Violation]:
    """``donated_leaves`` is the flat leaf count across all donated
    argnums at this jit site (the driver knows the lowering args); the
    compiled module must alias at least that many entry parameters to
    outputs. ``donated_range`` optionally names the (start, stop) param
    numbers the donated leaves occupy, for per-buffer attribution."""
    aliased = parse_input_output_alias(hlo_text)
    if donated_leaves <= len(aliased):
        return []
    params = entry_param_shapes(hlo_text)
    missing = donated_leaves - len(aliased)
    detail = ""
    if donated_range is not None:
        lo, hi = donated_range
        gaps = [p for p in range(lo, min(hi, len(params)))
                if p not in set(aliased)]
        shapes = ", ".join(f"#{p}:{params[p]}" for p in gaps[:6])
        if shapes:
            detail = f" (unaliased: {shapes}{'...' if len(gaps) > 6 else ''})"
    return [Violation(
        tag, 0, "donation-audit",
        f"{missing} of {donated_leaves} donated buffers have no "
        f"input/output alias — each costs a duplicate allocation{detail}")]


# ------------------------------------------------------------------ #
# hot-path purity                                                    #
# ------------------------------------------------------------------ #
def hot_path_purity(hlo_text: str, tag: str) -> list[Violation]:
    found: list[Violation] = []
    comps, entry = parse_module(hlo_text)
    for comp in comps.values():
        for instr in comp.instrs:
            base = instr.op.removesuffix("-start").removesuffix("-done")
            if base in _HOST_OPS or instr.op in _HOST_OPS:
                found.append(Violation(
                    tag, 0, "hot-path-purity",
                    f"host-transfer/stateful op {instr.op} "
                    f"(%{instr.name}) inside the step program"))
            elif instr.op == "custom-call":
                m = _CALLBACK_TARGET_RE.search(instr.attrs)
                if m:
                    found.append(Violation(
                        tag, 0, "hot-path-purity",
                        f"host callback custom-call {m.group(1)!r} "
                        f"(%{instr.name}) inside the step program"))
            if any(dt in ("f64", "c128") for dt, _ in instr.out_shapes):
                found.append(Violation(
                    tag, 0, "hot-path-purity",
                    f"fp64/c128 instruction %{instr.name} ({instr.op}) — "
                    "step programs are bf16/fp32 only"))
    return sorted(set(found))


# ------------------------------------------------------------------ #
# wire dtype policy                                                  #
# ------------------------------------------------------------------ #
def wire_dtype_policy(hlo: "str | HloCost", tag: str) -> list[Violation]:
    cost = hlo if isinstance(hlo, HloCost) else analyze_hlo(hlo)
    found = []
    for (op, dt), moved in sorted(cost.collective_dtype_bytes.items()):
        if op in ("all-reduce", "reduce-scatter") and \
                dt in _INT_REDUCE_DTYPES:
            found.append(Violation(
                tag, 0, "wire-dtype-policy",
                f"{op} over {dt} payload ({round(moved)} B) — compressed "
                "payloads must move via all-to-all/all-gather and "
                "accumulate in fp32 (overflow at high DP degree)"))
    return found


def ef_state_policy(executor, tag: str) -> list[Violation]:
    """EF residuals must stay fp32 — quantizing the *residual* compounds
    the quantization error instead of feeding it back."""
    import jax
    sync = getattr(executor, "_grad_sync", None)
    state = getattr(executor, "_ef_state", None)
    if sync is None or state is None:
        return []
    bad = [str(leaf.dtype) for leaf in jax.tree_util.tree_leaves(state)
           if str(leaf.dtype) != "float32"]
    if bad:
        return [Violation(tag, 0, "wire-dtype-policy",
                          f"EF residual leaves carry dtypes {sorted(set(bad))}"
                          " — residual state must stay fp32")]
    return []


# ------------------------------------------------------------------ #
# collective-schedule determinism                                    #
# ------------------------------------------------------------------ #
def _schedule(cost: HloCost) -> tuple:
    return (tuple(sorted(cost.collective_counts.items())),
            tuple(sorted((k, round(v)) for k, v in
                         cost.collective_bytes.items())))


def schedule_determinism_executor(executor, tag: str,
                                  max_failures: int | None = None
                                  ) -> tuple[list[Violation], int]:
    """Certify masking-is-data over the FULL recoverable survivor space:
    for every failure set RECTLR can mask, the executor's compiled step
    under the recovered schedule must carry the collective schedule of
    the healthy step at the same ``S_A``. Returns (violations,
    n_certified)."""
    from repro.core import SpareState
    from repro.exec.equivalence import recoverable_failure_sets

    n, r = executor.state.n, executor.state.r
    healthy_sched: dict[int, tuple] = {}

    def healthy(s_a: int) -> tuple:
        if s_a not in healthy_sched:
            st = SpareState(n, r)
            st.s_a = s_a
            healthy_sched[s_a] = _schedule(
                analyze_hlo(executor.compiled_step_text(state=st)))
        return healthy_sched[s_a]

    found: list[Violation] = []
    certified = 0
    for victims, state in recoverable_failure_sets(n, r, max_failures):
        got = _schedule(analyze_hlo(executor.compiled_step_text(state=state)))
        want = healthy(state.s_a)
        certified += 1
        if got != want:
            found.append(Violation(
                tag, 0, "collective-schedule-determinism",
                f"survivor set (victims={list(victims)}, S_A={state.s_a}) "
                f"compiles to a different collective schedule than the "
                f"healthy step: {got} != {want}"))
    return found, certified


def schedule_determinism_cell(text_a: str, text_b: str, tag: str,
                              weights_shape: str | None = None
                              ) -> list[Violation]:
    """Cell-level certification: two independent compiles of the same
    lowering must produce one collective schedule, and the SPARe weight
    table must be a live entry parameter (``weights_shape`` is the
    expected per-device shape string, e.g. ``"f32[2,4]"``)."""
    found = []
    sa, sb = _schedule(analyze_hlo(text_a)), _schedule(analyze_hlo(text_b))
    if sa != sb:
        found.append(Violation(
            tag, 0, "collective-schedule-determinism",
            f"two compiles of one lowering disagree on the collective "
            f"schedule: {sa} != {sb}"))
    if weights_shape is not None:
        if weights_shape not in entry_param_shapes(text_a):
            found.append(Violation(
                tag, 0, "collective-schedule-determinism",
                f"SPARe weight table ({weights_shape}) is not a live "
                "entry parameter — masking was folded into or pruned "
                "out of the program"))
    return found


HLO_PASSES = ("collective-schedule-determinism", "donation-audit",
              "hot-path-purity", "wire-dtype-policy")
