"""AST passes: the determinism lint and the thread-sharing audit.

**determinism** — the repo's recovery math and its CI gates lean on
byte-reproducibility (byte-identical campaign CSVs, bit-identical
requeued decodes, deterministic trace exports), so code that smuggles
ambient nondeterminism in is a correctness bug, not a style issue:

``wall-clock``       ``time.time()`` / ``datetime.now()``: durations
                     must use the monotonic clocks, provenance stamps an
                     injectable clock (see ``repro.ckpt``).
``unseeded-random``  module-level ``random.*`` / legacy ``np.random.*``
                     draws share hidden global state; use a seeded
                     ``Generator`` / ``random.Random`` instance.
``set-iteration``    iterating a set literal/constructor draws an
                     order that can vary with PYTHONHASHSEED; wrap in
                     ``sorted(...)``.
``builtin-hash``     ``hash()`` of str/bytes is salted per process —
                     anything persisted or compared across processes
                     must use a content hash.
``mutable-default``  a mutable default (``def f(x=[])`` or an unwrapped
                     dataclass field) is shared across calls/instances.

**thread-shared-state** — the feed thread (``exec/executor.py``) and
the async checkpoint writer (``ckpt/checkpoint.py``) must receive all
mutable inputs *by argument at submit time* (the snapshot is the
declared immutable channel). The audit resolves each thread target
(``pool.submit(f, ...)`` / ``threading.Thread(target=f)``), walks its
body plus same-class helper calls, and flags:

* writes to ``self.<attr>`` or ``nonlocal`` names from the thread body;
* reads of ``self.<attr>`` where the same class visibly reassigns the
  attribute outside ``__init__`` (mutable shared state, racy to read);
* reads of enclosing-function locals that are reassigned *after* the
  closure is defined (late-binding capture races).
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.core import (Report, Violation, file_skipped,
                                 iter_source_files, suppressed_lines)

__all__ = ["AST_PASSES", "lint_source", "run_ast_passes"]

_WALL_CLOCK_CALLS = {
    ("time", "time"),
    ("datetime", "now"), ("datetime", "utcnow"), ("datetime", "today"),
    ("date", "today"),
}
_RANDOM_MODULE_FNS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "getrandbits",
    "betavariate", "expovariate", "seed",
}
# the np.random.* legacy global-state API; the Generator constructors
# are the sanctioned replacements
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "Philox",
                 "PCG64", "MT19937", "BitGenerator"}
_MUTABLE_CTORS = {"list", "dict", "set"}


def _attr_chain(node: ast.AST) -> list[str]:
    """``np.random.choice`` -> ["np", "random", "choice"] (best effort)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return parts[::-1]


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset"))


class _DeterminismVisitor(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.found: list[Violation] = []
        self._np_aliases = {"numpy"}         # names numpy is imported as

    def _emit(self, node: ast.AST, rule: str, msg: str) -> None:
        self.found.append(Violation(self.path, node.lineno, rule, msg))

    # -- imports: track numpy aliases ------------------------------ #
    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            if alias.name == "numpy":
                self._np_aliases.add(alias.asname or "numpy")
        self.generic_visit(node)

    # -- calls ------------------------------------------------------ #
    def visit_Call(self, node: ast.Call) -> None:
        chain = _attr_chain(node.func)
        if len(chain) >= 2:
            if tuple(chain[-2:]) in _WALL_CLOCK_CALLS and \
                    chain[0] in ("time", "datetime"):
                self._emit(node, "wall-clock",
                           f"{'.'.join(chain)}() reads the wall clock; use "
                           "time.monotonic()/perf_counter() for durations "
                           "or inject a clock for provenance stamps")
            elif chain[0] == "random" and len(chain) == 2 and \
                    chain[1] in _RANDOM_MODULE_FNS:
                self._emit(node, "unseeded-random",
                           f"random.{chain[1]}() draws from the hidden "
                           "module-global state; use random.Random(seed)")
            elif len(chain) == 3 and chain[0] in self._np_aliases and \
                    chain[1] == "random" and chain[2] not in _NP_RANDOM_OK:
                self._emit(node, "unseeded-random",
                           f"{'.'.join(chain)}() uses the legacy global "
                           "RNG; use np.random.default_rng(seed)")
        elif chain == ["hash"]:
            self._emit(node, "builtin-hash",
                       "builtin hash() is salted per process "
                       "(PYTHONHASHSEED); use a content hash for anything "
                       "persisted or compared across processes")
        self.generic_visit(node)

    # -- set iteration ---------------------------------------------- #
    def _check_iter(self, it: ast.AST) -> None:
        if _is_set_expr(it):
            self._emit(it, "set-iteration",
                       "iteration order of a set can vary with "
                       "PYTHONHASHSEED; wrap in sorted(...)")

    def visit_For(self, node: ast.For) -> None:
        self._check_iter(node.iter)
        self.generic_visit(node)

    def visit_comprehension(self, node: ast.comprehension) -> None:
        self._check_iter(node.iter)
        self.generic_visit(node)

    # -- mutable defaults ------------------------------------------- #
    def _check_defaults(self, node) -> None:
        for d in list(node.args.defaults) + [d for d in
                                             node.args.kw_defaults if d]:
            if isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                    isinstance(d, ast.Call)
                    and isinstance(d.func, ast.Name)
                    and d.func.id in _MUTABLE_CTORS):
                self._emit(d, "mutable-default",
                           f"mutable default in {node.name}() is shared "
                           "across calls; default to None (or "
                           "dataclasses.field(default_factory=...))")

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._check_defaults(node)
        self.generic_visit(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._check_defaults(node)
        self.generic_visit(node)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        is_dataclass = any(
            (isinstance(d, ast.Name) and d.id == "dataclass")
            or (isinstance(d, ast.Attribute) and d.attr == "dataclass")
            or (isinstance(d, ast.Call) and "dataclass" in _attr_chain(
                d.func)[-1:])
            for d in node.decorator_list)
        if is_dataclass:
            for stmt in node.body:
                if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                    v = stmt.value
                    if isinstance(v, (ast.List, ast.Dict, ast.Set)) or (
                            isinstance(v, ast.Call)
                            and isinstance(v.func, ast.Name)
                            and v.func.id in _MUTABLE_CTORS):
                        self.found.append(Violation(
                            self.path, stmt.lineno, "mutable-default",
                            f"dataclass field in {node.name} holds a "
                            "mutable default shared across instances; use "
                            "field(default_factory=...)"))
        self.generic_visit(node)


# ------------------------------------------------------------------ #
# thread-sharing audit                                               #
# ------------------------------------------------------------------ #
def _self_attr(node: ast.AST) -> str | None:
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


class _ClassInfo:
    """Per-class mutation map: which self attributes are visibly
    reassigned outside ``__init__`` (mutable shared state)."""

    def __init__(self, node: ast.ClassDef):
        self.node = node
        self.methods = {m.name: m for m in node.body
                        if isinstance(m, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))}
        self.mutated_outside_init: set[str] = set()
        for name, m in self.methods.items():
            if name == "__init__":
                continue
            for sub in ast.walk(m):
                targets = []
                if isinstance(sub, ast.Assign):
                    targets = sub.targets
                elif isinstance(sub, (ast.AugAssign, ast.AnnAssign)):
                    targets = [sub.target]
                for t in targets:
                    attr = _self_attr(t)
                    if attr:
                        self.mutated_outside_init.add(attr)


def _thread_targets(func: ast.AST) -> list[tuple[ast.Call, ast.AST]]:
    """(call, target_expr) for every thread hand-off in ``func``:
    ``<pool>.submit(f, ...)`` and ``threading.Thread(target=f)``."""
    out = []
    for node in ast.walk(func):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr == "submit" and node.args:
            out.append((node, node.args[0]))
        chain = _attr_chain(node.func)
        if chain[-1:] == ["Thread"]:
            for kw in node.keywords:
                if kw.arg == "target":
                    out.append((node, kw.value))
    return out


class _ThreadAudit:
    MAX_DEPTH = 3

    def __init__(self, path: str, tree: ast.Module):
        self.path = path
        self.tree = tree
        self.found: list[Violation] = []
        self.classes = [_ClassInfo(n) for n in ast.walk(tree)
                        if isinstance(n, ast.ClassDef)]

    def run(self) -> list[Violation]:
        for cls in self.classes:
            for method in cls.methods.values():
                self._audit_scope(method, cls)
        for node in self.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._audit_scope(node, None)
        return self.found

    def _audit_scope(self, func: ast.AST, cls: _ClassInfo | None) -> None:
        for call, target in self._local_targets(func):
            if isinstance(target, ast.Attribute):
                attr = _self_attr(target)
                if attr and cls and attr in cls.methods:
                    self._audit_body(cls.methods[attr], cls, call,
                                     depth=0, seen={attr})
            elif isinstance(target, ast.Name):
                local = self._local_def(func, target.id)
                if local is not None:
                    self._audit_closure(local, func, cls, call)
                elif cls and target.id in cls.methods:
                    pass        # bare-name method ref: not a pattern used

    def _local_targets(self, func):
        return _thread_targets(func)

    @staticmethod
    def _local_def(func: ast.AST, name: str):
        for node in ast.walk(func):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == name:
                return node
        return None

    # -- method target: self.<attr> reads/writes -------------------- #
    def _audit_body(self, method, cls: _ClassInfo, call: ast.Call,
                    depth: int, seen: set[str]) -> None:
        if depth > self.MAX_DEPTH:
            return
        for node in ast.walk(method):
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            for t in targets:
                attr = _self_attr(t)
                if attr:
                    self.found.append(Violation(
                        self.path, node.lineno, "thread-shared-state",
                        f"thread target {method.name}() writes "
                        f"self.{attr}; mutate shared state on the "
                        "submitting thread and pass results back"))
            if isinstance(node, ast.Call):
                attr = _self_attr(node.func)
                if attr and attr in cls.methods and attr not in seen:
                    seen.add(attr)
                    self._audit_body(cls.methods[attr], cls, call,
                                     depth + 1, seen)
        for node in ast.walk(method):
            if isinstance(node, ast.Attribute) and not isinstance(
                    node.ctx, ast.Store):
                attr = _self_attr(node)
                if attr and attr in cls.mutated_outside_init:
                    self.found.append(Violation(
                        self.path, node.lineno, "thread-shared-state",
                        f"thread target {method.name}() reads "
                        f"self.{attr}, which {cls.node.name} reassigns "
                        "outside __init__; snapshot it into the submit "
                        "arguments instead"))

    # -- closure target: captured locals + self reads --------------- #
    def _audit_closure(self, closure, enclosing, cls: _ClassInfo | None,
                       call: ast.Call) -> None:
        params = {a.arg for a in closure.args.args}
        local_names = set(params)
        for node in ast.walk(closure):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        local_names.add(t.id)
            elif isinstance(node, ast.Nonlocal):
                self.found.append(Violation(
                    self.path, node.lineno, "thread-shared-state",
                    f"thread closure {closure.name}() rebinds nonlocal "
                    f"{', '.join(node.names)}; return the value and "
                    "assign on the submitting thread"))
        # self reads inside the closure body
        if cls is not None:
            for node in ast.walk(closure):
                attr = _self_attr(node)
                if attr and isinstance(node, ast.Attribute) and \
                        attr in cls.mutated_outside_init:
                    self.found.append(Violation(
                        self.path, node.lineno, "thread-shared-state",
                        f"thread closure {closure.name}() reads "
                        f"self.{attr}, which {cls.node.name} reassigns "
                        "outside __init__; snapshot it into a local "
                        "before defining the closure"))
        # late-binding captures: enclosing locals reassigned after the def
        reads = {node.id for node in ast.walk(closure)
                 if isinstance(node, ast.Name)
                 and isinstance(node.ctx, ast.Load)
                 and node.id not in local_names}
        for node in ast.walk(enclosing):
            if isinstance(node, ast.Assign) and \
                    node.lineno > closure.lineno:
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id in reads:
                        self.found.append(Violation(
                            self.path, node.lineno, "thread-shared-state",
                            f"{t.id} is reassigned after thread closure "
                            f"{closure.name}() captured it; the thread "
                            "may observe either value"))


def _determinism_pass(path: str, tree: ast.Module) -> list[Violation]:
    v = _DeterminismVisitor(path)
    v.visit(tree)
    return v.found


def _thread_pass(path: str, tree: ast.Module) -> list[Violation]:
    return _ThreadAudit(path, tree).run()


AST_PASSES = {
    "determinism": _determinism_pass,
    "thread-shared-state": _thread_pass,
}


def lint_source(path: str, source: str,
                passes=None) -> tuple[list[Violation], list[Violation]]:
    """Run the AST passes over one file; returns (violations,
    suppressed). Syntax errors surface as a ``parse-error`` finding
    rather than crashing the sweep."""
    if file_skipped(source):
        return [], []
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Violation(path, e.lineno or 0, "parse-error",
                          f"file does not parse: {e.msg}")], []
    found: list[Violation] = []
    for name, fn in (passes or AST_PASSES).items():
        found.extend(fn(path, tree))
    sup = suppressed_lines(source)
    kept, quiet = [], []
    for v in sorted(found):
        (quiet if v.rule in sup.get(v.line, ()) else kept).append(v)
    return kept, quiet


def run_ast_passes(root: str | Path, report: Report | None = None) -> Report:
    """Lint every repo source file into a :class:`Report`."""
    from pathlib import Path as _P
    root = _P(root)
    report = report if report is not None else Report()
    n_files = 0
    for f in iter_source_files(root):
        n_files += 1
        kept, quiet = lint_source(str(f.relative_to(root)),
                                  f.read_text(encoding="utf-8"))
        report.violations.extend(kept)
        report.suppressed.extend(quiet)
    report.note("ast", files_scanned=n_files)
    return report
