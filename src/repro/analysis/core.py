"""Pass framework plumbing: violations, suppressions, the report.

Every pass — AST or HLO — reduces to a list of :class:`Violation`.
A :class:`Report` collects them, applies inline suppressions, and
renders deterministically (sorted, stable JSON) so two runs over the
same tree are byte-identical — the report itself must pass the
determinism bar it enforces.

Suppression syntax (one reviewed finding, one line)::

    t0 = time.time()   # lint: ignore[wall-clock] -- provenance stamp

``# lint: ignore[rule-a,rule-b]`` suppresses the named rules on that
physical line only. A bare ``# lint: skip-file`` on one of the first
ten lines exempts the whole file (reserved for vendored code).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from pathlib import Path

__all__ = ["Violation", "Report", "iter_source_files", "suppressed_lines",
           "SKIP_FILE_RE"]

_IGNORE_RE = re.compile(r"#\s*lint:\s*ignore\[([\w\-, ]+)\]")
SKIP_FILE_RE = re.compile(r"#\s*lint:\s*skip-file\b")

# directories the repo-wide AST walk covers, relative to the repo root
SOURCE_ROOTS = ("src", "tests", "benchmarks", "examples")


@dataclass(frozen=True, order=True)
class Violation:
    """One finding. Ordered so reports sort deterministically."""

    path: str           # repo-relative file, or a program tag for HLO
    line: int           # 1-based; 0 for whole-program findings
    rule: str           # e.g. "wall-clock", "donation-audit"
    message: str

    def to_dict(self) -> dict:
        return {"path": self.path, "line": self.line, "rule": self.rule,
                "message": self.message}

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.message}"


def suppressed_lines(source: str) -> dict[int, set[str]]:
    """``{line_number: {rules}}`` for every inline suppression."""
    out: dict[int, set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _IGNORE_RE.search(text)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def file_skipped(source: str) -> bool:
    head = source.splitlines()[:10]
    return any(SKIP_FILE_RE.search(line) for line in head)


def iter_source_files(root: str | Path) -> list[Path]:
    """Every ``.py`` file the repo-wide lint covers, sorted."""
    root = Path(root)
    files: list[Path] = []
    for sub in SOURCE_ROOTS:
        base = root / sub
        if base.is_dir():
            files.extend(base.rglob("*.py"))
    return sorted(files)


@dataclass
class Report:
    """Violations + run metadata, rendered deterministically."""

    violations: list[Violation] = field(default_factory=list)
    suppressed: list[Violation] = field(default_factory=list)
    # pass name -> summary counters (files scanned, programs certified...)
    summary: dict[str, dict] = field(default_factory=dict)

    def extend(self, violations, suppressions: dict[int, set[str]]
               | None = None) -> None:
        """Add findings, diverting any whose (line, rule) is suppressed."""
        for v in violations:
            rules = (suppressions or {}).get(v.line, ())
            if v.rule in rules:
                self.suppressed.append(v)
            else:
                self.violations.append(v)

    def note(self, pass_name: str, **counters) -> None:
        entry = self.summary.setdefault(pass_name, {})
        for k, v in counters.items():
            entry[k] = entry.get(k, 0) + v if isinstance(v, (int, float)) \
                else v

    @property
    def clean(self) -> bool:
        return not self.violations

    def merge_json(self, payload: str) -> None:
        """Fold a child process's :meth:`to_json` report into this one
        (the HLO passes run in subprocesses so each pins its own
        emulated device count before jax initializes)."""
        data = json.loads(payload)
        self.violations.extend(Violation(**v) for v in data["violations"])
        self.suppressed.extend(Violation(**v) for v in data["suppressed"])
        for name, counters in data["summary"].items():
            self.note(name, **counters)

    def to_json(self) -> str:
        payload = {
            "clean": self.clean,
            "violations": [v.to_dict() for v in sorted(self.violations)],
            "suppressed": [v.to_dict() for v in sorted(self.suppressed)],
            "summary": {k: dict(sorted(v.items()))
                        for k, v in sorted(self.summary.items())},
        }
        return json.dumps(payload, indent=1, sort_keys=True)

    def render_text(self) -> str:
        lines = []
        for v in sorted(self.violations):
            lines.append(v.render())
        lines.append(f"{len(self.violations)} violation(s), "
                     f"{len(self.suppressed)} suppressed")
        for name, counters in sorted(self.summary.items()):
            stats = ", ".join(f"{k}={v}" for k, v in sorted(counters.items()))
            lines.append(f"  [{name}] {stats}")
        return "\n".join(lines)
