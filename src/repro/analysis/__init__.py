"""repro.analysis — static SPARe-invariant verification.

The paper's recovery math holds only if the lowered step programs are
*statically* well-behaved: masking must stay pure weight-table data
(identical collective schedules for every recoverable survivor set),
donated buffers must actually alias their outputs (a silent 2x memory
cost otherwise), step programs must stay free of host transfers and
fp64, and the int8 wire payloads must never be psummed. The runtime
spot-checks in ``tests/test_exec.py`` prove these on a handful of
fixtures; this package turns them into a pass framework any program —
and CI — can run:

* **HLO passes** (:mod:`.hlo_passes`) analyze compiled (post-SPMD) HLO
  text via :mod:`repro.launch.hlo`: ``collective-schedule-determinism``,
  ``donation-audit``, ``hot-path-purity``, ``wire-dtype-policy``.
* **AST passes** (:mod:`.ast_passes`) lint Python source repo-wide:
  ``determinism`` (wall-clock reads, unseeded RNG, set-iteration order,
  PYTHONHASHSEED-dependent ``hash()``, mutable defaults) and
  ``thread-shared-state`` (thread-target closures touching shared
  mutable state outside the submit-argument channel).

``python -m repro.launch.lint`` is the driver; findings render as a
deterministic JSON + text report and a single line suppresses a
reviewed one: ``# lint: ignore[<rule>]``.
"""
from repro.analysis.core import (Report, Violation, iter_source_files,
                                 suppressed_lines)
from repro.analysis.ast_passes import (AST_PASSES, lint_source,
                                       run_ast_passes)
from repro.analysis.hlo_passes import (HLO_PASSES, donation_audit,
                                       hot_path_purity,
                                       schedule_determinism_cell,
                                       schedule_determinism_executor,
                                       wire_dtype_policy)

__all__ = [
    "Report", "Violation", "iter_source_files", "suppressed_lines",
    "AST_PASSES", "lint_source", "run_ast_passes",
    "HLO_PASSES", "donation_audit", "hot_path_purity",
    "schedule_determinism_cell", "schedule_determinism_executor",
    "wire_dtype_policy",
]
