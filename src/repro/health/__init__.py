"""repro.health — gray-failure resilience: straggler detection + demotion.

Fail-stop recovery (mask -> reshape -> restart, PRs 1-9) misses the
dominant availability tax at 100k+ GPUs: *fail-slow* components —
degraded NICs, thermal throttling, flaky links — that silently drag
every synchronous step down to the straggler's pace. This package
closes that gap:

* :mod:`repro.health.detector` — an online straggler detector over
  per-group step timings: EWMA smoothing, median + MAD robust z-score,
  flag/clear hysteresis and dwell counters (deterministic, pure
  numpy);
* :mod:`repro.health.policy` — the closed-form degraded-throughput
  model (step time = max slowdown factor over groups still in the
  sync barrier) comparing tolerate vs SPARe *demotion* (a pure
  weight-table edit, zero recompiles) vs elastic reshape vs restart —
  the gray-failure analogue of :func:`repro.elastic.policy
  .ttt_estimates`, evaluated live by
  :meth:`repro.des.schemes.AdaptiveScheme.decide_degraded`.

Fail-slow *injection* lives with the other failure models
(:class:`repro.scenarios.models.SlowdownModel` and the injector's slow
channel); the trainer's health tick and the serving tier's
health-weighted routing consume this package.
"""
from repro.health.detector import HealthReport, StragglerDetector
from repro.health.policy import degraded_ttt_estimates

__all__ = ["StragglerDetector", "HealthReport", "degraded_ttt_estimates"]
