"""Closed-form throughput policy for degraded (fail-slow) groups.

Every gradient sync is a barrier, so a synchronous step takes

    step_time = sps * max(factor[g]  for g alive and in the barrier)

where ``factor[g]`` is group ``g``'s current slowdown (1.0 = healthy).
When the detector flags a straggler set ``candidates``, the run has
four ways to finish the remaining ``R`` steps:

* **tolerate** — keep everyone in the barrier and run at the
  straggler's pace::

      TTT_tolerate = R * sps * max_factor

* **demote** — SPARe-mask the candidates out of the weighted sync (a
  pure weight-table edit: zero recompiles once both stacking depths
  are warm, instantly reversible when the episode heals). Survivors
  cover the demoted types through redundant stacking, so per-step
  *work* is unchanged — the §3.1 invariant holds — and pace returns to
  the healthiest survivor's::

      TTT_demote = t_demote + R * sps * max_surviving_factor

  feasible only while RECTLR can re-cover the demoted set
  (``maskable``);

* **reshape** — shrink DP onto a survivor submesh excluding the
  stragglers, at full pace but ``dp_full / dp_new`` more steps for the
  same work (see :func:`repro.elastic.policy.ttt_estimates`);

* **restart** — swap the degraded hardware during a full restart
  outage and re-run from the last snapshot at full health.

Ties break toward the least disruptive action, in the order
tolerate > demote > reshape > restart (demote keeps all state warm;
reshape loses capacity; restart loses optimizer steps).
"""
from __future__ import annotations

import numpy as np

__all__ = ["degraded_ttt_estimates"]

#: tie-break preference, least disruptive first
_ACTION_ORDER = ("tolerate", "demote", "reshape", "restart")


def degraded_ttt_estimates(*, factors, candidates, remaining_steps: int,
                           seconds_per_step: float, dp_full: int,
                           dp_new: int = 0, maskable: bool = True,
                           alive=None, demoted=(), rollback_steps: int = 0,
                           t_restart: float, t_reshape: float,
                           t_demote: float = 0.0) -> dict:
    """All four candidates' time-to-train and the argmin ``action``.

    ``factors`` is the per-group slowdown vector (detector estimates or
    injector model); ``candidates`` the straggler set under decision;
    ``demoted`` the groups already masked out of the barrier;
    ``dp_new`` the degree an elastic reshape excluding the candidates
    would continue at (0 = reshape unavailable). ``maskable=False``
    (RECTLR cannot re-cover the candidate set) makes demote
    infeasible.
    """
    f = np.asarray(factors, dtype=np.float64)
    n = f.shape[0]
    live = (np.ones(n, dtype=bool) if alive is None
            else np.asarray(alive, dtype=bool))
    in_barrier = live.copy()
    for g in demoted:
        in_barrier[int(g)] = False
    cand = sorted(int(g) for g in candidates)

    def _pace(mask: np.ndarray) -> float:
        return float(f[mask].max()) if mask.any() else float("inf")

    sps = float(seconds_per_step)
    work = float(remaining_steps) * sps
    max_factor = _pace(in_barrier)
    after = in_barrier.copy()
    for g in cand:
        after[g] = False
    surviving_factor = _pace(after)

    tolerate_ttt = work * max_factor
    demote_ttt = (float(t_demote) + work * surviving_factor
                  if (maskable and cand and after.any()) else float("inf"))
    reshape_ttt = (float(t_reshape) + work * (float(dp_full) / dp_new)
                   if dp_new > 0 else float("inf"))
    restart_ttt = float(t_restart) + \
        (float(rollback_steps) + float(remaining_steps)) * sps

    ttts = {"tolerate": tolerate_ttt, "demote": demote_ttt,
            "reshape": reshape_ttt, "restart": restart_ttt}
    action = min(_ACTION_ORDER, key=lambda a: (ttts[a], _ACTION_ORDER.index(a)))
    return {
        "action": action,
        "tolerate_ttt": tolerate_ttt,
        "demote_ttt": demote_ttt,
        "reshape_ttt": reshape_ttt,
        "restart_ttt": restart_ttt,
        "max_factor": max_factor,
        "surviving_factor": surviving_factor,
        "candidates": cand,
        "maskable": bool(maskable),
        "dp_full": int(dp_full),
        "dp_new": int(dp_new),
        "remaining_steps": int(remaining_steps),
        "rollback_steps": int(rollback_steps),
        "seconds_per_step": sps,
        "t_restart": float(t_restart),
        "t_reshape": float(t_reshape),
        "t_demote": float(t_demote),
    }
