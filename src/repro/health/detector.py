"""Online straggler detection over per-group step timings.

The detector consumes one observation per training step: a vector of
per-DP-group step seconds — what each group's local compute + comm
took (or, on the emulated mesh, the injector's modeled
``group_step_seconds()``; on real hardware, the per-group sync-wait
timings the PR 7 telemetry tracks already capture). It must be

* **robust** — one straggler must not poison the baseline it is
  compared against, so the center/scale statistics are median + MAD,
  not mean + stddev;
* **stable** — gray failures are noisy, so raw timings are EWMA-
  smoothed and the flag decision uses hysteresis (a higher flag
  threshold than clear threshold) plus dwell counters: a group is only
  flagged after ``min_dwell`` consecutive anomalous steps and only
  cleared after ``clear_dwell`` consecutive healthy ones — no
  demote/re-admit flapping on transient noise;
* **deterministic** — pure numpy over the inputs, no wall clock, no
  randomness; identical timing streams produce identical flag
  sequences (the lint sweep's determinism rules apply here as to any
  hot-path module).

The robust z-score is the standard consistent estimate
``0.6745 * (x - median) / MAD`` with the MAD floored at
``mad_floor_frac * median`` so a perfectly uniform healthy fleet
(MAD = 0) cannot produce infinite scores from float dust.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["StragglerDetector", "HealthReport"]

#: Phi^-1(0.75): scales MAD to a stddev-consistent estimate
_MAD_CONSISTENCY = 0.6745


@dataclass(frozen=True)
class HealthReport:
    """One observation's verdict (all arrays length ``n_groups``)."""

    step: int
    #: EWMA-smoothed per-group step seconds
    smoothed: np.ndarray
    #: robust z-score vs the live-group median
    zscores: np.ndarray
    #: estimated slowdown factor: smoothed / median (1.0 = healthy)
    factors: np.ndarray
    #: groups currently flagged as stragglers (sorted)
    flagged: tuple[int, ...]
    #: groups whose flag rose this observation (sorted)
    newly_flagged: tuple[int, ...] = ()
    #: groups whose flag cleared this observation (sorted)
    newly_cleared: tuple[int, ...] = ()

    def factor(self, group: int) -> float:
        return float(self.factors[group])


class StragglerDetector:
    """Median+MAD straggler detector with EWMA smoothing, hysteresis,
    and dwell counters (see module docstring).

    Parameters
    ----------
    n_groups: DP-group count (observation vectors must match).
    ewma_alpha: smoothing weight of the newest sample in ``(0, 1]``.
    flag_z / clear_z: robust-z thresholds — a group must score above
        ``flag_z`` to accumulate flag dwell, and below ``clear_z`` to
        accumulate clear dwell (``flag_z > clear_z`` is the hysteresis
        band where state holds).
    flag_factor / clear_factor: slowdown-factor thresholds combined
        (AND) with the z thresholds, so a tightly-packed fleet's tiny
        MAD cannot flag a materially-healthy group.
    min_dwell / clear_dwell: consecutive observations required to
        raise / clear a flag.
    warmup: observations before any group may be flagged (the EWMA
        needs a few samples to mean anything).
    mad_floor_frac: MAD floor as a fraction of the median.
    """

    def __init__(self, n_groups: int, *, ewma_alpha: float = 0.4,
                 flag_z: float = 3.5, clear_z: float = 2.0,
                 flag_factor: float = 1.5, clear_factor: float = 1.2,
                 min_dwell: int = 3, clear_dwell: int = 3,
                 warmup: int = 2, mad_floor_frac: float = 0.02):
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if clear_z > flag_z or clear_factor > flag_factor:
            raise ValueError("clear thresholds must not exceed flag "
                             "thresholds (hysteresis)")
        if min_dwell < 1 or clear_dwell < 1:
            raise ValueError("dwell counts must be >= 1")
        self.n = int(n_groups)
        self.ewma_alpha = float(ewma_alpha)
        self.flag_z = float(flag_z)
        self.clear_z = float(clear_z)
        self.flag_factor = float(flag_factor)
        self.clear_factor = float(clear_factor)
        self.min_dwell = int(min_dwell)
        self.clear_dwell = int(clear_dwell)
        self.warmup = int(warmup)
        self.mad_floor_frac = float(mad_floor_frac)
        self.reset()

    def reset(self) -> None:
        """Forget all history (e.g. after a global restart)."""
        self._smoothed: np.ndarray | None = None
        self._flag_dwell = np.zeros(self.n, dtype=np.int64)
        self._clear_dwell = np.zeros(self.n, dtype=np.int64)
        self._flagged = np.zeros(self.n, dtype=bool)
        self.observations = 0
        self.reports: list[HealthReport] = []

    # ------------------------------------------------------------- #
    @property
    def flagged(self) -> tuple[int, ...]:
        return tuple(int(g) for g in np.flatnonzero(self._flagged))

    def estimated_factor(self, group: int) -> float:
        """Latest slowdown-factor estimate for ``group`` (1.0 before
        any observation)."""
        if not self.reports:
            return 1.0
        return self.reports[-1].factor(group)

    # ------------------------------------------------------------- #
    def observe(self, group_seconds, *, alive=None,
                step: int | None = None) -> HealthReport:
        """Feed one step's per-group timings; return the verdict.

        ``alive`` masks dead groups out of the baseline statistics and
        from flagging (a dead group is fail-stop, not fail-slow). The
        baseline deliberately *includes* already-flagged stragglers —
        the median absorbs a minority of outliers, and excluding them
        would let the clear decision compare a healed group against a
        baseline it no longer belongs to.
        """
        x = np.asarray(group_seconds, dtype=np.float64)
        if x.shape != (self.n,):
            raise ValueError(f"expected {self.n} group timings, "
                             f"got shape {x.shape}")
        live = (np.ones(self.n, dtype=bool) if alive is None
                else np.asarray(alive, dtype=bool).copy())
        if step is None:
            step = self.observations

        if self._smoothed is None:
            self._smoothed = x.copy()
        else:
            a = self.ewma_alpha
            self._smoothed = a * x + (1.0 - a) * self._smoothed
        s = self._smoothed

        base = s[live] if live.any() else s
        med = float(np.median(base))
        mad = float(np.median(np.abs(base - med)))
        mad = max(mad, self.mad_floor_frac * max(med, 1e-12), 1e-12)
        z = _MAD_CONSISTENCY * (s - med) / mad
        factors = s / max(med, 1e-12)

        self.observations += 1
        warm = self.observations > self.warmup
        anomalous = live & (z >= self.flag_z) & (factors >= self.flag_factor)
        healthy = (z <= self.clear_z) & (factors <= self.clear_factor)

        self._flag_dwell = np.where(anomalous, self._flag_dwell + 1, 0)
        self._clear_dwell = np.where(healthy, self._clear_dwell + 1, 0)
        # dead groups drop their flag immediately: fail-stop recovery
        # owns them now
        self._clear_dwell[~live] = self.clear_dwell
        before = self._flagged.copy()
        rise = warm & (self._flag_dwell >= self.min_dwell)
        fall = self._clear_dwell >= self.clear_dwell
        self._flagged = (self._flagged | rise) & ~fall

        newly_flagged = tuple(
            int(g) for g in np.flatnonzero(self._flagged & ~before))
        newly_cleared = tuple(
            int(g) for g in np.flatnonzero(before & ~self._flagged))
        report = HealthReport(
            step=int(step), smoothed=s.copy(), zscores=z, factors=factors,
            flagged=self.flagged, newly_flagged=newly_flagged,
            newly_cleared=newly_cleared)
        self.reports.append(report)
        return report
