"""Shared neural building blocks (pure jnp, bf16 activations / fp32 math
where it matters)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "rmsnorm", "swiglu", "rope_freqs", "apply_rope",
    "embed_lookup", "cross_entropy", "init_linear", "ACT_DTYPE",
]

ACT_DTYPE = jnp.bfloat16


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm in fp32, cast back to the activation dtype."""
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf * scale) * w.astype(jnp.float32)).astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: silu(x W_g) * (x W_u) W_d.

    Activation math stays in the compute dtype (bf16): upcasting the
    (tokens, d_ff) tensors to f32 doubled the dominant HBM-traffic term
    of every train cell for no measurable numeric benefit (§Perf iter 5;
    norms and softmax remain fp32 — those reductions are the sensitive
    ones).
    """
    g = jnp.dot(x, w_gate)
    u = jnp.dot(x, w_up)
    h = jax.nn.silu(g) * u
    return jnp.dot(h, w_down)


def mlp2(x: jax.Array, w_in: jax.Array, w_out: jax.Array,
         kind: str = "gelu") -> jax.Array:
    """Two-matrix MLP (starcoder2: gelu; nemotron/minitron: squared relu)."""
    h = jnp.dot(x, w_in)
    if kind == "gelu":
        h = jax.nn.gelu(h)
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(f"unknown mlp kind {kind!r}")
    return jnp.dot(h, w_out)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for rotary embedding, shape (head_dim/2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs (x0, x1) by position-dependent angles.

    x: (..., seq, heads, head_dim); positions: (..., seq) int32.
    Implemented split-half (HF/Llama convention).
    """
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv    # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]                        # (..., seq, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    """Token embedding via row gather.

    With the table sharded P('data', 'model') (vocab FSDP x embed TP),
    GSPMD resolves the gather as one all-gather of the (V, D/TP) slice over
    the data axis followed by a local take — one-hot matmul would instead
    cost 2*T*V*D FLOPs, prohibitive at V ~ 1.5e5.
    """
    return jnp.take(table, tokens, axis=0)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy, fp32 reduction. logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


def init_linear(key: jax.Array, shape: tuple[int, ...],
                dtype=ACT_DTYPE, scale: float | None = None) -> jax.Array:
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std
            ).astype(dtype)
