"""Model zoo: decoder-only LM family covering all 10 assigned architectures.

Families:

* ``dense``  — GQA transformer (optionally QKV-bias, padded-head TP)
* ``moe``    — MLA attention + shared/routed top-k experts (DeepSeek V2/V3)
* ``ssm``    — Mamba-2 SSD (attention-free)
* ``hybrid`` — Jamba-style 1:7 attn:mamba interleave with periodic MoE

Every architecture is a :class:`repro.models.config.ModelConfig`; the
builder in :mod:`repro.models.model` assembles the same reusable blocks
(:mod:`layers`, :mod:`attention`, :mod:`moe`, :mod:`ssm`) into
``init / loss (train fwd) / decode_step`` functions that are pure JAX and
scan-over-layers, so compile time is independent of depth.
"""
from .config import ModelConfig, MoEConfig, SSMConfig
from .model import Model, build_model

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "Model", "build_model"]
