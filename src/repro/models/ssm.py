"""Mamba-2 (SSD — state-space duality) block.

Chunked SSD semantics (Dao & Gu 2024): within chunks of length Q the
recurrence is computed as a masked attention-like quadratic form; across
chunks a tiny ``lax.scan`` carries the (heads, head_dim, d_state) running
state. Decode keeps O(1) state per token — which is why the ssm/hybrid
families are the only ones qualifying for the long_500k shape.

Projections are split per component (z/x/B/C/dt) instead of one fused
in_proj so each weight shards cleanly over the ``model`` axis (heads and
d_inner are model-sharded; the small B/C/dt projections replicate).

The per-chunk quadratic form is the Pallas kernel target
(``repro/kernels/ssd_scan.py``); :func:`ssd_chunked` doubles as its oracle.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, SSMConfig
from .layers import rmsnorm

__all__ = ["ssd_chunked", "ssd_decode_step", "mamba_forward", "mamba_decode",
           "MambaCache", "init_mamba_cache"]


class MambaCache(NamedTuple):
    conv: jax.Array    # (B, W-1, conv_dim) — rolling conv window
    state: jax.Array   # (B, nheads, head_dim, d_state) — SSD state


def init_mamba_cache(cfg: ModelConfig, batch: int,
                     dtype=jnp.bfloat16) -> MambaCache:
    s = cfg.ssm
    assert s is not None
    d_in = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return MambaCache(
        jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    )


# ------------------------------------------------------------------ #
# SSD core                                                            #
# ------------------------------------------------------------------ #
def ssd_chunked(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                b: jax.Array, c: jax.Array, chunk: int,
                state0: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x:  (B, S, H, P)   — per-head inputs (P = head_dim)
    dt: (B, S, H)      — softplus'd timestep
    a_log: (H,)        — A = -exp(a_log)
    b, c: (B, S, H, N) — input/output projections (already group-broadcast)
    Returns (y (B,S,H,P), final_state (B,H,P,N)).

    All decay math in fp32; the recurrence is y_t = c_t . S_t with
    S_t = exp(dt_t A) S_{t-1} + dt_t b_t (x) x_t.
    """
    bs, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    nc = s // chunk

    a = -jnp.exp(a_log.astype(jnp.float32))              # (H,)
    # chunk-major layout for the scan: (nc, B, Q, H, *)
    xr = x.reshape(bs, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    br = b.reshape(bs, nc, chunk, h, n).transpose(1, 0, 2, 3, 4)
    cr = c.reshape(bs, nc, chunk, h, n).transpose(1, 0, 2, 3, 4)
    dtr = dt.reshape(bs, nc, chunk, h).transpose(1, 0, 2, 3).astype(jnp.float32)

    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    init = (jnp.zeros((bs, h, p, n), jnp.float32)
            if state0 is None else state0.astype(jnp.float32))

    def scan_body(state, inp):
        xz, bz, cz, dtz = inp                            # (B,Q,H,*)
        dtaz = dtz * a[None, None, :]                    # (B,Q,H) log-decay
        cum = jnp.cumsum(dtaz, axis=1)                   # (B,Q,H)
        seg_total = cum[:, -1]                           # (B,H)

        # intra-chunk quadratic form: L[i,j] = exp(cum_i - cum_j), j <= i
        logl = cum[:, :, None, :] - cum[:, None, :, :]   # (B,Q,Q,H)
        l = jnp.where(mask[None, :, :, None], jnp.exp(logl), 0.0)
        cb = jnp.einsum("bihn,bjhn->bijh",
                        cz.astype(jnp.float32), bz.astype(jnp.float32))
        w = cb * l * dtz[:, None, :, :]                  # weight on x_j
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xz.astype(jnp.float32))

        # inter-chunk: y_inter[i] = exp(cum_i) * c_i . state
        y_inter = jnp.einsum("bihn,bhpn->bihp", cz.astype(jnp.float32), state)
        y_inter = y_inter * jnp.exp(cum)[..., None]

        # state update: decay-to-end-weighted outer products
        dec_to_end = jnp.exp(seg_total[:, None, :] - cum)  # (B,Q,H)
        s_chunk = jnp.einsum("bjh,bjhn,bjhp->bhpn",
                             dec_to_end * dtz, bz.astype(jnp.float32),
                             xz.astype(jnp.float32))
        new_state = state * jnp.exp(seg_total)[:, :, None, None] + s_chunk
        return new_state, (y_intra + y_inter).astype(x.dtype)

    final, ys = jax.lax.scan(scan_body, init, (xr, br, cr, dtr))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bs, s, h, p)
    return y, final


def ssd_decode_step(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                    b: jax.Array, c: jax.Array, state: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """Single-token SSD update. x (B,H,P), dt (B,H), b,c (B,H,N),
    state (B,H,P,N) fp32. Returns (y (B,H,P), new_state)."""
    dtf = dt.astype(jnp.float32)
    a = -jnp.exp(a_log.astype(jnp.float32))
    decay = jnp.exp(dtf * a[None, :])                    # (B,H)
    outer = jnp.einsum("bhp,bhn->bhpn", x.astype(jnp.float32),
                       b.astype(jnp.float32)) * dtf[:, :, None, None]
    new_state = state * decay[:, :, None, None] + outer
    y = jnp.einsum("bhpn,bhn->bhp", new_state, c.astype(jnp.float32))
    return y.astype(x.dtype), new_state


# ------------------------------------------------------------------ #
# full block                                                          #
# ------------------------------------------------------------------ #
def _conv1d_causal(x: jax.Array, w: jax.Array, bias: jax.Array,
                   prefix: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv. x (B,S,C); w (C,W); prefix (B,W-1,C).

    f32 taps+bias (cheap: 4-tap depthwise) with a single rounding point —
    the decode path computes the same window product in f32, so both
    paths round identically and the SSD recurrence sees the same inputs.
    """
    width = w.shape[1]
    if prefix is None:
        prefix = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prefix, x], axis=1).astype(jnp.float32)
    wf = w.astype(jnp.float32)
    out = sum(
        xp[:, i : i + x.shape[1], :] * wf[None, None, :, i]
        for i in range(width)
    )
    # indexing w as (C, W): w[:, i] per tap
    return out + bias[None, None, :].astype(jnp.float32)


def _split_proj(x, p, cfg: ModelConfig):
    s = cfg.ssm
    z = jnp.dot(x, p["wz"])                              # (B,S,d_in)
    xc = jnp.dot(x, p["wx"])                             # (B,S,d_in)
    bproj = jnp.dot(x, p["wb"])                          # (B,S,G*N)
    cproj = jnp.dot(x, p["wc"])                          # (B,S,G*N)
    dt = jnp.dot(x, p["wdt"])                            # (B,S,H)
    return z, xc, bproj, cproj, dt


def _broadcast_groups(t: jax.Array, n_heads: int, s: SSMConfig) -> jax.Array:
    """(B,S,G*N) -> (B,S,H,N) by repeating each group across its heads."""
    bshape = t.shape[:-1]
    g = s.n_groups
    t = t.reshape(*bshape, g, s.d_state)
    rep = n_heads // g
    t = jnp.broadcast_to(t[..., :, None, :], (*bshape, g, rep, s.d_state))
    return t.reshape(*bshape, n_heads, s.d_state)


def mamba_forward(x: jax.Array, p: dict, cfg: ModelConfig,
                  state0: jax.Array | None = None,
                  return_state: bool = False,
                  return_cache: bool = False):
    """Full-sequence Mamba-2 mixer. x (B,S,D) -> (B,S,D).

    ``return_cache`` returns ``(out, MambaCache(conv_tail, final_state))``
    — the exact cache :func:`mamba_decode` would hold after consuming the
    sequence token by token: the last W-1 raw ``conv_in`` rows plus the
    final SSD state (fused cache-filling prefill). NB: unlike attention,
    the SSD recurrence runs *through* every input token, so callers must
    feed exact-length prompts — right-padding would corrupt the state.
    """
    s = cfg.ssm
    assert s is not None
    bsz, seq, _ = x.shape
    nh = s.n_heads(cfg.d_model)
    d_in = s.d_inner(cfg.d_model)

    z, xc, bp, cp, dt = _split_proj(x, p, cfg)
    conv_in = jnp.concatenate([xc, bp, cp], axis=-1)
    conv_out = _conv1d_causal(conv_in, p["conv_w"], p["conv_b"])
    # the f32 conv bias promotes the chain — pin back to the compute dtype
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)
    xs = conv_out[..., :d_in]
    bs_ = conv_out[..., d_in : d_in + s.n_groups * s.d_state]
    cs = conv_out[..., d_in + s.n_groups * s.d_state :]

    xh = xs.reshape(bsz, seq, nh, s.head_dim)
    bh = _broadcast_groups(bs_, nh, s)
    ch = _broadcast_groups(cs, nh, s)
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32)
                            + p["dt_bias"].astype(jnp.float32))

    chunk = min(s.chunk, seq)
    y, final = ssd_chunked(xh, dt_sp, p["a_log"], bh, ch, chunk,
                           state0=state0)
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, None, :, None].astype(y.dtype)
    y = y.reshape(bsz, seq, d_in)
    # gated RMSNorm (mamba-2): norm(y * silu(z))
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["gate_norm"], cfg.norm_eps)
    out = jnp.dot(y, p["out_proj"])
    if return_cache:
        pad = jnp.zeros((bsz, s.conv_width - 1, conv_in.shape[-1]),
                        conv_in.dtype)
        tail = jnp.concatenate([pad, conv_in], axis=1)[:, -(s.conv_width - 1):]
        return out, MambaCache(tail.astype(jnp.bfloat16), final)
    if return_state:
        return out, final
    return out


def mamba_decode(x: jax.Array, p: dict, cfg: ModelConfig,
                 cache: MambaCache) -> tuple[jax.Array, MambaCache]:
    """One-token decode. x (B,1,D)."""
    s = cfg.ssm
    assert s is not None
    bsz = x.shape[0]
    nh = s.n_heads(cfg.d_model)
    d_in = s.d_inner(cfg.d_model)

    z, xc, bp, cp, dt = _split_proj(x, p, cfg)
    conv_in = jnp.concatenate([xc, bp, cp], axis=-1)     # (B,1,C)
    window = jnp.concatenate([cache.conv, conv_in], axis=1)  # (B,W,C)
    conv_out = jnp.einsum(
        "bwc,cw->bc", window.astype(jnp.float32),
        p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = window[:, 1:, :]

    xs = conv_out[:, :d_in]
    bs_ = conv_out[:, d_in : d_in + s.n_groups * s.d_state]
    cs = conv_out[:, d_in + s.n_groups * s.d_state :]
    xh = xs.reshape(bsz, nh, s.head_dim)
    bh = _broadcast_groups(bs_, nh, s)
    ch = _broadcast_groups(cs, nh, s)
    dt_sp = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                            + p["dt_bias"].astype(jnp.float32))

    y, new_state = ssd_decode_step(xh, dt_sp, p["a_log"], bh, ch, cache.state)
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, :, None].astype(y.dtype)
    y = y.reshape(bsz, 1, d_in)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["gate_norm"], cfg.norm_eps)
    return jnp.dot(y, p["out_proj"]), MambaCache(new_conv, new_state)
