"""Architecture configuration dataclasses.

``ModelConfig`` is the single source of truth consumed by the model
builder, the sharding rules, the data pipeline, and the dry-run launcher.
One instance per assigned architecture lives in ``repro/configs/<id>.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

__all__ = ["MoEConfig", "SSMConfig", "ModelConfig"]


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block parameters (DeepSeek / Jamba style)."""

    n_experts: int                 # routed experts
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    n_shared: int = 0              # always-on shared experts
    first_k_dense: int = 0         # leading dense layers (DeepSeek V2/V3)
    layer_period: int = 1          # MoE every `period` layers (Jamba: 2)
    capacity_factor: float = 1.25  # dispatch buffer slack


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block parameters."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    n_groups: int = 1
    chunk: int = 256               # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """One assigned architecture.

    ``family`` selects the block layout:
      dense  — [attn + mlp] x L
      moe    — [mla-attn + (dense | moe) mlp] x L (first_k_dense leading)
      ssm    — [mamba2] x L
      hybrid — period of ``hybrid_period`` blocks with one attention block
               at position ``hybrid_attn_pos`` and MoE every
               ``moe.layer_period`` blocks (Jamba 1:7)
    """

    name: str
    family: str                    # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int                   # 0 for attention-free
    n_kv_heads: int
    d_ff: int                      # dense FFN hidden (0 for pure-MoE/ssm)
    vocab: int
    head_dim: int = 0              # 0 => d_model // n_heads
    mlp_kind: str = "swiglu"       # swiglu (3-matrix) | gelu | relu2 (2-matrix)
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # attention flavor
    attn_kind: str = "gqa"         # gqa | mla
    # MLA (DeepSeek) dims
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    mla_d_nope: int = 128
    mla_d_rope: int = 64
    mla_d_v: int = 128

    # subfamilies
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    hybrid_period: int = 0         # jamba: 8
    hybrid_attn_pos: int = 0       # attention block index within period

    # modality frontend stub: None | "audio" | "vlm"
    frontend: str | None = None

    # training defaults
    grad_accum: int = 4            # paper Table 1: 4 gradient accumulations
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots | none (no remat)
    moment_dtype: str = "float32"      # Adam m/v ("bfloat16" at 671B scale)
    grad_accum_dtype: str = "float32"  # microbatch accumulator dtype

    # ------------------------------------------------------------------ #
    @property
    def padded_vocab(self) -> int:
        """Embedding-table vocab padded to a multiple of 512 (divisible by
        every mesh axis combination we shard it over — Megatron-style).
        Logit columns >= ``vocab`` are masked to -inf in the forward."""
        return -(-self.vocab // 512) * 512

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads == 0:
            return 0
        return self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """True if decode cost per token is o(seq) in attention state —
        SSM and hybrid families qualify for long_500k."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_moe(self) -> bool:
        return self.moe is not None

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced-config clone for smoke tests."""
        return replace(self, **kw)

    # -- parameter counting (for roofline MODEL_FLOPS = 6 N D) ---------- #
    def param_count(self) -> int:
        """Exact parameter count of the constructed model (all layers)."""
        total = self.vocab * self.d_model            # embed
        if not self.tie_embeddings:
            total += self.d_model * self.vocab       # lm_head
        total += self.d_model                        # final norm
        for kind in self.block_kinds():
            total += self._block_params(kind)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        total = self.vocab * self.d_model
        if not self.tie_embeddings:
            total += self.d_model * self.vocab
        total += self.d_model
        for kind in self.block_kinds():
            total += self._block_params(kind, active_only=True)
        return total

    def block_kinds(self) -> list[str]:
        """Per-layer block kind sequence, length ``n_layers``.

        Kinds: ``attn_dense``, ``attn_moe``, ``mamba_dense``, ``mamba_moe``,
        ``mamba`` (no mlp), ``attn`` (no mlp).
        """
        kinds = []
        for i in range(self.n_layers):
            if self.family == "dense":
                kinds.append("attn_dense")
            elif self.family == "moe":
                assert self.moe is not None
                if i < self.moe.first_k_dense:
                    kinds.append("attn_dense")
                else:
                    kinds.append("attn_moe")
            elif self.family == "ssm":
                kinds.append("mamba")
            elif self.family == "hybrid":
                assert self.moe is not None and self.hybrid_period > 0
                mixer = "attn" if i % self.hybrid_period == self.hybrid_attn_pos else "mamba"
                mlp = "moe" if i % self.moe.layer_period == self.moe.layer_period - 1 else "dense"
                kinds.append(f"{mixer}_{mlp}")
            else:
                raise ValueError(f"unknown family {self.family!r}")
        return kinds

    def _attn_params(self) -> int:
        d = self.d_model
        if self.attn_kind == "mla":
            h = self.n_heads
            dn, dr, dv = self.mla_d_nope, self.mla_d_rope, self.mla_d_v
            p = 0
            if self.q_lora_rank:
                p += d * self.q_lora_rank + self.q_lora_rank  # wq_a + norm
                p += self.q_lora_rank * h * (dn + dr)
            else:
                p += d * h * (dn + dr)
            p += d * (self.kv_lora_rank + dr) + self.kv_lora_rank  # wkv_a + norm
            p += self.kv_lora_rank * h * dn                   # wk_b
            p += self.kv_lora_rank * h * dv                   # wv_b
            p += h * dv * d                                   # wo
            return p
        dh = self.resolved_head_dim
        p = d * self.n_heads * dh + d * 2 * self.n_kv_heads * dh
        p += self.n_heads * dh * d
        if self.qkv_bias:
            p += (self.n_heads + 2 * self.n_kv_heads) * dh
        return p

    def _mamba_params(self) -> int:
        assert self.ssm is not None
        s, d = self.ssm, self.d_model
        d_in = s.d_inner(d)
        nh = s.n_heads(d)
        conv_dim = d_in + 2 * s.n_groups * s.d_state
        p = d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)  # in_proj
        p += conv_dim * s.conv_width + conv_dim               # conv w + b
        p += 3 * nh                                           # A_log, D, dt_bias
        p += d_in                                             # gated norm
        p += d_in * d                                         # out_proj
        return p

    def _mlp_params(self, moe: bool, active_only: bool = False) -> int:
        d = self.d_model
        if not moe:
            n_mats = 3 if self.mlp_kind == "swiglu" else 2
            return n_mats * d * self.d_ff
        assert self.moe is not None
        m = self.moe
        per_expert = 3 * d * m.d_expert
        n_routed = m.top_k if active_only else m.n_experts
        return d * m.n_experts + n_routed * per_expert + m.n_shared * per_expert

    def _block_params(self, kind: str, active_only: bool = False) -> int:
        p = 0
        mixer, _, mlp = kind.partition("_")
        if mixer == "attn":
            p += self._attn_params() + self.d_model  # + ln
        elif mixer == "mamba":
            p += self._mamba_params() + self.d_model
        if mlp == "dense":
            p += self._mlp_params(False) + self.d_model
        elif mlp == "moe":
            p += self._mlp_params(True, active_only) + self.d_model
        return p
