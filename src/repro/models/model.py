"""Model builder: config -> (init, loss, decode) pure functions.

Layer layout is expressed as *segments*: ``(pattern, n_rep)`` where
``pattern`` is a tuple of block kinds executed in order and the segment
repeats ``n_rep`` times under one ``lax.scan`` (per-kind parameter stacks
carry the leading ``n_rep`` axis). This keeps compile time independent of
depth while representing every assigned family:

  dense / ssm            [(single-kind,), L]
  deepseek moe           [(attn_dense,), k] + [(attn_moe,), L-k]
  jamba hybrid           [(attn_moe, mamba_dense, mamba_moe, ...), L/8]

Decode threads per-layer caches through the same scans (cache stacks are
the scanned xs/ys; the hidden state is the carry).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import ACT_DTYPE, cross_entropy, embed_lookup, init_linear, rmsnorm

__all__ = ["Model", "build_model", "segments_of"]


def segments_of(cfg: ModelConfig) -> list[tuple[tuple[str, ...], int]]:
    """Compress cfg.block_kinds() into scan segments."""
    kinds = cfg.block_kinds()
    if cfg.family == "hybrid":
        p = cfg.hybrid_period
        assert cfg.n_layers % p == 0, "hybrid depth must be divisible by period"
        pattern = tuple(kinds[:p])
        assert kinds == list(pattern) * (cfg.n_layers // p)
        return [(pattern, cfg.n_layers // p)]
    # maximal runs of equal kind
    segs: list[tuple[tuple[str, ...], int]] = []
    i = 0
    while i < len(kinds):
        j = i
        while j < len(kinds) and kinds[j] == kinds[i]:
            j += 1
        segs.append(((kinds[i],), j - i))
        i = j
    return segs


# ------------------------------------------------------------------ #
# block init                                                          #
# ------------------------------------------------------------------ #
def _init_attn(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    if cfg.attn_kind == "mla":
        h, dn, dr, dv = cfg.n_heads, cfg.mla_d_nope, cfg.mla_d_rope, cfg.mla_d_v
        p: dict = {
            "wkv_a": init_linear(ks[2], (d, cfg.kv_lora_rank + dr)),
            "kv_norm": jnp.ones((cfg.kv_lora_rank,), jnp.float32),
            "wk_b": init_linear(ks[3], (cfg.kv_lora_rank, h * dn)),
            "wv_b": init_linear(ks[4], (cfg.kv_lora_rank, h * dv)),
            "wo": init_linear(ks[5], (h * dv, d)),
        }
        if cfg.q_lora_rank:
            p["wq_a"] = init_linear(ks[0], (d, cfg.q_lora_rank))
            p["q_norm"] = jnp.ones((cfg.q_lora_rank,), jnp.float32)
            p["wq_b"] = init_linear(ks[1], (cfg.q_lora_rank, h * (dn + dr)))
        else:
            p["wq"] = init_linear(ks[0], (d, h * (dn + dr)))
        return p
    dh = cfg.resolved_head_dim
    p = {
        "wq": init_linear(ks[0], (d, cfg.n_heads * dh)),
        "wk": init_linear(ks[1], (d, cfg.n_kv_heads * dh)),
        "wv": init_linear(ks[2], (d, cfg.n_kv_heads * dh)),
        "wo": init_linear(ks[3], (cfg.n_heads * dh, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * dh,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * dh,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * dh,), jnp.float32)
    return p


def _init_mlp(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind != "swiglu":
        return {
            "w_in": init_linear(ks[0], (d, f)),
            "w_out": init_linear(ks[1], (f, d)),
        }
    return {
        "w_gate": init_linear(ks[0], (d, f)),
        "w_up": init_linear(ks[1], (d, f)),
        "w_down": init_linear(ks[2], (f, d)),
    }


def _init_moe(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    assert m is not None
    d, fe = cfg.d_model, m.d_expert
    ks = jax.random.split(key, 7)
    p = {
        "router": init_linear(ks[0], (d, m.n_experts), dtype=jnp.float32),
        "experts": {
            "w_gate": init_linear(ks[1], (m.n_experts, d, fe)),
            "w_up": init_linear(ks[2], (m.n_experts, d, fe)),
            "w_down": init_linear(ks[3], (m.n_experts, fe, d)),
        },
    }
    if m.n_shared:
        fs = m.n_shared * fe
        p["shared"] = {
            "w_gate": init_linear(ks[4], (d, fs)),
            "w_up": init_linear(ks[5], (d, fs)),
            "w_down": init_linear(ks[6], (fs, d)),
        }
    return p


def _init_mamba(key, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    assert s is not None
    d = cfg.d_model
    d_in = s.d_inner(d)
    nh = s.n_heads(d)
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 7)
    return {
        "wz": init_linear(ks[0], (d, d_in)),
        "wx": init_linear(ks[1], (d, d_in)),
        "wb": init_linear(ks[2], (d, s.n_groups * s.d_state)),
        "wc": init_linear(ks[3], (d, s.n_groups * s.d_state)),
        "wdt": init_linear(ks[4], (d, nh)),
        "conv_w": init_linear(ks[5], (conv_dim, s.conv_width),
                              scale=s.conv_width ** -0.5),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "gate_norm": jnp.ones((d_in,), jnp.float32),
        "out_proj": init_linear(ks[6], (d_in, d)),
    }


def _init_block(key, kind: str, cfg: ModelConfig) -> dict:
    mixer, _, mlp = kind.partition("_")
    k1, k2 = jax.random.split(key)
    p: dict = {"ln1": jnp.ones((cfg.d_model,), jnp.float32)}
    if mixer == "attn":
        p["attn"] = _init_attn(k1, cfg)
    else:
        p["mamba"] = _init_mamba(k1, cfg)
    if mlp:
        p["ln2"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["mlp" if mlp == "dense" else "moe"] = (
            _init_mlp(k2, cfg) if mlp == "dense" else _init_moe(k2, cfg))
    return p


# ------------------------------------------------------------------ #
# block apply                                                         #
# ------------------------------------------------------------------ #
@dataclass
class Model:
    """Bundle of pure functions for one architecture.

    ``mesh``/``dp_axes`` drive the expert-parallel MoE path and the
    activation sharding constraints; None falls back to the single-device
    reference behavior (tests, smoke configs).
    """

    cfg: ModelConfig
    mesh: jax.sharding.Mesh | None = None
    dp_axes: tuple[str, ...] = ("data",)
    ep_axis: str = "model"
    attn_chunk: int = 1024

    # ---------------- sharding constraints ---------------- #
    def _batch_axes(self, batch: int):
        if self.mesh is None:
            return None
        size = 1
        for a in self.dp_axes:
            size *= self.mesh.shape[a]
        return self.dp_axes if batch % size == 0 else None

    def _constrain(self, x: jax.Array, *tail) -> jax.Array:
        """Pin the batch axis to the data axes (GSPMD otherwise loses it at
        the embedding gather — conflicting 'data' use between table FSDP
        and batch sharding replicates the whole forward; measured 16x
        activation blow-up, see EXPERIMENTS.md §Dry-run)."""
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P
        spec = P(self._batch_axes(x.shape[0]),
                 *(tail if tail else (None,) * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def _mask_pad(self, logits: jax.Array) -> jax.Array:
        """Mask padded vocab columns to -inf (padding exists only so the
        table shards evenly; it must never win a softmax)."""
        cfg = self.cfg
        if cfg.padded_vocab == cfg.vocab:
            return logits
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        return jnp.where(col < cfg.vocab, logits,
                         jnp.asarray(-2.0 ** 20, logits.dtype))

    def _pin_layer_grads(self, layer_p):
        """Pin each weight's *gradient* sharding at its production point
        (inside the backward of the layer scan) so GSPMD reduce-scatters
        weight grads to their FSDP shard instead of all-reducing them to
        replicated inside the loop. Identity in the forward pass."""
        if self.mesh is None:
            return layer_p
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.dist.collectives import constrain_grad
        from repro.dist.sharding import _rule

        def pin(path, leaf):
            name = None
            for entry in reversed(path):
                if isinstance(entry, jax.tree_util.DictKey):
                    name = entry.key
                    break
            spec = P(*_rule(name, leaf.ndim, self.dp_axes))
            return constrain_grad(leaf, NamedSharding(self.mesh, spec))

        return jax.tree_util.tree_map_with_path(pin, layer_p)

    # ---------------- init ---------------- #
    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        segs = segments_of(cfg)
        keys = jax.random.split(key, len(segs) + 3)
        params: dict = {
            "embed": init_linear(keys[0], (cfg.padded_vocab, cfg.d_model),
                                 scale=0.02),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = init_linear(keys[1],
                                            (cfg.d_model, cfg.padded_vocab))
        seg_params = []
        for si, (pattern, n_rep) in enumerate(segs):
            def init_one(k):
                kk = jax.random.split(k, len(pattern))
                return tuple(_init_block(kk[i], kind, cfg)
                             for i, kind in enumerate(pattern))
            rep_keys = jax.random.split(keys[2 + si], n_rep)
            stacked = jax.vmap(init_one)(rep_keys)
            seg_params.append(stacked)
        params["segments"] = seg_params
        return params

    # ---------------- blocks ---------------- #
    def _mlp_part(self, x, p, kind):
        _, _, mlp = kind.partition("_")
        if not mlp:
            return x
        h = rmsnorm(x, p["ln2"], self.cfg.norm_eps)
        if mlp == "dense":
            from .layers import mlp2, swiglu
            if self.cfg.mlp_kind != "swiglu":
                return x + mlp2(h, p["mlp"]["w_in"], p["mlp"]["w_out"],
                                kind=self.cfg.mlp_kind)
            return x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                              p["mlp"]["w_down"])
        return x + moe_mod.moe_ffn(h, p["moe"], self.cfg, mesh=self.mesh,
                                   dp_axes=self.dp_axes, ep_axis=self.ep_axis)

    def _block_forward(self, x, p, kind, positions):
        cfg = self.cfg
        mixer = kind.partition("_")[0]
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        if mixer == "attn":
            if cfg.attn_kind == "mla":
                x = x + attn.mla_forward(h, p["attn"], cfg, positions,
                                         chunk=self.attn_chunk)
            else:
                hc = None
                if self.mesh is not None:
                    hc = lambda t: self._constrain(t, None, "model", None)
                x = x + attn.gqa_forward(h, p["attn"], cfg, positions,
                                         chunk=self.attn_chunk,
                                         head_constrain=hc)
        else:
            x = x + ssm_mod.mamba_forward(h, p["mamba"], cfg)
        return self._mlp_part(x, p, kind)

    def _block_decode(self, x, p, kind, cache, pos):
        cfg = self.cfg
        mixer = kind.partition("_")[0]
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        if mixer == "attn":
            dec = attn.mla_decode if cfg.attn_kind == "mla" else attn.gqa_decode
            y, cache = dec(h, p["attn"], cfg, cache, pos)
            x = x + y
        else:
            y, cache = ssm_mod.mamba_decode(h, p["mamba"], cfg, cache)
            x = x + y
        return self._mlp_part(x, p, kind), cache

    def _block_prefill(self, x, p, kind, positions):
        """Forward one block AND capture its decode cache (fused prefill)."""
        cfg = self.cfg
        mixer = kind.partition("_")[0]
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        if mixer == "attn":
            if cfg.attn_kind == "mla":
                y, cache = attn.mla_forward(h, p["attn"], cfg, positions,
                                            chunk=self.attn_chunk,
                                            return_kv=True)
            else:
                hc = None
                if self.mesh is not None:
                    hc = lambda t: self._constrain(t, None, "model", None)
                y, cache = attn.gqa_forward(h, p["attn"], cfg, positions,
                                            chunk=self.attn_chunk,
                                            head_constrain=hc, return_kv=True)
        else:
            y, cache = ssm_mod.mamba_forward(h, p["mamba"], cfg,
                                             return_cache=True)
        return self._mlp_part(x + y, p, kind), cache

    def _block_decode_paged(self, x, p, kind, cache, table, pos):
        cfg = self.cfg
        mixer = kind.partition("_")[0]
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        if mixer == "attn":
            dec = (attn.mla_decode_paged if cfg.attn_kind == "mla"
                   else attn.gqa_decode_paged)
            y, cache = dec(h, p["attn"], cfg, cache, table, pos)
        else:
            # SSD state is O(1) per sequence — the slot IS the page; the
            # dense decode path already advances every row independently
            y, cache = ssm_mod.mamba_decode(h, p["mamba"], cfg, cache)
        return self._mlp_part(x + y, p, kind), cache

    # ---------------- forward / loss ---------------- #
    def forward(self, params: dict, tokens: jax.Array | None = None,
                embeds: jax.Array | None = None) -> jax.Array:
        """Training forward. Returns logits (B, S, V)."""
        cfg = self.cfg
        if embeds is not None:
            x = embeds.astype(ACT_DTYPE)
        else:
            assert tokens is not None
            x = embed_lookup(params["embed"], tokens)
        x = self._constrain(x)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        for (pattern, n_rep), seg in zip(segments_of(cfg), params["segments"]):
            def body(xc, layer_p):
                layer_p = self._pin_layer_grads(layer_p)
                for kind, bp in zip(pattern, layer_p):
                    xc = self._block_forward(xc, bp, kind, positions)
                return self._constrain(xc), None
            if cfg.remat and cfg.remat_policy != "none":
                policy = {
                    "nothing": jax.checkpoint_policies.nothing_saveable,
                    # keep matmul outputs; recompute only cheap elementwise
                    "dots": jax.checkpoint_policies.
                    dots_with_no_batch_dims_saveable,
                }[cfg.remat_policy]
                body = jax.checkpoint(body, policy=policy)
            x, _ = jax.lax.scan(body, x, seg)

        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = self._mask_pad(jnp.dot(x, head))
        return self._constrain(logits, None, "model")

    def loss(self, params: dict, batch: dict) -> jax.Array:
        logits = self.forward(params, tokens=batch.get("tokens"),
                              embeds=batch.get("embeds"))
        return cross_entropy(logits, batch["labels"])

    # ---------------- prefill ---------------- #
    def prefill(self, params: dict, tokens: jax.Array | None = None,
                embeds: jax.Array | None = None) -> tuple[jax.Array, list]:
        """Fused cache-filling prefill.

        Runs the full forward once and returns ``(logits (B, S, V),
        state)`` where ``state`` matches :meth:`init_decode_state`
        (batch=B, s_max=S) leaf for leaf — the per-layer caches are
        byproducts of the forward (post-rope k/v, compressed MLA rows,
        conv tails + final SSD states), so prefill costs one forward, not
        S decode steps. Feed *exact-length* prompts: the SSD recurrence
        runs through every input token, so right-padding corrupts the
        state (the serve engine jits one executable per prompt-length
        bucket for this reason).
        """
        cfg = self.cfg
        if embeds is not None:
            x = embeds.astype(ACT_DTYPE)
        else:
            assert tokens is not None
            x = embed_lookup(params["embed"], tokens)
        x = self._constrain(x)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        states = []
        for (pattern, n_rep), seg in zip(segments_of(cfg), params["segments"]):
            def body(xc, layer_p):
                caches = []
                for kind, bp in zip(pattern, layer_p):
                    xc, c = self._block_prefill(xc, bp, kind, positions)
                    caches.append(c)
                return self._constrain(xc), tuple(caches)
            # scan ys stack the per-layer caches with a leading n_rep axis
            # — exactly the init_decode_state layout
            x, seg_cache = jax.lax.scan(body, x, seg)
            states.append(seg_cache)

        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = self._mask_pad(jnp.dot(x, head))
        return self._constrain(logits, None, "model"), states

    # ---------------- decode ---------------- #
    def init_decode_state(self, batch: int, s_max: int) -> list:
        """Per-segment stacked caches (leading axis n_rep)."""
        cfg = self.cfg
        states = []
        for pattern, n_rep in segments_of(cfg):
            per_pos = []
            for kind in pattern:
                mixer = kind.partition("_")[0]
                if mixer == "attn":
                    c = (attn.init_mla_cache(cfg, batch, s_max)
                         if cfg.attn_kind == "mla"
                         else attn.init_gqa_cache(cfg, batch, s_max))
                else:
                    c = ssm_mod.init_mamba_cache(cfg, batch)
                per_pos.append(jax.tree.map(
                    lambda t: jnp.broadcast_to(t[None], (n_rep, *t.shape)), c))
            states.append(tuple(per_pos))
        return states

    def decode_step(self, params: dict, state: list, pos: jax.Array,
                    tokens: jax.Array | None = None,
                    embeds: jax.Array | None = None
                    ) -> tuple[jax.Array, list]:
        """One-token step. tokens (B, 1) or embeds (B, 1, D); pos () int32.
        Returns (logits (B, 1, V), new state)."""
        cfg = self.cfg
        if embeds is not None:
            x = embeds.astype(ACT_DTYPE)
        else:
            assert tokens is not None
            x = embed_lookup(params["embed"], tokens)
        x = self._constrain(x)

        new_states = []
        for (pattern, n_rep), seg, seg_cache in zip(
                segments_of(cfg), params["segments"], state):
            def body(xc, inp):
                layer_p, layer_c = inp
                new_c = []
                for kind, bp, c in zip(pattern, layer_p, layer_c):
                    xc, nc = self._block_decode(xc, bp, kind, c, pos)
                    new_c.append(nc)
                return xc, tuple(new_c)
            x, new_cache = jax.lax.scan(body, x, (seg, seg_cache))
            new_states.append(new_cache)

        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        return self._mask_pad(jnp.dot(x, head)), new_states

    # ---------------- paged decode ---------------- #
    def init_paged_state(self, n_slots: int, n_pages: int,
                         page_size: int) -> list:
        """Paged decode state: per-layer physical page pools.

        Attention caches become page pools ``(n_rep, n_pages, PS, ...)``
        shared by all decode slots; Mamba caches stay slot-dense
        ``(n_rep, n_slots, ...)`` because SSD state is O(1) per sequence
        (the slot is the page). One ``(n_slots, max_pages)`` int32 block
        table — managed host-side by ``repro.serve.kvcache`` — addresses
        every layer's pools identically; page 0 is the trash page.
        """
        cfg = self.cfg
        states = []
        for pattern, n_rep in segments_of(cfg):
            per_pos = []
            for kind in pattern:
                mixer = kind.partition("_")[0]
                if mixer == "attn":
                    c = (attn.init_mla_pool(cfg, n_pages, page_size)
                         if cfg.attn_kind == "mla"
                         else attn.init_gqa_pool(cfg, n_pages, page_size))
                else:
                    c = ssm_mod.init_mamba_cache(cfg, n_slots)
                per_pos.append(jax.tree.map(
                    lambda t: jnp.broadcast_to(t[None], (n_rep, *t.shape)), c))
            states.append(tuple(per_pos))
        return states

    def decode_step_paged(self, params: dict, state: list,
                          table: jax.Array, pos: jax.Array,
                          tokens: jax.Array | None = None,
                          embeds: jax.Array | None = None
                          ) -> tuple[jax.Array, list]:
        """One-token step over paged pools, per-row positions.

        tokens (B, 1) or embeds (B, 1, D); table (B, max_pages) int32
        physical page ids; pos (B,) int32 — row b generates token
        ``pos[b]``. B is the fixed decode-slot count: admission and
        eviction change only table/pos *data*, never this program, which
        is what keeps continuous batching recompile-free.
        """
        cfg = self.cfg
        if embeds is not None:
            x = embeds.astype(ACT_DTYPE)
        else:
            assert tokens is not None
            x = embed_lookup(params["embed"], tokens)
        x = self._constrain(x)

        new_states = []
        for (pattern, n_rep), seg, seg_cache in zip(
                segments_of(cfg), params["segments"], state):
            def body(xc, inp):
                layer_p, layer_c = inp
                new_c = []
                for kind, bp, c in zip(pattern, layer_p, layer_c):
                    xc, nc = self._block_decode_paged(
                        xc, bp, kind, c, table, pos)
                    new_c.append(nc)
                return xc, tuple(new_c)
            x, new_cache = jax.lax.scan(body, x, (seg, seg_cache))
            new_states.append(new_cache)

        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        return self._mask_pad(jnp.dot(x, head)), new_states


def build_model(cfg: ModelConfig, mesh=None, dp_axes=("data",),
                attn_chunk: int = 1024) -> Model:
    return Model(cfg=cfg, mesh=mesh, dp_axes=tuple(dp_axes),
                 attn_chunk=attn_chunk)
