"""Mixture-of-Experts FFN (DeepSeek V2/V3 + Jamba style).

TPU-native realization of expert parallelism under a fixed
(``pod``, ``data``, ``model``) mesh:

* routed experts are sharded over the ``model`` axis (EP);
* activations stay batch-sharded over the data axes and *replicated*
  over ``model`` (exactly the layout Megatron-style TP leaves them in);
* every model-rank routes the token block it already holds to its local
  experts through a **static-capacity sort-free dispatch** (cumsum
  position + scatter), computes the grouped GEMMs, and the partial
  outputs combine with one ``psum`` over ``model`` — the same collective
  the TP MLP would have issued, so EP costs no extra collective phase;
* shared experts are plain TP (ffn hidden sharded over ``model``) and
  ride the same psum.

This avoids GShard's (T, E, C) one-hot dispatch einsums entirely — those
cost O(T*E*C*d) MACs and at DeepSeek-V3 scale (E=256) would rival the
expert GEMMs themselves (we measured this; see EXPERIMENTS.md §Perf).

Two entry points:
  * :func:`moe_ffn_reference` — dense-dispatch oracle (tiny configs/tests);
  * :func:`moe_ffn` — the production path (requires mesh axes in scope via
    shard_map; falls back to the reference when no mesh is active).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.collectives import shard_map_compat

from .config import ModelConfig, MoEConfig

__all__ = ["route_topk", "moe_ffn_reference", "moe_ffn", "expert_ffn_local"]


def route_topk(x_flat: jax.Array, router_w: jax.Array, top_k: int):
    """Router: top-k softmax gating with renormalized weights.

    x_flat: (T, D); router_w: (D, E). Returns (idx (T,k) int32, w (T,k)).
    Router math in fp32 (routing decisions are precision-sensitive).
    """
    gates = jnp.dot(x_flat.astype(jnp.float32), router_w.astype(jnp.float32))
    top_vals, top_idx = jax.lax.top_k(gates, top_k)
    top_w = jax.nn.softmax(top_vals, axis=-1)
    return top_idx, top_w


def _swiglu_expert(h_in, w_gate, w_up, w_down):
    g = jnp.einsum("ecd,edf->ecf", h_in, w_gate)
    u = jnp.einsum("ecd,edf->ecf", h_in, w_up)
    h = jax.nn.silu(g) * u          # bf16 activation math (§Perf iter 5)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def expert_ffn_local(x_flat: jax.Array, top_idx: jax.Array, top_w: jax.Array,
                     experts: dict, e_first: int, e_local: int,
                     capacity: int) -> jax.Array:
    """Dispatch a token block to ``e_local`` local experts and combine.

    Static-shape scatter dispatch: each (token, k-slot) routed to a local
    expert gets a position inside that expert's capacity buffer via a
    cumulative count; overflow slots are dropped (capacity_factor slack
    keeps drops rare — matches Switch/GShard semantics).

    x_flat (T, D); experts' leaves (E_local, D, F). Returns the *partial*
    combine (T, D): contributions of local experts only (psum upstream).
    """
    t, d = x_flat.shape
    k = top_idx.shape[1]
    local = (top_idx >= e_first) & (top_idx < e_first + e_local)
    eid = jnp.where(local, top_idx - e_first, 0)            # (T, k)

    flat_eid = eid.reshape(-1)                              # (T*k,)
    flat_local = local.reshape(-1)
    flat_w = top_w.reshape(-1)
    token_of = jnp.repeat(jnp.arange(t), k)

    # position of each slot within its expert's buffer: running count
    onehot = (jax.nn.one_hot(flat_eid, e_local, dtype=jnp.int32)
              * flat_local[:, None].astype(jnp.int32))      # (T*k, E_loc)
    pos = jnp.cumsum(onehot, axis=0) - onehot               # exclusive
    slot_pos = jnp.sum(pos * onehot, axis=1)                # (T*k,)
    keep = flat_local & (slot_pos < capacity)

    dump = e_local * capacity                               # overflow row
    dest = jnp.where(keep, flat_eid * capacity + slot_pos, dump)

    buf = jnp.zeros((e_local * capacity + 1, d), x_flat.dtype)
    buf = buf.at[dest].set(x_flat[token_of])
    h = buf[:-1].reshape(e_local, capacity, d)

    y = _swiglu_expert(h, experts["w_gate"], experts["w_up"], experts["w_down"])
    y_flat = y.reshape(e_local * capacity, d)

    gathered = jnp.where(keep[:, None], y_flat[jnp.minimum(dest, dump - 1)], 0.0)
    combined = jnp.zeros((t, d), x_flat.dtype)
    combined = combined.at[token_of].add(
        gathered * flat_w[:, None].astype(x_flat.dtype))
    return combined


def moe_ffn_reference(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """Dense-dispatch oracle: every expert computed for every token, masked
    combine. O(T * E * d * f) — only for tiny test configs."""
    moe = cfg.moe
    assert moe is not None
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    top_idx, top_w = route_topk(x_flat, p["router"], moe.top_k)
    ex = p["experts"]
    # (E, T, F) for all experts
    g = jnp.einsum("td,edf->etf", x_flat, ex["w_gate"])
    u = jnp.einsum("td,edf->etf", x_flat, ex["w_up"])
    h = jax.nn.silu(g) * u
    y_all = jnp.einsum("etf,efd->etd", h, ex["w_down"])     # (E, T, D)
    combine = jnp.zeros((x_flat.shape[0], moe.n_experts), jnp.float32)
    combine = combine.at[jnp.arange(x_flat.shape[0])[:, None], top_idx].add(top_w)
    y = jnp.einsum("te,etd->td", combine.astype(x.dtype), y_all)
    y = y + _shared_ffn(x_flat, p)
    return y.reshape(b, s, d)


def _shared_ffn(x_flat: jax.Array, p: dict) -> jax.Array:
    if "shared" not in p:
        return jnp.zeros_like(x_flat)
    sh = p["shared"]
    g = jnp.dot(x_flat, sh["w_gate"])
    u = jnp.dot(x_flat, sh["w_up"])
    h = jax.nn.silu(g) * u
    return jnp.dot(h, sh["w_down"])


def moe_ffn(x: jax.Array, p: dict, cfg: ModelConfig,
            mesh: jax.sharding.Mesh | None = None,
            dp_axes: tuple[str, ...] = ("data",),
            ep_axis: str = "model") -> jax.Array:
    """Production MoE FFN. x: (B, S, D) batch-sharded over ``dp_axes`` and
    replicated over ``ep_axis``; routed experts sharded over ``ep_axis``.

    Without a mesh (unit tests, smoke configs) falls back to the dense
    reference — bitwise-comparable up to capacity drops.
    """
    if mesh is None or ep_axis not in mesh.axis_names:
        return moe_ffn_reference(x, p, cfg)

    moe = cfg.moe
    assert moe is not None
    ep = mesh.shape[ep_axis]
    assert moe.n_experts % ep == 0, (
        f"{moe.n_experts} experts not divisible by EP degree {ep}")
    e_local = moe.n_experts // ep

    # batch-shard over dp when divisible (train/prefill/decode batches);
    # replicate for tiny serve batches (long_500k: B=1)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    batch_spec = tuple(dp_axes) if x.shape[0] % dp_size == 0 else None

    def body(xb, router_w, experts, shared):
        # xb: (B_loc, S, D) — replicated over ep_axis by in_spec
        b, s, d = xb.shape
        x_flat = xb.reshape(-1, d)
        t = x_flat.shape[0]
        capacity = max(8, int(moe.capacity_factor * t * moe.top_k
                              / moe.n_experts))
        top_idx, top_w = route_topk(x_flat, router_w, moe.top_k)
        rank = jax.lax.axis_index(ep_axis)
        y = expert_ffn_local(x_flat, top_idx, top_w, experts,
                             rank * e_local, e_local, capacity)
        if shared is not None:
            # shared experts are TP-sharded on hidden: partial contribution
            y = y + _shared_ffn(x_flat, {"shared": shared})
        y = jax.lax.psum(y, ep_axis)
        return y.reshape(b, s, d)

    shared = p.get("shared")
    x_spec = P(batch_spec, None, None)
    expert_specs = {k: P(ep_axis, None, None) for k in p["experts"]}
    args = [x, p["router"], p["experts"]]
    in_specs = [x_spec, P(None, None), expert_specs]
    if shared is not None:
        # shared experts: TP on the ffn hidden dim — w_down contracts over it
        in_specs.append({"w_gate": P(None, ep_axis), "w_up": P(None, ep_axis),
                         "w_down": P(ep_axis, None)})
        args.append(shared)
        fn = shard_map_compat(
            lambda a, b, c, dsh: body(a, b, c, dsh), mesh=mesh,
            in_specs=tuple(in_specs), out_specs=x_spec)
    else:
        fn = shard_map_compat(
            lambda a, b, c: body(a, b, c, None), mesh=mesh,
            in_specs=tuple(in_specs), out_specs=x_spec)
    return fn(*args)
