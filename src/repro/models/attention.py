"""Attention: GQA (chunked-causal flash-style reference) and DeepSeek MLA.

Train path uses a query-chunked implementation (O(S * chunk) score memory
instead of O(S^2)) written so the XLA scheduler sees plain einsums — the
Pallas flash kernel in ``repro/kernels/flash_attention.py`` implements the
same contract for the TPU hot path and is validated against
:func:`attend_chunked` (its pure-jnp oracle lives in ``kernels/ref.py``).

Decode path scores one query against a (possibly sequence-sharded) KV
cache; softmax over the sharded key axis lowers to all-reduce(max)/(sum) —
the TPU analogue of split-KV flash-decode.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, rmsnorm

__all__ = [
    "attend_chunked", "gqa_forward", "gqa_decode", "mla_forward",
    "mla_decode", "KVCache", "MLACache", "init_gqa_cache", "init_mla_cache",
    "init_gqa_pool", "init_mla_pool", "paged_view", "gqa_decode_paged",
    "mla_decode_paged",
]

_NEG_INF = -2.0 ** 20  # large-but-finite: keeps bf16/softmax NaN-free


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, KV, dh) -> (B, S, KV*n_rep, dh) by head repetition."""
    if n_rep == 1:
        return k
    b, s, kv, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, dh)
                            ).reshape(b, s, kv * n_rep, dh)


def attend_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                   chunk: int = 512, causal: bool = True) -> jax.Array:
    """Causal attention with query chunking.

    q: (B, S, H, dh); k, v: (B, S, H, dh)  (already GQA-expanded).
    Returns (B, S, H, dh). Scores for one chunk are (B, H, C, S) — the
    working set stays O(S*C) per head, which is what makes the 32k-prefill
    shapes compile inside a 16 GB HBM budget without a custom kernel.
    """
    b, s, h, dh = q.shape
    scale = dh ** -0.5
    chunk = min(chunk, s)
    n_chunks = s // chunk
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"

    kT = k.transpose(0, 2, 3, 1)         # (B, H, dh, S)
    vT = v.transpose(0, 2, 1, 3)         # (B, H, S, dh)
    q_chunks = q.reshape(b, n_chunks, chunk, h, dh).transpose(1, 0, 3, 2, 4)

    kpos = jnp.arange(s)

    def one_chunk(ci, qc):
        # qc: (B, H, C, dh)
        scores = jnp.einsum("bhcd,bhdk->bhck", qc, kT) * scale
        scores = scores.astype(jnp.float32)
        if causal:
            qpos = ci * chunk + jnp.arange(chunk)
            mask = qpos[:, None] >= kpos[None, :]
            scores = jnp.where(mask[None, None], scores, _NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bhck,bhkd->bhcd", probs, vT)

    out = jax.lax.map(lambda args: one_chunk(*args),
                      (jnp.arange(n_chunks), q_chunks))
    # (n_chunks, B, H, C, dh) -> (B, S, H, dh)
    return out.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dh)


# ------------------------------------------------------------------ #
# GQA                                                                 #
# ------------------------------------------------------------------ #
class KVCache(NamedTuple):
    k: jax.Array      # (B, S_max, KV, dh)
    v: jax.Array      # (B, S_max, KV, dh)


def init_gqa_cache(cfg: ModelConfig, batch: int, s_max: int,
                   dtype=jnp.bfloat16) -> KVCache:
    dh = cfg.resolved_head_dim
    shape = (batch, s_max, cfg.n_kv_heads, dh)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def _qkv(x, p, cfg: ModelConfig):
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    q = jnp.dot(x, p["wq"])
    k = jnp.dot(x, p["wk"])
    v = jnp.dot(x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(b, s, cfg.n_heads, dh)
    k = k.reshape(b, s, cfg.n_kv_heads, dh)
    v = v.reshape(b, s, cfg.n_kv_heads, dh)
    return q, k, v


def gqa_forward(x: jax.Array, p: dict, cfg: ModelConfig,
                positions: jax.Array | None = None,
                chunk: int = 512, head_constrain=None,
                return_kv: bool = False):
    """Full-sequence causal GQA. x: (B, S, D) -> (B, S, D).

    ``head_constrain`` pins (B, S, H, dh) tensors to head-sharding over
    the model axis (implicitly padded for H % TP != 0). Without it GSPMD
    may shard the *contraction* (head_dim) for awkward head counts and
    all-reduce the full (S x S) score tensors — measured 4.6 TB/step of
    avoidable all-reduce on starcoder2-7b (36 heads over TP=16); see
    EXPERIMENTS.md §Perf.

    ``return_kv`` additionally returns the decode-cache contents — the
    post-rope, pre-repeat ``KVCache(k, v)`` of shape (B, S, KV, dh) —
    which is the fused cache-filling prefill: the k/v are the exact
    tensors :func:`gqa_decode` would have written token by token, at
    zero extra compute (they are byproducts of the forward).
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q, k, v = _qkv(x, p, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    cache = KVCache(k, v) if return_kv else None
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    if head_constrain is not None:
        q, k, v = head_constrain(q), head_constrain(k), head_constrain(v)
    out = attend_chunked(q, k, v, chunk=chunk)
    if head_constrain is not None:
        out = head_constrain(out)
    y = jnp.dot(out.reshape(b, s, -1), p["wo"])
    if return_kv:
        return y, cache
    return y


def init_gqa_pool(cfg: ModelConfig, n_pages: int, page_size: int,
                  dtype=jnp.bfloat16) -> KVCache:
    """Physical page pool for paged decode: (n_pages, PS, KV, dh) leaves.

    Page 0 is reserved as the *trash page*: inactive decode slots carry an
    all-zero block table and pos 0, so their per-step scatter lands there
    and their gather reads it — garbage in, garbage out, fully masked.
    The allocator must never hand out page 0.
    """
    dh = cfg.resolved_head_dim
    shape = (n_pages, page_size, cfg.n_kv_heads, dh)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def init_mla_pool(cfg: ModelConfig, n_pages: int, page_size: int,
                  dtype=jnp.bfloat16) -> MLACache:
    """Physical page pool for paged MLA decode (compressed-latent rows)."""
    return MLACache(
        jnp.zeros((n_pages, page_size, cfg.kv_lora_rank), dtype),
        jnp.zeros((n_pages, page_size, cfg.mla_d_rope), dtype),
    )


def paged_view(pool: jax.Array, table: jax.Array) -> jax.Array:
    """Gather a logical per-sequence cache view from the physical pool.

    pool: (n_pages, PS, *tail); table: (B, M) int32 page ids.
    Returns (B, M*PS, *tail) — the contiguous cache each row *thinks* it
    has. Rows past ``pos`` hold stale/trash data; callers mask them, and
    softmax's exp underflows the _NEG_INF scores to exactly 0.0, so stale
    pages are unreachable rather than merely unlikely.
    """
    b, m = table.shape
    g = jnp.take(pool, table.reshape(-1), axis=0)
    return g.reshape(b, m * pool.shape[1], *pool.shape[2:])


def _paged_write(pool: jax.Array, new: jax.Array, table: jax.Array,
                 pos: jax.Array) -> jax.Array:
    """Scatter one new token row per sequence into its current page.

    new: (B, *tail) — token ``pos[b]`` of row b. Distinct live sequences
    own distinct pages so the scatter indices never collide except on the
    trash page (0, 0), where last-write-wins is fine by construction.
    """
    ps = pool.shape[1]
    page = jnp.take_along_axis(table, (pos // ps)[:, None], axis=1)[:, 0]
    return pool.at[page, pos % ps].set(new)


def gqa_decode(x: jax.Array, p: dict, cfg: ModelConfig, cache: KVCache,
               pos: jax.Array) -> tuple[jax.Array, KVCache]:
    """One-token decode. x: (B, 1, D); pos: () int32 — current position.

    The cache key axis may be sharded ('model'); the masked softmax
    reduction then lowers to the split-KV pattern (all-reduce max / sum).
    """
    b = x.shape[0]
    dh = cfg.resolved_head_dim
    q, k_new, v_new = _qkv(x, p, cfg)
    posb = jnp.broadcast_to(pos[None], (b, 1)) if pos.ndim == 0 else pos
    q = apply_rope(q, posb, cfg.rope_theta)
    k_new = apply_rope(k_new, posb, cfg.rope_theta)

    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, pos, axis=1)

    n_rep = cfg.n_heads // cfg.n_kv_heads
    kh = _repeat_kv(k, n_rep)           # (B, S_max, H, dh)
    vh = _repeat_kv(v, n_rep)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kh) * dh ** -0.5
    valid = (jnp.arange(k.shape[1]) <= pos)[None, None, None, :]
    scores = jnp.where(valid, scores.astype(jnp.float32), _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vh)
    y = jnp.dot(out.reshape(b, 1, -1), p["wo"])
    return y, KVCache(k, v)


def gqa_decode_paged(x: jax.Array, p: dict, cfg: ModelConfig,
                     pool: KVCache, table: jax.Array,
                     pos: jax.Array) -> tuple[jax.Array, KVCache]:
    """One-token decode against a paged KV pool, per-row positions.

    x: (B, 1, D); pool leaves: (n_pages, PS, KV, dh); table: (B, M)
    physical page ids; pos: (B,) int32 — row b is generating token
    ``pos[b]``. Unlike :func:`gqa_decode` (scalar pos, dense per-row
    cache) every row advances independently, which is what continuous
    batching needs: admissions and evictions only rewrite the block
    table, never the compiled program.
    """
    b = x.shape[0]
    dh = cfg.resolved_head_dim
    q, k_new, v_new = _qkv(x, p, cfg)
    posb = pos[:, None]                             # (B, 1)
    q = apply_rope(q, posb, cfg.rope_theta)
    k_new = apply_rope(k_new, posb, cfg.rope_theta)

    k_pool = _paged_write(pool.k, k_new[:, 0], table, pos)
    v_pool = _paged_write(pool.v, v_new[:, 0], table, pos)

    n_rep = cfg.n_heads // cfg.n_kv_heads
    kh = _repeat_kv(paged_view(k_pool, table), n_rep)   # (B, M*PS, H, dh)
    vh = _repeat_kv(paged_view(v_pool, table), n_rep)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kh) * dh ** -0.5
    valid = (jnp.arange(kh.shape[1])[None] <= pos[:, None])[:, None, None, :]
    scores = jnp.where(valid, scores.astype(jnp.float32), _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vh)
    y = jnp.dot(out.reshape(b, 1, -1), p["wo"])
    return y, KVCache(k_pool, v_pool)


# ------------------------------------------------------------------ #
# MLA (DeepSeek multi-head latent attention)                          #
# ------------------------------------------------------------------ #
class MLACache(NamedTuple):
    """Compressed cache: latent c_kv + shared rope key (the whole point of
    MLA — cache is rank x (kv_lora + d_rope) per token, not heads x dh)."""
    c_kv: jax.Array    # (B, S_max, kv_lora)
    k_rope: jax.Array  # (B, S_max, d_rope)


def init_mla_cache(cfg: ModelConfig, batch: int, s_max: int,
                   dtype=jnp.bfloat16) -> MLACache:
    return MLACache(
        jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
        jnp.zeros((batch, s_max, cfg.mla_d_rope), dtype),
    )


def _mla_q(x, p, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h, dn, dr = cfg.n_heads, cfg.mla_d_nope, cfg.mla_d_rope
    if cfg.q_lora_rank:
        cq = rmsnorm(jnp.dot(x, p["wq_a"]), p["q_norm"], cfg.norm_eps)
        q = jnp.dot(cq, p["wq_b"])
    else:
        q = jnp.dot(x, p["wq"])
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv(x, p, cfg: ModelConfig, positions):
    """Project to the latent + shared rope key (cache contents)."""
    dr = cfg.mla_d_rope
    ckv = jnp.dot(x, p["wkv_a"])                       # (B,S,lora+dr)
    c_kv, k_rope = ckv[..., :cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    c_kv = rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def _mla_attend(q_nope, q_rope, c_kv, k_rope, p, cfg: ModelConfig,
                causal_pos: jax.Array | None):
    """Latent-space attention (the 'absorbed' MLA formulation).

    Scores are computed *in the latent space*: q_nope is absorbed through
    W_uk so the per-token key is just c_kv (rank 512), never the expanded
    (H, dh) keys — this is the TPU-friendly form (one big einsum, small
    cache reads).
    """
    b, s_q = q_nope.shape[:2]
    h, dn, dv = cfg.n_heads, cfg.mla_d_nope, cfg.mla_d_v
    wk = p["wk_b"].reshape(cfg.kv_lora_rank, h, dn)
    wv = p["wv_b"].reshape(cfg.kv_lora_rank, h, dv)
    # absorb: q_lat (B,Sq,H,lora) = q_nope . wk^T
    q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope, wk)
    scores = jnp.einsum("bqhl,bkl->bhqk", q_lat, c_kv)
    scores = scores + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope)
    scores = scores.astype(jnp.float32) * (dn + cfg.mla_d_rope) ** -0.5
    if causal_pos is not None:
        qpos, kpos = causal_pos
        if qpos.ndim == 2:
            # per-row positions (B, Sq) — the paged-decode spelling
            mask = (qpos[:, :, None] >= kpos[None, None, :])[:, None]
        else:
            mask = (qpos[:, None] >= kpos[None, :])[None, None]
        scores = jnp.where(mask, scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q_nope.dtype)
    o_lat = jnp.einsum("bhqk,bkl->bqhl", probs, c_kv)   # latent values
    out = jnp.einsum("bqhl,lhd->bqhd", o_lat, wv)       # expand via W_uv
    return out.reshape(b, s_q, h * dv)


def mla_forward(x: jax.Array, p: dict, cfg: ModelConfig,
                positions: jax.Array | None = None,
                chunk: int = 512, return_kv: bool = False):
    """Full-sequence causal MLA. Query-chunked like the GQA path.

    ``return_kv`` additionally returns ``MLACache(c_kv, k_rope)`` — the
    exact compressed rows :func:`mla_decode` would have cached token by
    token (fused cache-filling prefill, zero extra compute).
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q_nope, q_rope = _mla_q(x, p, cfg, positions)
    c_kv, k_rope = _mla_kv(x, p, cfg, positions)

    chunk = min(chunk, s)
    n_chunks = s // chunk
    assert s % chunk == 0
    kpos = jnp.arange(s)

    def one_chunk(ci):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, ci * chunk, chunk, axis=1)
        qpos = ci * chunk + jnp.arange(chunk)
        return _mla_attend(sl(q_nope), sl(q_rope), c_kv, k_rope, p, cfg,
                           (qpos, kpos))

    out = jax.lax.map(one_chunk, jnp.arange(n_chunks))
    out = out.transpose(1, 0, 2, 3).reshape(b, s, -1)
    y = jnp.dot(out, p["wo"])
    if return_kv:
        return y, MLACache(c_kv, k_rope)
    return y


def mla_decode(x: jax.Array, p: dict, cfg: ModelConfig, cache: MLACache,
               pos: jax.Array) -> tuple[jax.Array, MLACache]:
    """One-token MLA decode against the compressed latent cache."""
    b = x.shape[0]
    posb = jnp.broadcast_to(pos[None], (b, 1))
    q_nope, q_rope = _mla_q(x, p, cfg, posb)
    c_new, kr_new = _mla_kv(x, p, cfg, posb)
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_new, pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, kr_new, pos, axis=1)

    s_max = c_kv.shape[1]
    qpos = pos[None]                     # (1,)
    kpos = jnp.arange(s_max)
    out = _mla_attend(q_nope, q_rope, c_kv, k_rope, p, cfg, (qpos, kpos))
    return jnp.dot(out, p["wo"]), MLACache(c_kv, k_rope)


def mla_decode_paged(x: jax.Array, p: dict, cfg: ModelConfig,
                     pool: MLACache, table: jax.Array,
                     pos: jax.Array) -> tuple[jax.Array, MLACache]:
    """One-token MLA decode against a paged compressed-latent pool.

    Same contract as :func:`gqa_decode_paged`: table (B, M) page ids,
    pos (B,) per-row positions, page 0 is the trash page.
    """
    posb = pos[:, None]                             # (B, 1)
    q_nope, q_rope = _mla_q(x, p, cfg, posb)
    c_new, kr_new = _mla_kv(x, p, cfg, posb)
    c_pool = _paged_write(pool.c_kv, c_new[:, 0], table, pos)
    r_pool = _paged_write(pool.k_rope, kr_new[:, 0], table, pos)
    c_kv = paged_view(c_pool, table)                # (B, M*PS, lora)
    k_rope = paged_view(r_pool, table)
    kpos = jnp.arange(c_kv.shape[1])
    out = _mla_attend(q_nope, q_rope, c_kv, k_rope, p, cfg, (posb, kpos))
    return jnp.dot(out, p["wo"]), MLACache(c_pool, r_pool)
