"""Trace analyzer: ``python -m repro.launch.obs trace.json``.

Renders a dumped telemetry trace (:meth:`repro.obs.Telemetry.dump_trace`
— the Chrome/Perfetto JSON the trainer's ``--trace`` flag writes) into:

* a **phase table** — wall-clock per span name, top-level and nested,
  with the coverage fraction (how much of the traced wall-clock landed
  inside *named* phases; the acceptance gate demands >= 95%);
* a **recovery-attribution table** — one row per failure event, its
  victims, and where the time went: *masking* (recovery handling that
  kept training — controller + schedule re-plan), *rollback* (steps
  re-executed after a wipe-out, costed at the run's median step
  duration), *restart* (the modeled cluster restart outage the injector
  accounted on its clock);
* optionally a **text timeline** of the main track (``--timeline``).

Exit status enforces the CI gates: ``--assert-coverage 0.95`` and
``--assert-recovery-markers`` (at least one failure marker AND one
recover span — an injected-failure run whose trace shows neither is a
broken bridge, not a quiet one).

The same trace loads unchanged at https://ui.perfetto.dev (failure
markers ride per-DP-group tracks under the main span rows).
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.obs.trace import TraceView, load_trace

__all__ = ["phase_table", "attribution_table", "coverage", "analyze",
           "main"]


def phase_table(view: TraceView, track: str = "main") -> list[dict]:
    """Aggregate spans by (depth, name) on one track."""
    agg: dict[tuple, dict] = {}
    for s in view.track_spans(track):
        key = (s.depth, s.name)
        row = agg.setdefault(key, {"depth": s.depth, "phase": s.name,
                                   "count": 0, "total_us": 0.0})
        row["count"] += 1
        row["total_us"] += s.dur
    wall = view.wall_us(track)
    rows = sorted(agg.values(),
                  key=lambda r: (r["depth"], -r["total_us"]))
    for row in rows:
        row["total_s"] = row["total_us"] / 1e6
        row["pct_of_wall"] = (100.0 * row["total_us"] / wall) if wall else 0.0
    return rows


def coverage(view: TraceView, track: str = "main") -> float:
    """Fraction of the track's wall-clock inside top-level named spans.

    Top-level spans from one recorder never overlap (they come off one
    nesting stack), so the sum of their durations is the covered time.
    """
    wall = view.wall_us(track)
    if wall <= 0:
        return 0.0
    covered = sum(s.dur for s in view.track_spans(track, depth=0))
    return covered / wall


def _median_step_us(view: TraceView) -> float:
    steps = [s.dur for s in view.named("step")]
    return float(np.median(steps)) if steps else 0.0


def attribution_table(view: TraceView) -> list[dict]:
    """One row per ``recover`` span: where did the event's time go?

    * ``masking_s`` — host wall inside the recover span for masked
      (non-wipe-out) recoveries: the RECTLR controller + schedule
      re-plan that kept training alive;
    * ``rollback_s`` — wiped-out steps re-executed, costed at the run's
      median step duration (``rollback_depth x median(step)``);
    * ``restart_s`` — the modeled restart outage the injector accounted
      on its failure clock (``restart_seconds`` span arg), i.e. what a
      real cluster would additionally pay to come back;
    * ``reshape_s`` — the modeled resharding outage of an elastic
      degraded-continue (``reshape_seconds`` span arg): the event kept
      training at a reduced DP degree instead of restarting.

    Gray-failure events get their own kinds: ``demote`` (a fail-slow
    group proactively masked out of the weighted sync — the victims
    were alive, just slow) and ``readmit`` (the healed group folded
    back in); both are weight-table edits, so their cost lands in
    ``masking_s`` like any mask.
    """
    step_us = _median_step_us(view)
    rows = []
    for s in view.named("recover"):
        args = s.args or {}
        wipe = bool(args.get("wipeout"))
        reshape = bool(args.get("reshape"))
        depth = int(args.get("rollback_depth", 0))
        if args.get("demote"):
            kind = "demote"
        elif args.get("readmit"):
            kind = "readmit"
        elif reshape:
            kind = "reshape"
        elif wipe:
            kind = "restart"
        else:
            kind = "mask"
        rows.append({
            "t_s": s.ts / 1e6,
            "step": args.get("step"),
            "kind": kind,
            "victims": args.get("victims", []),
            "handling_s": s.dur / 1e6,
            "masking_s": (s.dur / 1e6
                          if kind in ("mask", "demote", "readmit")
                          else 0.0),
            "rollback_depth": depth,
            "rollback_s": depth * step_us / 1e6,
            "restart_s": float(args.get("restart_seconds", 0.0)),
            "reshape_s": float(args.get("reshape_seconds", 0.0)),
            "dp": (f"{args.get('dp_before', '?')}->"
                   f"{args.get('dp_after', '?')}" if reshape else ""),
            "s_a": f"{args.get('s_a_before', '?')}->"
                   f"{args.get('s_a_after', '?')}",
        })
    return rows


def analyze(view: TraceView) -> dict:
    """Everything the text report prints, as one JSON-able dict."""
    failures = [i for i in view.instants if i.name == "failure"]
    att = attribution_table(view)
    return {
        "tracks": view.tracks,
        "wall_s": view.wall_us("main") / 1e6,
        "coverage": coverage(view),
        "phases": phase_table(view),
        "failure_markers": len(failures),
        "failure_tracks": sorted({i.track for i in failures}),
        "recovery_events": att,
        "lost": {
            "masking_s": sum(r["masking_s"] for r in att),
            "rollback_s": sum(r["rollback_s"] for r in att),
            "restart_s": sum(r["restart_s"] for r in att),
            "reshape_s": sum(r["reshape_s"] for r in att),
        },
    }


def _fmt_s(x: float) -> str:
    return f"{x:9.3f}"


def _print_report(rep: dict, view: TraceView, timeline: int) -> None:
    print(f"trace: {rep['wall_s']:.3f}s wall on main | "
          f"tracks: {', '.join(rep['tracks'])}")
    print(f"\nphases (main track, % of {rep['wall_s']:.3f}s wall):")
    print(f"  {'phase':<16} {'count':>6} {'total_s':>9} {'% wall':>7}")
    for row in rep["phases"]:
        pad = "  " * row["depth"]
        print(f"  {pad}{row['phase']:<{16 - 2 * row['depth']}} "
              f"{row['count']:>6} {_fmt_s(row['total_s'])} "
              f"{row['pct_of_wall']:>6.1f}%")
    print(f"  coverage (top-level named spans): "
          f"{100.0 * rep['coverage']:.1f}%")

    att = rep["recovery_events"]
    print(f"\nrecovery attribution ({rep['failure_markers']} failure "
          f"markers on {len(rep['failure_tracks'])} group tracks, "
          f"{len(att)} recovery events):")
    if att:
        print(f"  {'t_s':>8} {'step':>5} {'kind':>7} {'victims':<14} "
              f"{'masking_s':>9} {'rollback_s':>10} {'restart_s':>9} "
              f"{'reshape_s':>9} {'DP':>6} {'S_A':>6}")
        for r in att:
            vict = ",".join(str(v) for v in r["victims"])
            print(f"  {r['t_s']:>8.3f} {str(r['step']):>5} "
                  f"{r['kind']:>7} {vict:<14} "
                  f"{r['masking_s']:>9.3f} {r['rollback_s']:>10.3f} "
                  f"{r['restart_s']:>9.1f} {r['reshape_s']:>9.1f} "
                  f"{r.get('dp', ''):>6} {r['s_a']:>6}")
        lost = rep["lost"]
        print(f"  {'TOTAL':>22} {'':<14} {lost['masking_s']:>9.3f} "
              f"{lost['rollback_s']:>10.3f} {lost['restart_s']:>9.1f} "
              f"{lost['reshape_s']:>9.1f}")
        print("  (masking = recovery handling that kept training, incl. "
              "demote/readmit weight-table edits for fail-slow groups; "
              "rollback = wiped steps x median step; restart = modeled "
              "outage on the injector clock; reshape = modeled elastic "
              "resharding outage, training continued degraded)")

    if timeline:
        print(f"\ntimeline (main track, first {timeline} spans):")
        for s in view.track_spans("main")[:timeline]:
            pad = "  " * s.depth
            print(f"  {s.ts / 1e6:>9.3f}s {pad}{s.name:<14} "
                  f"{s.dur / 1e6:8.3f}s")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome/Perfetto trace JSON "
                                  "(--trace output of launch.train/serve)")
    ap.add_argument("--timeline", type=int, nargs="?", const=60, default=0,
                    help="also print the first N main-track spans")
    ap.add_argument("--json", default=None,
                    help="write the analysis dict to this path")
    ap.add_argument("--assert-coverage", type=float, default=None,
                    help="exit non-zero unless named top-level spans "
                         "cover >= this fraction of wall-clock")
    ap.add_argument("--assert-recovery-markers", action="store_true",
                    help="exit non-zero unless the trace carries >= 1 "
                         "failure marker and >= 1 recover span")
    args = ap.parse_args(argv)

    view = load_trace(args.trace)
    rep = analyze(view)
    _print_report(rep, view, args.timeline)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(rep, fh, indent=1, sort_keys=True)

    ok = True
    if args.assert_coverage is not None and \
            rep["coverage"] < args.assert_coverage:
        print(f"FAIL: coverage {rep['coverage']:.3f} < "
              f"{args.assert_coverage}", file=sys.stderr)
        ok = False
    if args.assert_recovery_markers and not (
            rep["failure_markers"] and rep["recovery_events"]):
        print(f"FAIL: expected failure markers + recovery spans, got "
              f"{rep['failure_markers']} markers / "
              f"{len(rep['recovery_events'])} events", file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
