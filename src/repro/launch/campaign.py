"""Campaign CLI: ``python -m repro.launch.campaign [--preset regimes]``.

Runs a declarative scenario grid (scheme x N x r x failure model x seed)
through the process-parallel campaign runner and writes byte-stable
CSV/JSON artifacts under ``benchmarks/results/``. Grids come from a
named preset (``--preset``, see ``--list``) or a JSON spec file
(``--grid``) with the :class:`repro.scenarios.campaign.CampaignSpec`
fields::

    {"name": "my_sweep",
     "schemes": ["spare", ["replication", {"r": 2}]],
     "ns": [200], "rs": [4, 9],
     "models": [{"kind": "correlated", "label": "rack", "burst_prob": 0.2}],
     "seeds": [0, 1], "steps": 600}

Determinism: each cell seeds its RNG from a hash of its own identity,
so ``--jobs 4`` produces byte-identical artifacts to ``--jobs 1``.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="smoke",
                    help="named grid (see --list)")
    ap.add_argument("--grid", default=None,
                    help="JSON CampaignSpec file (overrides --preset)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="worker processes (1 = serial)")
    ap.add_argument("--seeds", type=int, default=None,
                    help="override: sweep seeds 0..K-1")
    ap.add_argument("--steps", type=int, default=None,
                    help="override the grid's training horizon")
    ap.add_argument("--base-seed", type=int, default=None,
                    help="override the grid's seed-hash salt")
    ap.add_argument("--out", default=None,
                    help="artifact basename (default: the grid's name)")
    ap.add_argument("--outdir", default=None,
                    help="artifact directory (default: benchmarks/results)")
    ap.add_argument("--list", action="store_true",
                    help="list presets, schemes, failure models, traces")
    args = ap.parse_args(argv)

    from repro.des import list_schemes
    from repro.scenarios import (CAMPAIGN_PRESETS, CampaignSpec,
                                 bundled_traces, list_failure_models,
                                 ranking_by_regime, run_campaign,
                                 save_artifacts)

    if args.list:
        print(f"presets:  {sorted(CAMPAIGN_PRESETS)}")
        print(f"schemes:  {list_schemes()}")
        print(f"models:   {list_failure_models()}")
        print(f"traces:   {bundled_traces()}")
        return

    if args.grid:
        spec = CampaignSpec.from_json(args.grid)
    else:
        try:
            spec = CAMPAIGN_PRESETS[args.preset]
        except KeyError:
            sys.exit(f"unknown preset {args.preset!r}; "
                     f"have {sorted(CAMPAIGN_PRESETS)}")
    if args.seeds is not None:
        spec.seeds = list(range(args.seeds))
    if args.steps is not None:
        spec.steps = args.steps

    cells = spec.cells()
    print(f"[campaign] {spec.name}: {len(cells)} cells, "
          f"jobs={args.jobs}", file=sys.stderr)
    t0 = time.perf_counter()
    results = run_campaign(cells, jobs=args.jobs, base_seed=args.base_seed)
    elapsed = time.perf_counter() - t0

    csv_path, json_path = save_artifacts(args.out or spec.name, results,
                                         outdir=args.outdir)
    cell_s = sum(r["elapsed_s"] for r in results)
    print(f"[campaign] done in {elapsed:.1f}s wall; {cell_s:.1f}s total "
          f"cell-time ({cell_s / max(elapsed, 1e-9):.2f}x speedup vs "
          f"serial)", file=sys.stderr)
    print(f"[campaign] artifacts: {csv_path} {json_path}", file=sys.stderr)

    for regime, ranking in ranking_by_regime(results).items():
        order = " > ".join(
            f"{e['scheme']}({e['mean_ttt_norm']:.2f})" for e in ranking)
        print(f"{regime}: {order}")


if __name__ == "__main__":
    main()
