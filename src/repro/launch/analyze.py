import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Dry-run deep analysis: per-instruction collective/buffer attribution
with trip-count multipliers — the §Perf hypothesis tool.

  python -m repro.launch.analyze --arch qwen2.5-3b --shape train_4k \
      [--multi-pod] [--top 15]
"""
import argparse
import re
from collections import defaultdict

from repro.launch import hlo as H

__all__ = ["top_collectives", "top_buffers", "compile_cell"]


def compile_cell(arch: str, shape_name: str, multi_pod: bool):
    from repro.launch.dryrun import run_cell  # noqa: F401 (env set above)
    import jax
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.configs import SHAPES, get_config
    from repro.dist.sharding import cache_specs, param_specs
    from repro.launch.dryrun import input_specs
    from repro.launch.mesh import dp_axes, make_production_mesh
    from repro.models import build_model
    from repro.optim import adamw_init
    from repro.train import make_prefill, make_serve_step, make_train_step
    import jax.numpy as jnp

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg, mesh=mesh, dp_axes=dp_axes(multi_pod))
    p_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_spec = param_specs(p_shapes, cfg, multi_pod)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_spec)
    batch, bspec_tree = input_specs(cfg, shape, mesh, multi_pod, 1)
    b_shard = {k: NamedSharding(mesh, v) for k, v in bspec_tree.items()}
    with mesh:
        if shape.kind == "train":
            opt_shapes = jax.eval_shape(
                lambda p: adamw_init(p, moment_dtype=cfg.moment_dtype),
                p_shapes)
            o_spec = type(opt_shapes)(step=P(), mu=p_spec, nu=p_spec)
            o_shard = jax.tree.map(
                lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
                o_spec, is_leaf=lambda x: isinstance(x, P))
            fn = make_train_step(model, grad_shardings=p_shard)
            return jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard),
                           out_shardings=(p_shard, o_shard, None),
                           donate_argnums=(0, 1)
                           ).lower(p_shapes, opt_shapes, batch).compile()
        if shape.kind == "prefill":
            fn = make_prefill(model)
            return jax.jit(fn, in_shardings=(p_shard, b_shard.get("tokens"),
                                             b_shard.get("embeds")),
                           out_shardings=None
                           ).lower(p_shapes, batch.get("tokens"),
                                   batch.get("embeds")).compile()
        cache_shapes = jax.eval_shape(
            lambda: model.init_decode_state(shape.global_batch, shape.seq))
        c_spec = cache_specs(cache_shapes, cfg, mesh, multi_pod)
        c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_spec)
        fn = make_serve_step(model)
        return jax.jit(fn, in_shardings=(p_shard, c_shard, None,
                                         b_shard.get("tokens"),
                                         b_shard.get("embeds")),
                       out_shardings=(None, c_shard), donate_argnums=(1,)
                       ).lower(p_shapes, cache_shapes,
                               jax.ShapeDtypeStruct((), jnp.int32),
                               batch.get("tokens"),
                               batch.get("embeds")).compile()


def _walk(comps, entry, visit):
    """DFS from entry multiplying trip counts; visit(instr, comp, mult)."""
    def go(name, mult):
        comp = comps[name]
        for instr in comp.instrs:
            if instr.op == "while":
                body = re.search(r"body=%?([\w\.\-]+)", instr.attrs)
                trip = H._trip_count(instr, comps) or 1
                if body and body.group(1) in comps:
                    go(body.group(1), mult * trip)
                continue
            if instr.op in ("call", "async-start"):
                fm = re.search(r"(?:to_apply|calls|called_computation)"
                               r"=%?([\w\.\-]+)", instr.attrs)
                if fm and fm.group(1) in comps:
                    go(fm.group(1), mult)
                continue
            visit(instr, comp, mult)
    go(entry, 1.0)


def top_collectives(hlo_text: str, k: int = 15):
    comps, entry = H.parse_module(hlo_text)
    items = defaultdict(lambda: [0.0, 0, ""])

    def visit(instr, comp, mult):
        base = instr.op.removesuffix("-start").removesuffix("-done")
        if base not in H._COLLECTIVES or instr.op.endswith("-done"):
            return
        out_b = H._shape_bytes(instr.out_shapes)
        if instr.op.endswith("-start"):
            out_b //= 2
        moved = {"all-reduce": 2.0 * out_b,
                 "reduce-scatter": out_b * H._group_size(instr.attrs)
                 }.get(base, float(out_b))
        m = re.search(r'op_name="([^"]+)"', instr.attrs)
        src = m.group(1) if m else "?"
        shp = "/".join(f"{dt}{list(d)}" for dt, d in instr.out_shapes[:2])
        key = (base, shp, src[-110:])
        items[key][0] += moved * mult
        items[key][1] += int(mult)

    _walk(comps, entry, visit)
    rows = sorted(((v[0], v[1], k2) for k2, v in items.items()),
                  reverse=True)[:k]
    return rows


def top_buffers(hlo_text: str, k: int = 15):
    comps, entry = H.parse_module(hlo_text)
    items = defaultdict(lambda: [0.0, 0])

    def visit(instr, comp, mult):
        base = instr.op.removesuffix("-start")
        if base in H._COLLECTIVES or instr.op in H._NO_BYTES or \
                instr.op == "reshape":
            return
        b = H._shape_bytes(instr.out_shapes)
        if instr.op in H._READ_OPS:
            for o in instr.operands:
                b += H._shape_bytes(comp.shapes.get(o, []))
        m = re.search(r'op_name="([^"]+)"', instr.attrs)
        src = (m.group(1) if m else instr.op)[-100:]
        items[(instr.op, src)][0] += b * mult
        items[(instr.op, src)][1] += int(mult)

    _walk(comps, entry, visit)
    return sorted(((v[0], v[1], k2) for k2, v in items.items()),
                  reverse=True)[:k]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()
    compiled = compile_cell(args.arch, args.shape, args.multi_pod)
    txt = compiled.as_text()
    print("== top collectives (bytes moved x trips) ==")
    for moved, trips, (op, shp, src) in top_collectives(txt, args.top):
        print(f"{moved / 2**30:9.2f} GiB x{trips:5d} {op:18s} {shp:28s} {src}")
    print("\n== top HBM traffic contributors ==")
    for b, trips, (op, src) in top_buffers(txt, args.top):
        print(f"{b / 2**30:9.2f} GiB x{trips:5d} {op:22s} {src}")


if __name__ == "__main__":
    main()
