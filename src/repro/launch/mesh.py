"""Production + emulated mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this
module touches no jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls these.

Production target: TPU v5e pods.
  single-pod : (16, 16)    = 256 chips, axes (data, model)
  multi-pod  : (2, 16, 16) = 512 chips, axes (pod, data, model)

The SPARe data-parallel groups are the ``pod x data`` slices (N = 32 DP
groups of M = 16 model-sharded chips on the multi-pod mesh); the ``pod``
axis crosses the DCI boundary, which is exactly the axis the SPARe
failure-masking weights neutralize when a whole slice drops out.

:func:`make_emulated_mesh` builds the same ``(data, model)`` topology
from however many devices the host platform exposes — the
``repro.exec`` SPMD tests and benchmarks run the real sharded step on
any machine via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import numpy as np

import jax

__all__ = ["make_production_mesh", "make_emulated_mesh", "dp_axes",
           "dp_degree"]


def _make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer releases; explicit
    Auto types match the old default, so fall back silently."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:        # make_mesh predates axis_types
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_emulated_mesh(data_degree: int,
                       model_degree: int = 1) -> jax.sharding.Mesh:
    """``(data, model)`` mesh over the first ``data*model`` local devices.

    On a CPU container, export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=<n>`` *before the
    first jax import* to fan one host out into ``n`` emulated devices —
    the same SPMD partitioner, collectives, and HLO the production mesh
    sees, at laptop scale.
    """
    need = data_degree * model_degree
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"mesh ({data_degree}, {model_degree}) needs {need} devices "
            f"but only {have} are visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before the "
            f"first jax import (see README §repro.exec)")
    devices = np.asarray(jax.devices()[:need]).reshape(
        data_degree, model_degree)
    return jax.sharding.Mesh(devices, ("data", "model"))


def dp_axes(multi_pod: bool) -> tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def dp_degree(mesh: jax.sharding.Mesh, multi_pod: bool) -> int:
    n = 1
    for a in dp_axes(multi_pod):
        n *= mesh.shape[a]
    return n
