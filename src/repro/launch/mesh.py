"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this
module touches no jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls these.

Production target: TPU v5e pods.
  single-pod : (16, 16)    = 256 chips, axes (data, model)
  multi-pod  : (2, 16, 16) = 512 chips, axes (pod, data, model)

The SPARe data-parallel groups are the ``pod x data`` slices (N = 32 DP
groups of M = 16 model-sharded chips on the multi-pod mesh); the ``pod``
axis crosses the DCI boundary, which is exactly the axis the SPARe
failure-masking weights neutralize when a whole slice drops out.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "dp_axes", "dp_degree"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def dp_axes(multi_pod: bool) -> tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def dp_degree(mesh: jax.sharding.Mesh, multi_pod: bool) -> int:
    n = 1
    for a in dp_axes(multi_pod):
        n *= mesh.shape[a]
    return n
