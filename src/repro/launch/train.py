"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the full SPARe+CKPT loop (Alg. 1) at a configurable scale. On this
CPU container it runs reduced configs end-to-end (``--smoke``, default);
on a real TPU fleet the same entry point runs the full config on the
production mesh (``--full`` uses the sharded train step the dry-run
lowers; per-host data feeding via the same deterministic pipeline).

Failure injection comes in two flavors:

* ``--mtbf-steps K`` — the legacy toy injector: Poisson arrivals in step
  time, uniform single-group victims;
* ``--failure-model SPEC [--topology SPEC]`` — the scenario bridge
  (:mod:`repro.train.injection`): any registered
  :class:`repro.scenarios.models.FailureModel` drives the live trainer
  through the cluster topology, so rack/pod bursts and trace replays
  deliver *multi-group* kill batches to ``scheme.recover``. SPEC is a
  registry name (``correlated``) or a JSON object
  (``'{"kind": "correlated", "scope": "rack", "burst_prob": 0.5}'``).

``--sweep-regimes`` ignores ``--arch`` and runs the trainer campaign
preset instead: the tiny-config trainer across the three PR-2 regimes
(weibull / rack-burst / trace replay), verifying the §3.1 gradient
invariant after every recovery.

``--mesh`` swaps the emulated trainer for the :class:`repro.exec
.MeshExecutor`: the identical loop (same schemes, same injectors, same
report) but the step runs sharded over an ``n_groups x model_degree``
device mesh with the §3.1 weighted all-reduce on the wire. On a CPU
container the launcher forces the host platform to fan out into enough
emulated devices automatically (the dry-run trick), so
``python -m repro.launch.train --arch qwen2.5-3b --mesh`` works
anywhere.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _spec(arg: str | None):
    """Parse a model/topology CLI spec: JSON object or bare name."""
    if arg is None:
        return None
    arg = arg.strip()
    if arg.startswith("{"):
        return json.loads(arg)
    return arg


def _resolve_r(args) -> int:
    """'-r 0 = Thm-4.3 optimal' — one policy for every launcher path."""
    from repro.core.theory import r_star
    return args.redundancy or max(2, min(r_star(args.n_groups),
                                         args.n_groups - 1))


def _sweep_regimes(args) -> None:
    from repro.scenarios.campaign import (run_trainer_cell,
                                          trainer_regime_cells)

    trace_dir = args.trace     # in sweep mode --trace names a DIRECTORY
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
        print(f"[sweep] telemetry on: one trace per regime under "
              f"{trace_dir}/", file=sys.stderr)
    cells = trainer_regime_cells(steps=args.steps, n=args.n_groups,
                                 r=_resolve_r(args),
                                 topology=_spec(args.topology),
                                 seconds_per_step=args.seconds_per_step,
                                 base_seed=args.seed,
                                 trace_dir=trace_dir or None)
    rows = []
    for cell in cells:
        label = cell["model"].get("label", cell["model"]["kind"])
        print(f"[sweep] {label}: N={cell['n']} r={cell['r']} "
              f"steps={cell['steps']}", file=sys.stderr)
        row = run_trainer_cell(cell)
        rows.append(row)
        print(f"[sweep] {label}: steps={row['steps_done']} "
              f"failures={row['failures']} wipeouts={row['wipeouts']} "
              f"reorders={row['reorders']} patches={row['patches']} "
              f"multi_group={row['multi_group_events']} "
              f"max_grad_err={row['max_grad_check_err']:.2e}")
    multi = sum(r["multi_group_events"] for r in rows)
    print(f"[sweep] total multi-group kill batches delivered to "
          f"scheme.recover: {multi}")
    if args.report_json:
        with open(args.report_json, "w") as f:
            json.dump(rows, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--n-groups", type=int, default=8,
                    help="SPARe data-parallel degree N")
    ap.add_argument("--redundancy", "-r", type=int, default=0,
                    help="stack redundancy r (0 = Thm-4.3 optimal)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--per-type-batch", type=int, default=2)
    ap.add_argument("--mtbf-steps", type=float, default=0.0,
                    help="legacy Poisson injector: failures every ~K "
                         "steps (0 = none)")
    ap.add_argument("--failure-model", default=None,
                    help="scenario-bridge injection: model name or JSON "
                         "spec (repro.scenarios registry)")
    ap.add_argument("--topology", default=None,
                    help="cluster topology: preset name or JSON spec "
                         "(default: small layout at N)")
    ap.add_argument("--seconds-per-step", type=float, default=None,
                    help="step duration on the failure model's clock "
                         "(default: DES t_comp + t_allreduce)")
    ap.add_argument("--verify-equivalence", action="store_true",
                    help="check the §3.1 gradient invariant after every "
                         "successful recovery")
    ap.add_argument("--sweep-regimes", action="store_true",
                    help="run the tiny-config trainer (seq=32, "
                         "per-type batch 1, §3.1-verified) across the "
                         "three PR-2 failure regimes and exit; honors "
                         "--steps/--n-groups/-r/--seed/--topology/"
                         "--seconds-per-step, ignores the other flags")
    ap.add_argument("--mesh", action="store_true",
                    help="run on a real SPMD device mesh (repro.exec."
                         "MeshExecutor) instead of the emulated trainer; "
                         "forces --xla_force_host_platform_device_count "
                         "when too few devices are visible")
    ap.add_argument("--model-degree", type=int, default=1,
                    help="tensor-parallel degree of the --mesh mesh")
    ap.add_argument("--sync", default="shard_map",
                    choices=("shard_map", "gspmd"),
                    help="--mesh gradient-sync spelling: explicit "
                         "bucketed psum under shard_map, or GSPMD "
                         "NamedShardings with params sharded on the "
                         "model axis")
    ap.add_argument("--grad-compress", default="none",
                    choices=("none", "int8_ef"),
                    help="--mesh only: compress the bucketed gradient "
                         "sync (int8 payload + per-bucket scales over "
                         "the wire, EF residuals as device-local state; "
                         "requires --sync shard_map)")
    ap.add_argument("--elastic", action="store_true",
                    help="with --mesh: enable the elastic recovery tier "
                         "(repro.elastic.ElasticMeshExecutor) — an "
                         "unmaskable failure set shrinks the DP degree "
                         "and continues degraded when the TTT policy "
                         "favors it over restart")
    ap.add_argument("--t-reshape", type=float, default=60.0,
                    help="--elastic only: modeled outage seconds per "
                         "online resharding (weighed against the "
                         "t_restart outage by the TTT policy)")
    ap.add_argument("--scheme", default="spare",
                    help="fault-tolerance scheme (repro.des registry: "
                         "spare | replication | ckpt_only | adaptive)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--report-json", default=None)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record telemetry and write a Perfetto-loadable "
                         "Chrome trace here (analyze with "
                         "python -m repro.launch.obs PATH); a metrics "
                         "snapshot lands next to it at PATH.metrics.json")
    ap.add_argument("--trace-deep", action="store_true",
                    help="with --trace: in-jit bucket markers + EF "
                         "residual norms (changes the compiled program; "
                         "attribution sessions only)")
    args = ap.parse_args()

    if args.sweep_regimes:
        _sweep_regimes(args)
        return

    if args.mesh:
        # must land before the FIRST jax import (jax locks the device
        # count on init); every repro import below is function-local so
        # this is still early enough. Append rather than setdefault —
        # unrelated pre-set XLA_FLAGS must not silently disable the
        # fan-out (an explicit user-set device count still wins).
        existing = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in existing:
            flag = ("--xla_force_host_platform_device_count="
                    f"{args.n_groups * args.model_degree}")
            os.environ["XLA_FLAGS"] = f"{existing} {flag}".strip()

    from repro.configs import get_config, smoke_config
    from repro.des import get_scheme
    from repro.train.trainer import PoissonInjector, SpareTrainer

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.scaled(grad_accum=1)
    r = _resolve_r(args)
    tag = "" if args.grad_compress == "none" else f"+{args.grad_compress}"
    plane = (f"{args.n_groups}x{args.model_degree}/{args.sync}{tag}"
             if args.mesh else "emulated")
    print(f"[train] arch={args.arch} N={args.n_groups} r={r} "
          f"scheme={args.scheme} steps={args.steps} mesh={plane} "
          f"params={cfg.param_count():,}")

    tel = None
    if args.trace is not None:
        from repro.obs import Telemetry
        tel = Telemetry(deep=args.trace_deep)

    scheme_kwargs = {} if args.scheme == "ckpt_only" else {"r": r}
    common = dict(n_groups=args.n_groups, redundancy=r, seq=args.seq,
                  per_type_batch=args.per_type_batch, seed=args.seed,
                  ckpt_dir=args.ckpt_dir, base_lr=args.lr,
                  total_steps=args.steps, telemetry=tel,
                  scheme=get_scheme(args.scheme, **scheme_kwargs))
    if args.mesh:
        compress = None if args.grad_compress == "none" else \
            args.grad_compress
        mesh_kw = dict(model_degree=args.model_degree, sync=args.sync,
                       grad_compress=compress, **common)
        if args.elastic:
            from repro.elastic import ElasticMeshExecutor
            trainer = ElasticMeshExecutor(cfg, t_reshape=args.t_reshape,
                                          **mesh_kw)
        else:
            from repro.exec import MeshExecutor
            trainer = MeshExecutor(cfg, **mesh_kw)
    elif args.elastic:
        ap.error("--elastic needs --mesh (the elastic tier reshapes a "
                 "real device mesh)")
    else:
        trainer = SpareTrainer(cfg, **common)
    if args.failure_model is not None:
        from repro.train.injection import ScenarioInjector
        injector = ScenarioInjector(
            _spec(args.failure_model), _spec(args.topology),
            n_groups=args.n_groups,
            seconds_per_step=args.seconds_per_step, seed=args.seed)
    elif args.mtbf_steps > 0:
        injector = PoissonInjector(args.mtbf_steps, seed=args.seed)
    else:
        injector = None
    t0 = time.perf_counter()
    rep = trainer.run(args.steps, injector=injector,
                      verify_equivalence=args.verify_equivalence)
    dt = time.perf_counter() - t0
    print(f"[train] done: {rep.steps_done} steps in {dt:.1f}s "
          f"({dt / max(rep.steps_done, 1):.2f}s/step)")
    print(f"[train] loss {rep.losses[0]:.4f} -> {rep.losses[-1]:.4f} | "
          f"failures={rep.failures} wipeouts={rep.wipeouts} "
          f"reshapes={rep.reshapes} reorders={rep.reorders} "
          f"patches={rep.patches} S_A={trainer.state.s_a} "
          f"ckpts={rep.ckpt_saves}")
    if rep.reshapes:
        print(f"[train] elastic: DP degree now {trainer.state.n} "
              f"(full {args.n_groups}); policy log: "
              f"{getattr(trainer, 'policy_log', [])}")
    if rep.events:
        print(f"[train] recovery events={len(rep.events)} "
              f"multi_group={rep.multi_group_events} "
              f"rollback_steps={rep.rollback_steps} "
              f"max_grad_err={rep.max_grad_check_err:.2e}")
    if args.report_json:
        with open(args.report_json, "w") as f:
            json.dump({"losses": rep.losses, "failures": rep.failures,
                       "wipeouts": rep.wipeouts, "steps": rep.steps_done,
                       "multi_group_events": rep.multi_group_events,
                       "max_grad_check_err": rep.max_grad_check_err},
                      f)
    if tel is not None:
        tel.dump_trace(args.trace)
        tel.metrics.dump(args.trace + ".metrics.json")
        print(f"[train] trace -> {args.trace} (analyze: python -m "
              f"repro.launch.obs {args.trace}) | metrics -> "
              f"{args.trace}.metrics.json")


if __name__ == "__main__":
    main()
