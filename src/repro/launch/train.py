"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the full SPARe+CKPT loop (Alg. 1) at a configurable scale. On this
CPU container it runs reduced configs end-to-end (``--smoke``, default);
on a real TPU fleet the same entry point runs the full config on the
production mesh (``--full`` uses the sharded train step the dry-run
lowers; per-host data feeding via the same deterministic pipeline).
"""
from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--n-groups", type=int, default=8,
                    help="SPARe data-parallel degree N")
    ap.add_argument("--redundancy", "-r", type=int, default=0,
                    help="stack redundancy r (0 = Thm-4.3 optimal)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--per-type-batch", type=int, default=2)
    ap.add_argument("--mtbf-steps", type=float, default=0.0,
                    help="inject failures every ~K steps (0 = none)")
    ap.add_argument("--scheme", default="spare",
                    help="fault-tolerance scheme (repro.des registry: "
                         "spare | replication | ckpt_only | adaptive)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--report-json", default=None)
    args = ap.parse_args()

    from repro.configs import get_config, smoke_config
    from repro.core.theory import r_star
    from repro.des import get_scheme
    from repro.train.trainer import PoissonInjector, SpareTrainer

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.scaled(grad_accum=1)
    r = args.redundancy or max(2, min(r_star(args.n_groups),
                                      args.n_groups - 1))
    print(f"[train] arch={args.arch} N={args.n_groups} r={r} "
          f"scheme={args.scheme} steps={args.steps} "
          f"params={cfg.param_count():,}")

    scheme_kwargs = {} if args.scheme == "ckpt_only" else {"r": r}
    trainer = SpareTrainer(cfg, n_groups=args.n_groups, redundancy=r,
                           seq=args.seq, per_type_batch=args.per_type_batch,
                           seed=args.seed, ckpt_dir=args.ckpt_dir,
                           base_lr=args.lr, total_steps=args.steps,
                           scheme=get_scheme(args.scheme, **scheme_kwargs))
    injector = (PoissonInjector(args.mtbf_steps, seed=args.seed)
                if args.mtbf_steps > 0 else None)
    t0 = time.time()
    rep = trainer.run(args.steps, injector=injector)
    dt = time.time() - t0
    print(f"[train] done: {rep.steps_done} steps in {dt:.1f}s "
          f"({dt / max(rep.steps_done, 1):.2f}s/step)")
    print(f"[train] loss {rep.losses[0]:.4f} -> {rep.losses[-1]:.4f} | "
          f"failures={rep.failures} wipeouts={rep.wipeouts} "
          f"reorders={rep.reorders} patches={rep.patches} "
          f"S_A={trainer.state.s_a} ckpts={rep.ckpt_saves}")
    if args.report_json:
        with open(args.report_json, "w") as f:
            json.dump({"losses": rep.losses, "failures": rep.failures,
                       "wipeouts": rep.wipeouts, "steps": rep.steps_done},
                      f)


if __name__ == "__main__":
    main()
