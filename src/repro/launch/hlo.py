"""Trip-count-aware accounting over compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits every ``while`` body ONCE — for a
scan-over-layers model that undercounts FLOPs/bytes/collectives by the
layer count (we measured 26x on a 36-layer model). This module re-derives
the three roofline inputs honestly:

* parse the HLO module into computations + instructions;
* walk from ENTRY, expanding ``while`` bodies by their trip count (taken
  from jax's ``backend_config={"known_trip_count":{"n":...}}``, falling
  back to the loop-condition constant), fusions/calls by 1, conditionals
  by the max branch;
* FLOPs: matmul convention — ``dot`` = 2 * prod(lhs shape) * prod(rhs
  free dims) (+ small depthwise-conv term); elementwise ops are ignored,
  as in standard MFU accounting;
* bytes: per *kernel* (fusion call sites count operands+outputs once —
  XLA's own bytes-accessed granularity), times trip counts;
* collectives: per-device moved bytes with ring-algorithm multipliers
  (all-reduce 2x out, all-gather 1x out, reduce-scatter ~in, all-to-all /
  collective-permute 1x), ``-start``/``-done`` pairs counted once.

Shapes in post-SPMD text are per-device, so all outputs here are
per-device quantities — the same granularity as the roofline formulas.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["HloCost", "analyze_hlo", "collective_report",
           "wire_byte_ratio", "same_collective_schedule"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
    "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\((.*)\)\s+->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_NO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "while", "conditional", "call", "iota", "partition-id",
    "replica-id",
}
# Ops whose *operand reads* count as HBM traffic. Elementwise ops between
# these get fused on the TPU target, so their reads are producers' writes
# (already counted as output bytes below) — counting every unfused CPU-HLO
# elementwise operand would overstate HBM traffic ~5-10x (measured).
# reshape is excluded entirely: free reshapes lower to bitcast and real
# layout changes show up as copy/transpose.
_READ_OPS = {
    "dot", "convolution", "fusion", "copy", "transpose",
    "scatter", "gather", "dynamic-slice",
    "reduce", "sort", "custom-call", "select-and-scatter", "concatenate",
    "pad", "reverse", "cumsum",
} | set(_COLLECTIVES)


def _parse_shapes(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _shape_bytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    op: str
    out_shapes: list
    operands: list[str]
    attrs: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # instr name -> shapes
    by_name: dict = field(default_factory=dict)  # instr name -> Instr


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0   # fusion-boundary reads+writes (upper bound)
    bytes_written: float = 0.0    # outputs only (optimistic-fusion lower bound)
    collective_bytes: dict = field(default_factory=dict)
    collective_counts: dict = field(default_factory=dict)
    # (op, payload dtype) -> moved bytes: separates the int8 compressed
    # gradient payload from fp32 scales/loss psums in the wire report
    collective_dtype_bytes: dict = field(default_factory=dict)
    unknown_trip_loops: int = 0

    def scaled(self, k: float) -> "HloCost":
        return HloCost(
            self.flops * k, self.bytes_accessed * k, self.bytes_written * k,
            {o: b * k for o, b in self.collective_bytes.items()},
            {o: c * k for o, c in self.collective_counts.items()},
            {o: b * k for o, b in self.collective_dtype_bytes.items()},
            self.unknown_trip_loops,
        )

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        self.bytes_accessed += other.bytes_accessed
        self.bytes_written += other.bytes_written
        for o, b in other.collective_bytes.items():
            self.collective_bytes[o] = self.collective_bytes.get(o, 0) + b
        for o, c in other.collective_counts.items():
            self.collective_counts[o] = self.collective_counts.get(o, 0) + c
        for o, b in other.collective_dtype_bytes.items():
            self.collective_dtype_bytes[o] = \
                self.collective_dtype_bytes.get(o, 0) + b
        self.unknown_trip_loops += other.unknown_trip_loops

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _split_operands(arg_text: str) -> list[str]:
    """Names of %operands at the top level of op(...)."""
    return re.findall(r"%([\w\.\-]+)", arg_text)


def parse_module(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = None
    current: Computation | None = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):            # possible computation header
            m = _HEADER_RE.match(line)
            if m:
                is_entry, name, params = m.group(1), m.group(2), m.group(3)
                current = Computation(name)
                comps[name] = current
                if is_entry:
                    entry = name
                # parameter shapes from the signature
                for pm in re.finditer(r"([\w\.\-]+):\s*([^,()]+(?:\([^)]*\))?)",
                                      params):
                    current.shapes[pm.group(1)] = _parse_shapes(pm.group(2))
                continue
            if line.startswith("}"):
                current = None
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # split "TYPE op(args), attrs" — op token = word right before '('
        om = re.search(r"([\w\-]+)\(", rhs)
        if not om:
            continue
        op = om.group(1)
        type_part = rhs[: om.start()]
        rest = rhs[om.end():]
        depth = 1
        i = 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        arg_text, attrs = rest[: i - 1], rest[i:]
        shapes = _parse_shapes(type_part)
        instr = Instr(name, op, shapes, _split_operands(arg_text), attrs)
        current.instrs.append(instr)
        current.shapes[name] = shapes
        current.by_name[name] = instr
    assert entry is not None, "no ENTRY computation found"
    return comps, entry


def _dot_flops(instr: Instr, comp: Computation) -> float:
    if len(instr.operands) < 2:
        return 0.0
    lhs = comp.shapes.get(instr.operands[0], [])
    rhs = comp.shapes.get(instr.operands[1], [])
    if not lhs or not rhs:
        return 0.0
    lhs_dims = lhs[0][1]
    rhs_dims = rhs[0][1]
    def dims_of(key):
        m = re.search(key + r"=\{([\d,]*)\}", instr.attrs)
        return [int(x) for x in m.group(1).split(",") if x] if m else []
    rc = set(dims_of("rhs_contracting_dims"))
    rb = set(dims_of("rhs_batch_dims"))
    lhs_prod = 1
    for d in lhs_dims:
        lhs_prod *= d
    rhs_free = 1
    for i, d in enumerate(rhs_dims):
        if i not in rc and i not in rb:
            rhs_free *= d
    return 2.0 * lhs_prod * rhs_free


def _conv_flops(instr: Instr, comp: Computation) -> float:
    out_elems = 0
    for dt, dims in instr.out_shapes:
        n = 1
        for d in dims:
            n *= d
        out_elems += n
    m = re.search(r"window=\{size=([\dx]+)", instr.attrs)
    ksize = 1
    if m:
        for d in m.group(1).split("x"):
            ksize *= int(d)
    return 2.0 * out_elems * ksize


def _group_size(attrs: str, default: int = 2) -> int:
    m = _GROUPS_V1_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_V2_RE.search(attrs)
    if m:
        return int(m.group(2))
    return default


def _trip_count(instr: Instr, comps: dict[str, Computation]) -> int | None:
    m = _TRIP_RE.search(instr.attrs)
    if m:
        return int(m.group(1))
    # fallback: constant bound in the loop condition
    cm = re.search(r"condition=%?([\w\.\-]+)", instr.attrs)
    if cm and cm.group(1) in comps:
        for ci in comps[cm.group(1)].instrs:
            if ci.op == "constant":
                vm = re.search(r"constant\((\d+)\)", ci.attrs) or \
                     re.search(r"constant\((\d+)\)", ci.name)
                if vm:
                    return int(vm.group(1))
        # constants may appear inline: constant(61)
        for ci in comps[cm.group(1)].instrs:
            pass
    return None


def _cost_of(comp_name: str, comps: dict[str, Computation],
             memo: dict, flops_only: bool = False) -> HloCost:
    key = (comp_name, flops_only)
    if key in memo:
        return memo[key]
    memo[key] = HloCost()          # cycle guard
    comp = comps[comp_name]
    cost = HloCost()
    for instr in comp.instrs:
        op = instr.op
        if op == "dot":
            cost.flops += _dot_flops(instr, comp)
        elif op == "convolution":
            cost.flops += _conv_flops(instr, comp)
        elif op == "while":
            body = re.search(r"body=%?([\w\.\-]+)", instr.attrs)
            cond = re.search(r"condition=%?([\w\.\-]+)", instr.attrs)
            trip = _trip_count(instr, comps)
            if trip is None:
                trip = 1
                cost.unknown_trip_loops += 1
            if body and body.group(1) in comps:
                cost.add(_cost_of(body.group(1), comps, memo,
                                  flops_only).scaled(trip))
            if cond and cond.group(1) in comps:
                cost.add(_cost_of(cond.group(1), comps, memo,
                                  flops_only).scaled(trip))
            continue
        elif op == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                  instr.attrs)
            names = (_split_operands(branches[0]) if branches else
                     [m.group(1) for m in re.finditer(
                         r"(?:true|false)_computation=%?([\w\.\-]+)",
                         instr.attrs)])
            best = None
            for nm in names:
                if nm in comps:
                    c = _cost_of(nm, comps, memo, flops_only)
                    if best is None or c.flops + c.bytes_accessed > \
                            best.flops + best.bytes_accessed:
                        best = c
            if best:
                cost.add(best)
            continue
        elif op == "fusion":
            fm = re.search(r"calls=%?([\w\.\-]+)", instr.attrs)
            if fm and fm.group(1) in comps:
                # descend for FLOPs only (dots can be fused); bytes are
                # counted at the kernel boundary below
                cost.add(_cost_of(fm.group(1), comps, memo,
                                  flops_only=True))
        elif op == "call" or op == "async-start":
            fm = re.search(r"(?:to_apply|calls|called_computation)"
                           r"=%?([\w\.\-]+)", instr.attrs)
            if fm and fm.group(1) in comps:
                cost.add(_cost_of(fm.group(1), comps, memo, flops_only))
            continue

        base_op = op.removesuffix("-start").removesuffix("-done")
        if base_op in _COLLECTIVES:
            if flops_only or op.endswith("-done"):
                continue
            # per-dtype byte accounting: a multi-operand collective has a
            # TUPLE output (e.g. `(s8[1024], f32[8]) all-reduce(...)` for
            # a compressed payload + its scales), and each element's
            # bytes must land under its OWN dtype — keying everything on
            # the first element would silently misfile the mix.
            per_dtype: dict[str, int] = {}
            for dt, dims in instr.out_shapes:
                n = 1
                for d in dims:
                    n *= d
                per_dtype[dt] = per_dtype.get(dt, 0) + n * _DTYPE_BYTES[dt]
            # async-start outputs carry the operands alongside the
            # results: halve each dtype's share (the tuple repeats every
            # element once as input, once as output)
            if op.endswith("-start"):
                per_dtype = {dt: b // 2 for dt, b in per_dtype.items()}
            # XLA:CPU float-normalization promotes bf16 collectives to f32
            # (promoted reduction computations / converts hoisted before
            # the collective); XLA:TPU moves bf16 natively — count wire
            # bytes at the logical width. Only the f32 SHARE can be a
            # promoted bf16 one: int8 compressed payloads also come out
            # of a convert fusion (f32 -> s8 quantize) and must NOT be
            # halved, so non-f32 tuple elements keep their width.
            promoted = "f32" in per_dtype and "_promoted" in instr.attrs
            if not promoted and "f32" in per_dtype and instr.operands:
                producer = comp.by_name.get(instr.operands[0])
                if producer is not None and (
                        producer.op == "convert"
                        or "convert" in producer.name):
                    promoted = True
            if promoted:
                # logical width the wire actually moves
                per_dtype["bf16"] = per_dtype.get("bf16", 0) + \
                    per_dtype.pop("f32") // 2
            if base_op == "all-reduce":
                mult = 2.0
            elif base_op == "reduce-scatter":
                mult = float(_group_size(instr.attrs))
            else:
                mult = 1.0
            moved_total = 0.0
            for dt, b in per_dtype.items():
                moved = mult * b
                moved_total += moved
                cost.collective_dtype_bytes[(base_op, dt)] = \
                    cost.collective_dtype_bytes.get((base_op, dt), 0.0) \
                    + moved
            cost.collective_bytes[base_op] = \
                cost.collective_bytes.get(base_op, 0.0) + moved_total
            cost.collective_counts[base_op] = \
                cost.collective_counts.get(base_op, 0) + 1
            continue  # ICI traffic — keep out of the HBM bytes term

        if not flops_only and op not in _NO_BYTES and op != "reshape":
            if op == "dynamic-update-slice":
                # TPU updates donated buffers in place: traffic is the
                # update slice (read + write), not the full cache copy
                upd = (_shape_bytes(comp.shapes.get(instr.operands[1], []))
                       if len(instr.operands) > 1 else 0)
                cost.bytes_accessed += 2 * upd
                cost.bytes_written += upd
                continue
            out_b = _shape_bytes(instr.out_shapes)
            b = out_b
            if op in _READ_OPS or op.removesuffix("-start") in _READ_OPS:
                for o in instr.operands:
                    b += _shape_bytes(comp.shapes.get(o, []))
            cost.bytes_accessed += b
            cost.bytes_written += out_b
    memo[key] = cost
    return cost


def analyze_hlo(hlo_text: str) -> HloCost:
    comps, entry = parse_module(hlo_text)
    return _cost_of(entry, comps, {})


def collective_report(hlo_text: str) -> dict:
    """Back-compat wrapper: trip-count-aware collective table.

    ``by_dtype`` splits the per-op wire bytes by payload dtype (keys
    ``"op/dtype"``) — the view that shows the compressed gradient sync
    moving int8 payloads + a sliver of fp32 scales instead of fp32
    buckets.
    """
    cost = analyze_hlo(hlo_text)
    return {
        "counts": {k: int(v) for k, v in cost.collective_counts.items()},
        "bytes": {k: round(v) for k, v in cost.collective_bytes.items()},
        "by_dtype": {f"{op}/{dt}": round(v) for (op, dt), v in
                     sorted(cost.collective_dtype_bytes.items())},
        "total_bytes": round(cost.total_collective_bytes),
    }


def _as_cost(hlo: "str | HloCost") -> HloCost:
    return hlo if isinstance(hlo, HloCost) else analyze_hlo(hlo)


def wire_byte_ratio(hlo: "str | HloCost",
                    baseline: "str | HloCost") -> float:
    """Per-device collective wire bytes of ``hlo`` relative to
    ``baseline`` (compiled HLO text or pre-parsed costs).

    This is the gate for the compressed gradient sync: the int8-EF step
    must come in at <= ~0.3x of the fp32 step's gradient-sync traffic
    (ISSUE-5 acceptance; the two-phase protocol's ideal is 0.25x + the
    fp32-scale sliver). Both steps run the same program shape otherwise,
    so the total-collective ratio IS the gradient-sync ratio on the
    manual (pure-DP) program.
    """
    base = _as_cost(baseline).total_collective_bytes
    return _as_cost(hlo).total_collective_bytes / max(base, 1e-30)


def same_collective_schedule(a: "str | HloCost",
                             b: "str | HloCost") -> bool:
    """True iff two compiled steps carry the identical collective
    schedule — same op counts AND same per-op moved bytes. The
    masked-vs-unmasked invariant (failure masking is weight data, zero
    extra collectives) must hold with compression on or off."""
    ca, cb = _as_cost(a), _as_cost(b)
    return (ca.collective_counts == cb.collective_counts
            and ca.collective_bytes == cb.collective_bytes)
