"""Repo-wide static analysis driver: ``python -m repro.launch.lint``.

One command runs every :mod:`repro.analysis` pass and renders one
deterministic report:

* **AST passes** (always, in-process, jax-free): the determinism lint
  and the thread-shared-state audit over every ``.py`` file under
  ``src/ tests/ benchmarks/ examples/``.
* **HLO passes** (``--hlo`` / ``--assert-clean``): donation audit,
  hot-path purity, wire-dtype policy, and collective-schedule
  determinism over the compiled production programs. These fan out as
  subprocesses because each target pins its own emulated device count
  *before* jax initializes: the five dryrun matrix cells re-lower at
  512 devices (via :func:`repro.launch.dryrun.lower_cell` — the exact
  jit sites CI compiles), and one certification child at 8 devices
  sweeps the live :class:`~repro.exec.executor.MeshExecutor` variants
  over the FULL RECTLR-recoverable survivor space, the reshaped-mesh
  executables of :class:`~repro.elastic.ElasticMeshExecutor` after a
  degraded-continue shrink, the demoted-set program a gray-failure
  SPARe demotion (``repro.health``) switches to, plus the
  :class:`~repro.train.trainer.SpareTrainer` jit site and every
  :class:`~repro.serve.engine.ExecutableCache` program of a warmed
  :class:`~repro.serve.engine.ServeEngine`.

Exit status: 0 unless ``--assert-clean`` is given and any unsuppressed
violation survives — the CI ``static-analysis`` job gates on exactly
that. ``--json`` prints the machine report (byte-identical across
runs); ``--out FILE`` writes it as the CI artifact.

Internal child modes (spawned by the driver, usable directly when
debugging one target): ``--cell ARCH SHAPE [--multi-pod]`` and
``--certify-executors``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

from repro.analysis import Report, run_ast_passes

# the CI dryrun green-up matrix (one cell per model family); the lint
# gate certifies the same five programs it compiles
MATRIX_CELLS = (
    ("qwen2.5-3b", "train_4k", False),
    ("deepseek-v2-lite-16b", "train_4k", False),
    ("mamba2-1.3b", "long_500k", False),
    ("jamba-v0.1-52b", "decode_32k", True),
    ("musicgen-medium", "prefill_32k", True),
)


# ------------------------------------------------------------------ #
# child: one dryrun cell at 512 emulated devices                     #
# ------------------------------------------------------------------ #
def run_cell_passes(arch: str, shape: str, multi_pod: bool) -> Report:
    # importing dryrun pins XLA_FLAGS to 512 host devices — must happen
    # in a fresh process (this one), never in the jax-free parent
    from repro.analysis import (donation_audit, hot_path_purity,
                                schedule_determinism_cell, wire_dtype_policy)
    from repro.launch.dryrun import SHAPES, lower_cell

    report = Report()
    mesh = "2x16x16" if multi_pod else "16x16"
    kind = SHAPES[shape].kind
    # train cells sweep the stack depth (S_A rises as failures consume
    # redundancy); double-compile certification runs at the base depth
    depths = (1, 2) if kind == "train" else (1,)
    for s_a in depths:
        lowered, meta = lower_cell(arch, shape, multi_pod, s_a=s_a)
        tag = f"cell:{arch}/{shape}/{mesh}@S_A={s_a}"
        if lowered is None:
            report.note("cells", **{f"{tag} skipped": meta["reason"]})
            continue
        text = lowered.compile().as_text()

        donate, arg_leaves = meta["donate"], meta["arg_leaves"]
        donated_leaves = sum(arg_leaves[i] for i in donate)
        rng = None
        if donate:
            rng = (sum(arg_leaves[:min(donate)]),
                   sum(arg_leaves[:max(donate) + 1]))
        report.extend(donation_audit(text, donated_leaves, tag,
                                     donated_range=rng))
        report.extend(hot_path_purity(text, tag))
        report.extend(wire_dtype_policy(text, tag))
        if s_a == depths[0]:
            relowered, _ = lower_cell(arch, shape, multi_pod, s_a=s_a)
            report.extend(schedule_determinism_cell(
                text, relowered.compile().as_text(), tag,
                weights_shape=meta["weights_shape"]))
        report.note("cells", programs_certified=1,
                    donated_leaves_audited=donated_leaves)
    return report


# ------------------------------------------------------------------ #
# child: live executors / trainer / serve cache at 8 devices         #
# ------------------------------------------------------------------ #
def certify_executors() -> Report:
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    import jax
    import jax.numpy as jnp

    from repro.analysis import (donation_audit, hot_path_purity,
                                schedule_determinism_executor,
                                wire_dtype_policy)
    from repro.analysis.hlo_passes import ef_state_policy
    from repro.configs import smoke_config
    from repro.exec.executor import MeshExecutor

    leaves = lambda t: len(jax.tree_util.tree_leaves(t))  # noqa: E731
    report = Report()
    cfg = smoke_config("qwen2.5-3b").scaled(grad_accum=1)

    # every sync variant of the production step, swept over the FULL
    # RECTLR-recoverable survivor space (n=4, r=2: all singles + the
    # doubles the controller can mask)
    variants = [("shard_map", None), ("gspmd", None),
                ("shard_map", "int8_ef")]
    for sync, compress in variants:
        tag = f"executor:{sync}" + (f"+{compress}" if compress else "")
        ex = MeshExecutor(cfg, sync=sync, grad_compress=compress,
                          n_groups=4, redundancy=2, model_degree=2,
                          seq=32, per_type_batch=2, total_steps=50)
        text = ex.compiled_step_text()
        report.extend(donation_audit(text, ex.donated_leaves(), tag))
        report.extend(hot_path_purity(text, tag))
        report.extend(wire_dtype_policy(text, tag))
        report.extend(ef_state_policy(ex, tag))
        found, certified = schedule_determinism_executor(ex, tag)
        report.extend(found)
        report.note("collective-schedule-determinism",
                    survivor_sets_certified=certified)
        report.note("donation-audit",
                    donated_leaves_audited=ex.donated_leaves())

    # the elastic tier's reshaped-mesh executables: shrink past an
    # unmaskable adjacent pair (DP 8 -> 4 on the survivor submesh) and
    # certify the degraded-shape programs with the same passes, plus
    # the full RECTLR survivor sweep at the shrunken shape
    from repro.elastic import ElasticMeshExecutor

    for compress in (None, "int8_ef"):
        tag = "executor:elastic-reshaped" + (f"+{compress}" if compress
                                             else "")
        elx = ElasticMeshExecutor(cfg, sync="shard_map",
                                  grad_compress=compress, n_groups=8,
                                  redundancy=2, model_degree=1,
                                  seq=32, per_type_batch=2, total_steps=50)
        elx.reshape([0, 1])
        text = elx.compiled_step_text()
        report.extend(donation_audit(text, elx.donated_leaves(), tag))
        report.extend(hot_path_purity(text, tag))
        report.extend(wire_dtype_policy(text, tag))
        report.extend(ef_state_policy(elx, tag))
        found, certified = schedule_determinism_executor(elx, tag)
        report.extend(found)
        report.note("collective-schedule-determinism",
                    survivor_sets_certified=certified)
        report.note("donation-audit",
                    donated_leaves_audited=elx.donated_leaves())
        elx.close()

    # the gray tier's demoted-set executables: a fail-slow group
    # proactively masked out of the weighted sync runs the SAME mesh
    # shape one stack deeper — certify the demoted program with the full
    # pass set through the real demote path, then re-admit and record
    # that the weight table restored
    import numpy as np

    from repro.health.detector import HealthReport
    from repro.train.injection import ScriptedInjector
    from repro.train.trainer import TrainReport as _TrainReport

    tag = "executor:demoted"
    dex = MeshExecutor(cfg, sync="shard_map", n_groups=4, redundancy=2,
                       model_degree=2, seq=32, per_type_batch=2,
                       total_steps=50)
    factors = np.ones(4)
    factors[0] = 3.0
    hr = HealthReport(step=0, smoothed=factors * 64.0, zscores=factors,
                      factors=factors, flagged=(0,), newly_flagged=(0,))
    dinj = ScriptedInjector({}, seconds_per_step=64.0, n_groups=4)
    dex._demote([0], hr, dinj, _TrainReport())
    text = dex.compiled_step_text()
    report.extend(donation_audit(text, dex.donated_leaves(), tag))
    report.extend(hot_path_purity(text, tag))
    report.extend(wire_dtype_policy(text, tag))
    report.extend(ef_state_policy(dex, tag))
    found, certified = schedule_determinism_executor(dex, tag)
    report.extend(found)
    report.note("collective-schedule-determinism",
                survivor_sets_certified=certified)
    report.note("donation-audit",
                donated_leaves_audited=dex.donated_leaves())
    dex._readmit([0], hr, dinj, _TrainReport())
    report.note("cells", demoted_programs_certified=1,
                readmit_schedule_restored=int(
                    bool(dex.state.alive.all())
                    and int(dex.state.s_a) == 1))
    dex.close()

    # the emulation trainer's jit site (donate_argnums=(0, 1))
    from repro.data.pipeline import spare_batch
    from repro.train.trainer import SpareTrainer, TrainReport

    tr = SpareTrainer(cfg, n_groups=4, redundancy=2, seq=32,
                      per_type_batch=2, total_steps=50)
    batch = {k: jnp.asarray(v) for k, v in
             spare_batch(tr.pipeline, tr.state, 0).items()}
    fn = tr._compiled(tr.state.s_a, TrainReport())
    text = fn.lower(tr.params, tr.opt_state, batch).compile().as_text()
    donated = leaves(tr.params) + leaves(tr.opt_state)
    report.extend(donation_audit(text, donated, "trainer:spare"))
    report.extend(hot_path_purity(text, "trainer:spare"))
    report.note("donation-audit", donated_leaves_audited=donated)

    # every AOT program a warmed ServeEngine can ever run
    from repro.models.model import build_model
    from repro.serve import ServeEngine, pool_pages_for

    scfg = smoke_config("qwen2.5-3b")
    model = build_model(scfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, n_slots=2,
                         n_pages=pool_pages_for(2, 8 + 4, 4),
                         page_size=4, max_new=4, buckets=(8,))
    engine.warmup()
    for key, text, donated in engine.cache.programs():
        tag = "serve:" + "/".join(str(k) for k in key)
        report.extend(donation_audit(text, donated, tag))
        report.extend(hot_path_purity(text, tag))
        report.extend(wire_dtype_policy(text, tag))
        report.note("donation-audit", donated_leaves_audited=donated)
    report.note("cells", serve_programs_certified=len(engine.cache._exe))
    return report


# ------------------------------------------------------------------ #
# parent driver                                                      #
# ------------------------------------------------------------------ #
def _spawn(extra: list[str], out: Path, label: str) -> str | None:
    """Run one child lint mode; return its JSON report, or an error."""
    cmd = [sys.executable, "-m", "repro.launch.lint", *extra,
           "--child-out", str(out)]
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)      # each child pins its own device count
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    if proc.returncode != 0 or not out.exists():
        tail = (proc.stderr or proc.stdout or "")[-2000:]
        return f"child {label} failed (exit {proc.returncode}): {tail}"
    return None


def run_hlo_passes(report: Report, progress=lambda msg: None) -> None:
    from repro.analysis import Violation
    with tempfile.TemporaryDirectory(prefix="repro-lint-") as td:
        jobs = []
        for i, (arch, shape, multi_pod) in enumerate(MATRIX_CELLS):
            extra = ["--cell", arch, shape]
            if multi_pod:
                extra.append("--multi-pod")
            jobs.append((extra, Path(td) / f"cell{i}.json",
                         f"cell:{arch}/{shape}"))
        jobs.append((["--certify-executors"],
                     Path(td) / "executors.json", "certify-executors"))
        for extra, out, label in jobs:
            progress(f"[lint] {label} ...")
            err = _spawn(extra, out, label)
            if err:
                report.extend([Violation(label, 0, "analysis-driver", err)])
            else:
                report.merge_json(out.read_text())


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.lint",
        description="SPARe static analysis: determinism lint + compiled "
                    "SPMD invariant verification")
    ap.add_argument("--root", default=".",
                    help="repo root for the AST walk (default: cwd)")
    ap.add_argument("--hlo", action="store_true",
                    help="also certify compiled programs (subprocess "
                         "fan-out over dryrun cells + live executors)")
    ap.add_argument("--assert-clean", action="store_true",
                    help="run everything; exit 1 on any violation")
    ap.add_argument("--json", action="store_true",
                    help="print the machine report instead of text")
    ap.add_argument("--out", help="also write the JSON report here")
    # internal child modes
    ap.add_argument("--cell", nargs=2, metavar=("ARCH", "SHAPE"),
                    help=argparse.SUPPRESS)
    ap.add_argument("--multi-pod", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--certify-executors", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--child-out", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.cell or args.certify_executors:
        report = (run_cell_passes(args.cell[0], args.cell[1],
                                  args.multi_pod)
                  if args.cell else certify_executors())
        payload = report.to_json()
        if args.child_out:
            Path(args.child_out).write_text(payload)
        else:
            print(payload)
        return 0

    report = Report()
    run_ast_passes(args.root, report)
    if args.hlo or args.assert_clean:
        run_hlo_passes(report, progress=lambda m: print(m, file=sys.stderr))

    if args.out:
        Path(args.out).write_text(report.to_json())
    print(report.to_json() if args.json else report.render_text())
    if args.assert_clean and not report.clean:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
