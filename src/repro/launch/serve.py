"""Serving launcher: batched greedy decoding with a KV/SSM cache.

``python -m repro.launch.serve --arch mamba2-1.3b --tokens 32`` runs a
smoke-scale batch of requests end to end (prefill + decode loop) and
reports tokens/s. On TPU the same driver jits ``serve_step`` with the
production shardings (what the decode_* dry-run cells lower).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import smoke_config
    from repro.models import build_model
    from repro.train import make_serve_step

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    serve_step = jax.jit(make_serve_step(model), donate_argnums=(1,))

    rng = np.random.default_rng(args.seed)
    b = args.batch
    s_max = args.prompt_len + args.tokens
    state = model.init_decode_state(batch=b, s_max=s_max)
    prompt = rng.integers(0, cfg.vocab, (b, args.prompt_len), dtype=np.int32)
    embeds = (rng.normal(size=(b, 1, cfg.d_model)).astype(np.float32)
              if cfg.frontend else None)

    # prefill token-by-token through the decode path (cache-filling)
    tok = jnp.asarray(prompt[:, :1])
    for t in range(args.prompt_len):
        logits, state = serve_step(
            params, state, jnp.int32(t),
            tokens=None if cfg.frontend else jnp.asarray(prompt[:, t:t + 1]),
            embeds=None if not cfg.frontend else jnp.asarray(embeds))
    next_tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1)[:, None]

    t0 = time.time()
    generated = [next_tok]
    for t in range(args.prompt_len, s_max - 1):
        logits, state = serve_step(
            params, state, jnp.int32(t),
            tokens=None if cfg.frontend else generated[-1],
            embeds=None if not cfg.frontend else jnp.asarray(embeds))
        generated.append(jnp.argmax(logits[:, :cfg.vocab], axis=-1)[:, None])
    jax.block_until_ready(generated[-1])
    dt = time.time() - t0
    n_tok = b * len(generated)
    out = np.concatenate([np.asarray(g) for g in generated], axis=1)
    print(f"[serve] arch={args.arch} batch={b} generated "
          f"{len(generated)} tokens/request in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s aggregate)")
    print(f"[serve] sample: {out[0][:16].tolist()}")


if __name__ == "__main__":
    main()
