"""Serving launcher: continuous-batching decode over SPARe-masked replicas.

``python -m repro.launch.serve --arch qwen2.5-3b --requests 16`` runs the
full serving tier end to end on CPU: a deterministic
:class:`~repro.data.pipeline.RequestStream` feeds a
:class:`~repro.serve.replicas.ReplicaServer` (paged KV cache, fused
prefill, per-slot decode), optionally under a live failure campaign:

    python -m repro.launch.serve --arch qwen2.5-3b --requests 16 \\
        --replicas 3 \\
        --failure-model '{"kind": "correlated", "scope": "rack",
                          "burst_prob": 1.0, "mtbf": 400.0}'

Reports aggregate tokens/s, p50/p99 per-token latency, and the replica
event log; exits non-zero if any admitted request failed to complete
while a replica survived, or if anything compiled after warmup (the
SPARe no-recompile gate). ``benchmarks/serving_bench.py`` wraps the same
loop to record healthy-vs-degraded numbers in ``BENCH_serving.json``.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.obs.metrics import latency_stats  # noqa: F401 — re-exported;
# the one implementation (exact-quantile histograms incl. p99.9) shared
# with benchmarks/serving_bench.py


def build_server(args, cfg, model, params, telemetry=None):
    from repro.serve import ReplicaServer, pool_pages_for

    injector = None
    if args.failure_model:
        from repro.des.params import DESParams
        from repro.scenarios.topology import ClusterTopology
        from repro.train import ScenarioInjector
        topo = (ClusterTopology(**json.loads(args.topology))
                if args.topology else
                ClusterTopology(n_groups=args.replicas, hosts_per_group=1,
                                hosts_per_rack=1))
        injector = ScenarioInjector(
            json.loads(args.failure_model), topo, n_groups=args.replicas,
            seconds_per_step=args.seconds_per_step,
            params=DESParams(n=args.replicas), seed=args.seed)

    ckpt = None
    if args.ckpt_dir:
        from repro.ckpt import CheckpointManager
        ckpt = CheckpointManager(args.ckpt_dir, n_groups=args.replicas,
                                 redundancy=1, mtbf=1e6, t_save=1.0,
                                 t_restart=1.0)

    buckets = tuple(int(b) for b in args.buckets.split(","))
    kwargs = dict(
        n_slots=args.slots, page_size=args.page_size, max_new=args.max_new,
        buckets=buckets,
        n_pages=pool_pages_for(args.slots, max(buckets) + args.max_new,
                               args.page_size))
    return ReplicaServer(model, params, n_replicas=args.replicas,
                         injector=injector, ckpt=ckpt, engine_kwargs=kwargs,
                         telemetry=telemetry)


def serve_and_measure(srv, requests):
    """Drive the server to drain; return (finished, wall_seconds)."""
    for req in requests:
        srv.submit(req)
    t0 = time.perf_counter()
    done = srv.run()
    return done, time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots per replica")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--buckets", default="8,16",
                    help="prompt-length buckets (one prefill executable "
                         "each; prompts are exact-length, never padded)")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--failure-model", default=None,
                    help='failure-model JSON, e.g. \'{"kind": '
                         '"correlated", "scope": "rack", ...}\'')
    ap.add_argument("--topology", default=None,
                    help="ClusterTopology JSON (defaults to one replica "
                         "per rack)")
    ap.add_argument("--seconds-per-step", type=float, default=100.0)
    ap.add_argument("--ckpt-dir", default=None,
                    help="enables the wipe-out reload path")
    ap.add_argument("--report-json", default=None)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record telemetry and write a Perfetto-loadable "
                         "trace (per-replica prefill/decode/admit/evict "
                         "lanes + failure markers); metrics snapshot at "
                         "PATH.metrics.json")
    args = ap.parse_args()

    import jax

    from repro.configs import smoke_config
    from repro.data import RequestStream
    from repro.models import build_model
    from repro.obs import Telemetry

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    # metrics always on (counters are cheap and the no-recompile gate
    # reads the snapshot); span recording only with --trace
    tel = Telemetry(trace=args.trace is not None)
    srv = build_server(args, cfg, model, params, telemetry=tel)
    srv.warmup()
    frozen = tel.snapshot()["counters"]["serve.exec_cache.misses"]
    buckets = tuple(int(b) for b in args.buckets.split(","))
    stream = RequestStream(cfg, buckets=buckets, max_new=args.max_new,
                           seed=args.seed)
    done, wall = serve_and_measure(srv, stream.requests(args.requests))

    stats = latency_stats(done)
    report = {
        "arch": args.arch,
        **srv.report(),
        **stats,
        "wall_s": round(wall, 3),
        "tokens_per_s": round(stats["tokens"] / wall, 2) if wall else None,
        "requests": args.requests,
        "completed_requests": len(done),
    }
    print(json.dumps(report, indent=1))
    if args.report_json:
        with open(args.report_json, "w") as fh:
            json.dump(report, fh, indent=1)
    if args.trace:
        tel.dump_trace(args.trace)
        tel.metrics.dump(args.trace + ".metrics.json")
        print(f"[serve] trace -> {args.trace} (analyze: python -m "
              f"repro.launch.obs {args.trace})")

    assert len(done) == args.requests, (
        f"dropped {args.requests - len(done)} requests")
    # the frozen-recompiles gate reads the METRICS SNAPSHOT — the cache's
    # counters are the registry's, so snapshot and cache cannot diverge
    snap = tel.snapshot()
    assert snap["counters"]["serve.exec_cache.misses"] == frozen, (
        f"recompiled after warmup: "
        f"{snap['counters']['serve.exec_cache.misses'] - frozen} misses")


if __name__ == "__main__":
    main()
