import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
SPMD-partitions, compiles, and fits — without touching real hardware.

The two lines above MUST precede any jax import (jax locks the device
count on first init); smoke tests and benches never import this module,
so they keep seeing 1 device.

Per cell this script:
  1. builds the production mesh (16,16) or (2,16,16);
  2. jits the real train / prefill / serve step with the production
     in/out shardings (donated params+opt);
  3. ``.lower().compile()`` — any sharding mismatch, unsupported
     collective, or compile-time OOM fails the cell;
  4. records ``memory_analysis()`` (per-device bytes: proves it fits 16 GB
     HBM), ``cost_analysis()`` (per-device FLOPs/bytes), and the
     collective-traffic table parsed from ``compiled.as_text()`` —
     the §Roofline inputs.

Results append to ``benchmarks/results/dryrun/*.json`` (one file per
cell, so a sweep can resume after interruption).

Usage:
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --list
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, applicable, get_config
from repro.dist.sharding import batch_spec, cache_specs, param_specs
from repro.launch.hlo import analyze_hlo
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import build_model
from repro.optim import adamw_init
from repro.train import make_prefill, make_serve_step, make_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# TPU v5e per-chip constants (§Roofline)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg, shape, mesh, multi_pod: bool, s_a: int = 1):
    """ShapeDtypeStructs + shardings for one cell's step inputs."""
    bspec = batch_spec(shape.global_batch, mesh, multi_pod)
    if shape.kind == "train":
        n_micro = s_a * cfg.grad_accum
        b_micro = shape.global_batch // cfg.grad_accum
        batch = {"labels": _sds((n_micro, b_micro, shape.seq), jnp.int32),
                 "weights": _sds((n_micro, b_micro), jnp.float32)}
        shard = {"labels": P(None, bspec, None),
                 "weights": P(None, bspec)}
        if cfg.frontend:
            batch["embeds"] = _sds((n_micro, b_micro, shape.seq, cfg.d_model),
                                   jnp.bfloat16)
            shard["embeds"] = P(None, bspec, None, None)
        else:
            batch["tokens"] = _sds((n_micro, b_micro, shape.seq), jnp.int32)
            shard["tokens"] = P(None, bspec, None)
        return batch, shard
    if shape.kind == "prefill":
        b = shape.global_batch
        if cfg.frontend:
            return ({"embeds": _sds((b, shape.seq, cfg.d_model), jnp.bfloat16)},
                    {"embeds": P(bspec, None, None)})
        return ({"tokens": _sds((b, shape.seq), jnp.int32)},
                {"tokens": P(bspec, None)})
    # decode
    b = shape.global_batch
    if cfg.frontend:
        return ({"embeds": _sds((b, 1, cfg.d_model), jnp.bfloat16)},
                {"embeds": P(bspec, None, None)})
    return ({"tokens": _sds((b, 1), jnp.int32)},
            {"tokens": P(bspec, None)})


def model_flops_per_device(cfg, shape, n_devices: int) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference),
    expressed per device to match cost_analysis granularity."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq
        return 6.0 * n_active * tokens / n_devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq
        return 2.0 * n_active * tokens / n_devices
    return 2.0 * n_active * shape.global_batch / n_devices


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               s_a: int = 1, overrides: dict | None = None):
    """Lower one cell's production jit-site and return ``(lowered,
    meta)``. This is THE jit call the sweep certifies — ``run_cell``
    compiles it for the roofline record, and the static analyzer
    (``repro.analysis`` via ``python -m repro.launch.lint``) re-lowers
    it to audit donation aliasing, hot-path purity, wire dtypes, and
    collective-schedule determinism on the byte-identical program.

    ``meta`` carries what the HLO passes need but the compiled text
    alone cannot recover: the per-argument flat leaf counts
    (``arg_leaves``), the donated argnums, and the expected per-device
    shape of the SPARe weight-table input (``weights_shape``, train
    cells only — the liveness check that proves masking reaches the
    program as runtime data).
    """
    cfg = get_config(arch)
    attn_chunk = 1024
    if overrides:
        overrides = dict(overrides)
        attn_chunk = overrides.pop("__attn_chunk", 1024)
        if overrides:
            cfg = cfg.scaled(**overrides)
    shape = SHAPES[shape_name]
    ok_run, why = applicable(cfg, shape)
    if not ok_run:
        return None, {"skipped": True, "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg, mesh=mesh, dp_axes=dp_axes(multi_pod),
                        attn_chunk=attn_chunk)

    p_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_spec = param_specs(p_shapes, cfg, multi_pod)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_spec)

    batch, bspec_tree = input_specs(cfg, shape, mesh, multi_pod, s_a)
    b_shard = {k: NamedSharding(mesh, v) for k, v in bspec_tree.items()}
    n_leaves = lambda t: len(jax.tree_util.tree_leaves(t))  # noqa: E731

    meta = {"devices": mesh.size, "kind": shape.kind}
    with mesh:
        if shape.kind == "train":
            opt_shapes = jax.eval_shape(
                lambda p: adamw_init(p, moment_dtype=cfg.moment_dtype),
                p_shapes)
            o_spec = type(opt_shapes)(
                step=P(), mu=jax.tree.map(lambda s: s, p_spec),
                nu=jax.tree.map(lambda s: s, p_spec))
            o_shard = jax.tree.map(
                lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
                o_spec, is_leaf=lambda x: isinstance(x, P))
            step_fn = make_train_step(model, grad_shardings=p_shard)
            jitted = jax.jit(step_fn,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(p_shapes, opt_shapes, batch)
            from repro.launch.mesh import dp_degree
            w = batch["weights"]
            meta.update(
                donate=(0, 1),
                arg_leaves=[n_leaves(p_shapes), n_leaves(opt_shapes),
                            n_leaves(batch)],
                weights_shape=(f"f32[{w.shape[0]},"
                               f"{w.shape[1] // dp_degree(mesh, multi_pod)}]"))
        elif shape.kind == "prefill":
            fn = make_prefill(model)
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard.get("tokens"),
                                               b_shard.get("embeds")),
                             out_shardings=None)
            lowered = jitted.lower(p_shapes, batch.get("tokens"),
                                   batch.get("embeds"))
            meta.update(donate=(), arg_leaves=[
                n_leaves(p_shapes), n_leaves(batch.get("tokens")),
                n_leaves(batch.get("embeds"))], weights_shape=None)
        else:  # decode
            cache_shapes = jax.eval_shape(
                lambda: model.init_decode_state(shape.global_batch, shape.seq))
            c_spec = cache_specs(cache_shapes, cfg, mesh, multi_pod)
            c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_spec)
            fn = make_serve_step(model)
            jitted = jax.jit(
                fn,
                in_shardings=(p_shard, c_shard, None,
                              b_shard.get("tokens"), b_shard.get("embeds")),
                out_shardings=(None, c_shard),
                donate_argnums=(1,))
            lowered = jitted.lower(p_shapes, cache_shapes,
                                   jax.ShapeDtypeStruct((), jnp.int32),
                                   batch.get("tokens"), batch.get("embeds"))
            meta.update(donate=(1,), arg_leaves=[
                n_leaves(p_shapes), n_leaves(cache_shapes), 1,
                n_leaves(batch.get("tokens")),
                n_leaves(batch.get("embeds"))], weights_shape=None)
    return lowered, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             s_a: int = 1, variant: str = "baseline",
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides and any(k != "__attn_chunk" for k in overrides):
        cfg = cfg.scaled(**{k: v for k, v in overrides.items()
                            if k != "__attn_chunk"})
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "variant": variant, "s_a": s_a, "ok": False}

    t0 = time.perf_counter()
    lowered, meta = lower_cell(arch, shape_name, multi_pod, s_a=s_a,
                               overrides=overrides)
    if lowered is None:
        rec.update(skipped=True, reason=meta["reason"], ok=True)
        return rec
    n_dev = meta["devices"]
    rec["lower_s"] = round(time.perf_counter() - t0, 1)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.perf_counter() - t1, 1)

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # pre-0.5 jax: one dict per program
        ca = ca[0] if ca else {}
    # trip-count-aware accounting (XLA's cost_analysis visits while bodies
    # once — useless for scan-over-layers; see repro/launch/hlo.py)
    hc = analyze_hlo(compiled.as_text())
    colls = {
        "counts": {k: int(v) for k, v in hc.collective_counts.items()},
        "bytes": {k: round(v) for k, v in hc.collective_bytes.items()},
        "total_bytes": round(hc.total_collective_bytes),
    }

    flops = hc.flops
    bytes_accessed = hc.bytes_accessed
    coll_bytes = hc.total_collective_bytes
    mf = model_flops_per_device(cfg, shape, n_dev)

    rec.update(
        ok=True,
        devices=n_dev,
        arg_bytes=int(ma.argument_size_in_bytes),
        out_bytes=int(ma.output_size_in_bytes),
        temp_bytes=int(ma.temp_size_in_bytes),
        alias_bytes=int(ma.alias_size_in_bytes),
        peak_bytes=int(ma.argument_size_in_bytes + ma.temp_size_in_bytes
                       + ma.output_size_in_bytes - ma.alias_size_in_bytes),
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        xla_flops_loop_body_once=float(ca.get("flops", 0.0)),
        unknown_trip_loops=hc.unknown_trip_loops,
        collectives=colls,
        model_flops_per_device=mf,
        useful_flops_ratio=(mf / flops if flops else 0.0),
        roofline={
            "compute_s": flops / PEAK_FLOPS,
            # fusion-boundary reads+writes: the cost_analysis-convention
            # upper bound on HBM traffic (XLA:TPU fuses more aggressively)
            "memory_s": bytes_accessed / HBM_BW,
            # outputs-only: optimistic-fusion lower bound
            "memory_lb_s": hc.bytes_written / HBM_BW,
            "collective_s": coll_bytes / ICI_BW,
        },
    )
    terms = {k: rec["roofline"][k]
             for k in ("compute_s", "memory_s", "collective_s")}
    rec["bottleneck"] = max(terms, key=terms.get)
    return rec


def cell_list():
    cells = []
    for arch in ARCHS:
        for shape_name in SHAPES:
            for multi_pod in (False, True):
                cells.append((arch, shape_name, multi_pod))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--s-a", type=int, default=1,
                    help="all-reduce stack depth to lower (SPARe S_A)")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (python literal), "
                         "e.g. --set remat_policy='dots'")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out-dir", default=str(RESULTS_DIR))
    args = ap.parse_args()

    if args.list:
        for arch, shape, mp in cell_list():
            print(f"{arch} {shape} {'2x16x16' if mp else '16x16'}")
        return

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    tag = f"{args.arch}__{args.shape}__{mesh_name}__{args.variant}"
    import ast
    overrides = {}
    for kv in args.set:
        k, _, v = kv.partition("=")
        overrides[k] = ast.literal_eval(v)
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod,
                       s_a=args.s_a, variant=args.variant,
                       overrides=overrides or None)
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash the sweep
        rec = {"arch": args.arch, "shape": args.shape, "mesh": mesh_name,
               "variant": args.variant, "ok": False,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    status = "OK" if rec.get("ok") else "FAIL"
    if rec.get("skipped"):
        status = "SKIP"
    print(f"[{status}] {tag} "
          f"compile={rec.get('compile_s', '-')}s "
          f"peak={rec.get('peak_bytes', 0)/2**30:.2f}GiB "
          f"bottleneck={rec.get('bottleneck', '-')}")
    if not rec.get("ok"):
        print(rec.get("error", ""))
        raise SystemExit(1)


if __name__ == "__main__":
    main()
