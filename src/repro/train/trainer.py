"""SpareTrainer — the paper's Alg. 1 as an executable training loop.

Glues every substrate together:

  data pipeline  ->  SPARe schedule (stacks, weights)   [host, RECTLR]
       |                      |
       v                      v
  jitted train_step(params, opt, stacked_batch)          [device, SPMD]
       |
  checkpoint manager (Eq.-1 interval, in-memory snapshot + disk)

Failure handling per Alg. 1, delegated to a pluggable
:class:`repro.des.FaultToleranceScheme` (the *same* scheme objects the
DES simulates — ``trainer.scheme.recover(state, failed)`` is the
protocol decision point shared by both):
  * injected node failures are detected "at the all-reduce" — i.e. the
    trainer consults the injector after dispatching a step and, on
    failure, discards that step's update (the all-reduce failed), asks
    the scheme for a recovery decision (RECTLR for SPARe), performs
    patch compute by re-dispatching with the updated schedule, and
    continues;
  * injectors may be plain callables (``injector(state) -> [groups]``,
    e.g. :class:`PoissonInjector`) or a scenario bridge exposing
    ``poll(state) -> [StepEvent]`` (:class:`repro.train.injection
    .ScenarioInjector`): each event's victim batch — a whole rack/pod
    blast radius at once — reaches ``scheme.recover`` in ONE call, and
    every recovery outcome is recorded in ``TrainReport.events``;
  * wipe-out -> global restart: state.reset(), rollback to the last
    in-memory snapshot (always kept, even with no checkpoint directory)
    or disk checkpoint;
  * S_A changes recompile the step once per depth (cached).

The trainer runs the *real protocol* at laptop scale (N groups emulated
in one process, weights mask dead groups' contributions); the same code
paths scale to the production mesh — the dry-run lowers exactly this
``train_step``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.core import Rectlr, SpareState
from repro.data import ShardedTokenPipeline, spare_batch
from repro.des import DESParams, FaultToleranceScheme, get_scheme
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.obs.trace import Telemetry, maybe_span
from repro.optim import adamw_init
from repro.train.step import make_train_step

__all__ = ["SpareTrainer", "PoissonInjector", "TrainReport",
           "RecoveryEvent"]


class PoissonInjector:
    """Host-side failure injector: exponential arrivals in *step* time.

    ``mean_steps_between_failures`` is the *system* mean when ``n_groups``
    is 0 (the default), or the *per-group* mean when ``n_groups`` is
    given — the aggregate arrival rate then scales with cluster size
    (``mean / n_groups`` steps between system failures), matching the
    DES's rate-∝-active-GPUs failure model.
    """

    def __init__(self, mean_steps_between_failures: float, seed: int = 0,
                 n_groups: int = 0):
        self.rng = np.random.default_rng(seed)
        self.mean = (mean_steps_between_failures / n_groups if n_groups > 0
                     else mean_steps_between_failures)
        self.next_at = self.rng.exponential(self.mean)
        self.clock = 0.0

    def __call__(self, state: SpareState) -> list[int]:
        self.clock += 1.0
        failed = []
        while self.clock >= self.next_at:
            survivors = state.survivors
            if survivors.size:
                failed.append(int(self.rng.choice(survivors)))
            self.next_at += self.rng.exponential(self.mean)
        return failed


@dataclass
class RecoveryEvent:
    """Outcome of one failure event's ``scheme.recover`` call."""

    step: int                        # trainer step at detection
    victims: list[int]               # simultaneous-kill set (>=1 group)
    wipeout: bool
    reordered: bool
    patch_count: int
    s_a_before: int
    s_a_after: int
    moves: int = 0
    rollback_depth: int = 0          # steps rolled back (wipe-out only)
    grad_check_err: float | None = None   # §3.1 relative error, if verified
    # -- elastic recovery tier (repro.elastic): an unmaskable failure --
    # -- set absorbed by shrinking the DP degree instead of restarting --
    reshape: bool = False            # degraded-continue took the event
    dp_before: int = 0               # DP degree before the reshape
    dp_after: int = 0                # DP degree training continues at
    # -- gray-failure tier (repro.health): fail-slow groups masked out --
    # -- of the weighted sync, and masked back in when they heal       --
    demote: bool = False             # victims were alive-but-slow, masked
    readmit: bool = False            # healed victims rejoined the sync
    slow_factor: float = 0.0         # detector's slowdown estimate
    # -- durations (the obs CLI's attribution table keys off these) -- #
    wall_seconds: float = 0.0        # host wall-clock handling the event
    step_seconds: float = 0.0        # step-clock cost: controller time for
    #                                  a mask, rollback_depth x sec/step
    #                                  for a wipe-out
    restart_seconds: float = 0.0     # modeled outage (t_restart, wipe-outs)
    reshape_seconds: float = 0.0     # modeled resharding outage (reshapes)

    @property
    def multi_group(self) -> bool:
        return len(self.victims) > 1


@dataclass
class TrainReport:
    steps_done: int = 0
    losses: list = field(default_factory=list)
    failures: int = 0
    wipeouts: int = 0
    reshapes: int = 0
    demotes: int = 0
    readmits: int = 0
    reorders: int = 0
    patches: int = 0
    recompiles: int = 0
    ckpt_saves: int = 0
    controller_seconds: float = 0.0
    events: list = field(default_factory=list)   # list[RecoveryEvent]

    @property
    def multi_group_events(self) -> int:
        return sum(1 for e in self.events if e.multi_group)

    @property
    def rollback_steps(self) -> int:
        return sum(e.rollback_depth for e in self.events)

    @property
    def max_grad_check_err(self) -> float:
        errs = [e.grad_check_err for e in self.events
                if e.grad_check_err is not None]
        return max(errs) if errs else 0.0


class SpareTrainer:
    def __init__(self, cfg: ModelConfig, *, n_groups: int, redundancy: int,
                 seq: int = 128, per_type_batch: int = 2, seed: int = 0,
                 ckpt_dir: str | None = None, mtbf: float = 300.0,
                 t_save: float = 60.0, t_restart: float = 3600.0,
                 base_lr: float = 3e-4, total_steps: int = 1000,
                 scheme: FaultToleranceScheme | None = None,
                 telemetry: Telemetry | None = None,
                 detector=None):
        self.cfg = cfg
        self.telemetry = telemetry
        self.state = SpareState(n_groups, redundancy)
        # recovery policy: any registered FaultToleranceScheme; defaults to
        # SPARe (Alg. 1/2). `ctl` stays exposed for direct controller pokes
        # (tests, deep dives) and aliases the scheme's own controller so
        # both views mutate the same bookkeeping.
        self.scheme = scheme if scheme is not None \
            else get_scheme("spare", r=redundancy)
        self.scheme.prepare(DESParams(n=n_groups, mtbf=mtbf, t_save=t_save,
                                      t_restart=t_restart))
        self._t_restart = float(t_restart)   # modeled outage per wipe-out
        self.ctl = getattr(self.scheme, "ctl", None) or Rectlr()
        self.model = build_model(cfg)
        self.pipeline = ShardedTokenPipeline(cfg, seq, per_type_batch,
                                             seed=seed)
        key = jax.random.PRNGKey(seed)
        self.params = self.model.init(key)
        self.opt_state = adamw_init(self.params,
                                    moment_dtype=cfg.moment_dtype)
        self._base_lr = float(base_lr)
        self.total_steps = int(total_steps)
        self._step_fn = make_train_step(self.model, base_lr=base_lr,
                                        total_steps=total_steps)
        self._jitted: dict[int, Any] = {}       # S_A -> compiled step
        self.ckpt = None
        if ckpt_dir is not None:
            self.ckpt = CheckpointManager(
                ckpt_dir, n_groups=n_groups, redundancy=redundancy,
                mtbf=mtbf, t_save=t_save, t_restart=t_restart)
        # in-memory snapshot fallback when no checkpoint directory is
        # configured: a wipe-out must still roll params/step back (the
        # memory tier is free — it needs no storage at all)
        self._snapshot: tuple[int, Any] | None = None
        self.step = 0
        # gray-failure tier: an optional repro.health.StragglerDetector
        # fed each step from the injector's per-group timings; flagged
        # stragglers may be demoted (masked out of the weighted sync)
        # and are re-admitted bit-identically when they heal
        self.detector = detector
        self.health_log: list[dict] = []
        self._demoted: set[int] = set()
        # (stacks, hosts, alive, s_a, supplier, schedule_version) taken
        # just before the demoting recover(); restoring it on re-admit
        # reproduces the pre-demotion weight table bit-for-bit as long
        # as no other recovery touched the schedule in between
        self._demote_snapshot: tuple | None = None
        self._schedule_version = 0

    # ---------------------------------------------------------------- #
    def _compiled(self, s_a: int, report: TrainReport):
        if s_a not in self._jitted:
            self._jitted[s_a] = jax.jit(self._step_fn, donate_argnums=(0, 1))
            report.recompiles += 1
            if self.telemetry is not None:
                self.telemetry.counter("train.recompiles").inc()
        return self._jitted[s_a]

    def _dispatch(self, report: TrainReport):
        batch_np = spare_batch(self.pipeline, self.state, self.step)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        fn = self._compiled(self.state.s_a, report)
        return fn(self.params, self.opt_state, batch)

    # ---------------------------------------------------------------- #
    # snapshot tiers                                                   #
    # ---------------------------------------------------------------- #
    def _snapshot_now(self) -> None:
        """Record the rollback point: the CheckpointManager's memory tier
        when one is configured, else the trainer's own host-side copy —
        a wipe-out must never keep post-failure params."""
        if self.ckpt is not None:
            self.ckpt.snapshot(self.step, (self.params, self.opt_state))
        else:
            self._snapshot = (self.step, jax.tree.map(
                np.asarray, (self.params, self.opt_state)))

    def _rollback(self) -> tuple[int, Any]:
        if self.ckpt is not None:
            return self.ckpt.rollback()
        assert self._snapshot is not None, "no snapshot taken yet"
        return self._snapshot

    def _snapshot_step(self) -> int:
        """Step of the current rollback point WITHOUT restoring it — the
        rollback-depth estimate recovery policies cost restarts with."""
        snap = self.ckpt._snapshot if self.ckpt is not None \
            else self._snapshot
        return snap[0] if snap is not None else self.step

    def _poll_events(self, injector) -> list[list[int]]:
        """One victim batch per failure event this step. A scenario
        bridge (``poll``) yields per-event blast radii; a plain callable
        yields at most one merged batch."""
        if injector is None:
            return []
        poll = getattr(injector, "poll", None)
        if poll is not None:
            return [ev.victims for ev in poll(self.state)]
        failed = injector(self.state)
        return [list(failed)] if failed else []

    # ---------------------------------------------------------------- #
    # recovery-tier hooks (repro.elastic overrides these)              #
    # ---------------------------------------------------------------- #
    def _event_victims(self, victims: list[int]) -> list[int]:
        """Map one event's victim ids into the trainer's group space.
        Identity here; the elastic executor polls on PHYSICAL group ids
        and translates through its survivor map, so events that land
        after a reshape still resolve against the live mesh."""
        return victims

    def _unmaskable_action(self, victims: list[int], injector) -> str:
        """Decide what an unmaskable failure set costs: ``"restart"``
        (wipe-out rollback, the only option here) or ``"reshape"``
        (continue degraded on a survivor submesh — the elastic tier)."""
        return "restart"

    def _apply_reshape(self, event: RecoveryEvent, victims: list[int],
                       injector, report: TrainReport) -> None:
        """Shrink onto the surviving devices and continue. Only the
        elastic executor implements this; the base trainer never routes
        here because :meth:`_unmaskable_action` always restarts."""
        raise NotImplementedError(
            "elastic reshaping needs repro.elastic.ElasticMeshExecutor")

    def _global_restart(self) -> None:
        """Wipe-out: every group comes back at full capacity (the
        modeled cluster restart) before the rollback restores params.
        Degraded hardware is swapped during the outage, so demotion
        and detector history reset with it."""
        self.state.reset()
        self._demoted.clear()
        self._demote_snapshot = None
        self._schedule_version += 1
        if self.detector is not None:
            self.detector.reset()

    # ---------------------------------------------------------------- #
    # gray-failure tier: straggler detection -> demote / re-admit      #
    # ---------------------------------------------------------------- #
    def _mask_feasible(self, victims: list[int]) -> bool:
        """Would masking ``victims`` out of the sync leave every shard
        type covered? Probed on a scratch copy because RECTLR mutates
        ``alive``/``supplier`` before its wipe-out short-circuit."""
        import copy
        probe = copy.deepcopy(self.state)
        return not Rectlr().on_failures(probe, list(victims)).wipeout

    def _degraded_dp_new(self, victims: list[int]) -> int:
        """DP degree an elastic reshape excluding ``victims`` would
        continue at; 0 here — the base trainer has no elastic tier."""
        return 0

    def _health_tick(self, injector, report: TrainReport) -> None:
        """One detector observation per completed step: feed per-group
        modeled timings, then act on verdict changes — demote freshly
        flagged stragglers (when the degraded-TTT policy says so) and
        re-admit demoted groups the detector has cleared."""
        det = self.detector
        if det is None or injector is None:
            return
        timings_fn = getattr(injector, "group_step_seconds", None)
        if timings_fn is None:
            return
        timings = np.asarray(timings_fn(), dtype=np.float64)
        if timings.shape != self.state.alive.shape:
            return      # post-reshape logical/physical mismatch: skip
        # demoted groups are schedule-dead but physically alive: keep
        # observing them (their flag must persist until the episode
        # actually heals, else demote/re-admit would flap)
        live = self.state.alive.copy()
        for g in self._demoted:
            live[g] = True
        hr = det.observe(timings, alive=live, step=self.step)
        tel = self.telemetry
        if tel is not None:
            tel.gauge("health.flagged").set(len(hr.flagged))
            for g in hr.newly_flagged:
                tel.instant("straggler", track=f"dp/{g}",
                            args={"step": self.step})
            for g in hr.newly_cleared:
                tel.instant("healed", track=f"dp/{g}",
                            args={"step": self.step})

        # re-admission first: a healed group rejoins before new
        # demotions are weighed, so the policy sees the true barrier
        healed = [g for g in sorted(self._demoted)
                  if g not in hr.flagged and not self.state.alive[g]]
        if healed:
            self._readmit(healed, hr, injector, report)

        candidates = [g for g in hr.flagged
                      if g not in self._demoted and self.state.alive[g]]
        if not candidates:
            return
        maskable = self._mask_feasible(candidates)
        sps = float(getattr(injector, "seconds_per_step", 0.0) or 0.0)
        kw = dict(
            factors=hr.factors, candidates=candidates,
            remaining_steps=max(self.total_steps - self.step, 1),
            seconds_per_step=sps, dp_full=self.state.n,
            dp_new=self._degraded_dp_new(candidates), maskable=maskable,
            alive=self.state.alive, demoted=sorted(self._demoted),
            rollback_steps=max(self.step - self._snapshot_step(), 0),
            t_restart=self._t_restart)
        decide = getattr(self.scheme, "decide_degraded", None)
        if decide is not None:
            action = decide(**kw)
        else:
            from repro.health.policy import degraded_ttt_estimates
            action = degraded_ttt_estimates(
                **{k: v for k, v in kw.items()},
                t_reshape=float("inf"))["action"]
        self.health_log.append({
            "step": self.step, "candidates": list(candidates),
            "factors": [round(float(hr.factors[g]), 4)
                        for g in candidates],
            "maskable": maskable, "action": action})
        if action == "demote":
            self._demote(candidates, hr, injector, report)
        elif action == "restart":
            self._health_restart(candidates, hr, injector, report)
        elif action == "reshape":
            self._health_reshape(candidates, hr, injector, report)
        # "tolerate": keep everyone in the barrier, observe again next
        # step — the episode may heal on its own

    def _demote(self, groups: list[int], hr, injector,
                report: TrainReport) -> None:
        """SPARe-demote alive-but-slow ``groups``: mask them out of the
        weighted sync exactly as a failure would — a pure weight-table
        edit through the scheme's controller — while remembering the
        pre-demotion schedule for bit-identical re-admission."""
        tel = self.telemetry
        st = self.state
        snap = (st.stacks.copy(), st.alive.copy(), int(st.s_a),
                st.supplier.copy())
        factor = max(float(hr.factors[g]) for g in groups)
        ev_args = {"step": self.step, "victims": list(groups),
                   "demote": True}
        with maybe_span(tel, "recover", args=ev_args):
            outcome = self.scheme.recover(st, list(groups),
                                          step=self.step)
            self._schedule_version += 1
            if outcome.wipeout:     # feasibility probe said otherwise
                raise RuntimeError(
                    f"demotion of {groups} wiped out the schedule "
                    f"despite passing the feasibility probe")
            self._demote_snapshot = (snap, self._schedule_version)
            self._demoted.update(int(g) for g in groups)
            notify = getattr(injector, "notify_demoted", None)
            if notify is not None:
                notify(groups, True)
            event = RecoveryEvent(
                step=self.step, victims=list(groups), wipeout=False,
                reordered=outcome.reordered,
                patch_count=outcome.patch_count,
                s_a_before=outcome.s_a_before,
                s_a_after=outcome.s_a_after, moves=outcome.moves,
                demote=True, slow_factor=factor)
            event.step_seconds = outcome.controller_seconds
            ev_args.update(s_a_before=outcome.s_a_before,
                           s_a_after=outcome.s_a_after,
                           wipeout=False)
        event.wall_seconds = 0.0
        report.controller_seconds += outcome.controller_seconds
        report.demotes += 1
        report.reorders += int(outcome.reordered)
        report.patches += outcome.patch_count
        report.events.append(event)
        if tel is not None:
            tel.counter("health.demotes").inc()
            tel.gauge("train.s_a").set(outcome.s_a_after)

    def _readmit(self, groups: list[int], hr, injector,
                 report: TrainReport) -> None:
        """Fold healed ``groups`` back into the weighted sync. The fast
        path restores the pre-demotion schedule snapshot verbatim —
        bit-identical to an always-healthy run's weight table. If any
        other recovery touched the schedule since the demotion, the
        snapshot is stale: rebuild from a clean reset by replaying the
        still-dead and still-demoted sets through the controller."""
        tel = self.telemetry
        st = self.state
        s_a_before = int(st.s_a)
        ev_args = {"step": self.step, "victims": list(groups),
                   "readmit": True}
        with maybe_span(tel, "recover", args=ev_args):
            snap = self._demote_snapshot
            clean = (snap is not None
                     and snap[1] == self._schedule_version
                     and set(groups) == set(self._demoted))
            if clean:
                stacks, alive, s_a, supplier = snap[0]
                st.stacks[:] = stacks
                st.alive[:] = alive
                st.s_a = s_a
                st.supplier[:] = supplier
            else:
                still_out = sorted(
                    int(w) for w in np.flatnonzero(~st.alive)
                    if w not in groups)
                st.reset()
                if still_out:
                    self.scheme.recover(st, still_out, step=self.step)
            st.assert_invariants()
            self._schedule_version += 1
            self._demote_snapshot = None
            self._demoted.difference_update(int(g) for g in groups)
            notify = getattr(injector, "notify_demoted", None)
            if notify is not None:
                notify(groups, False)
            event = RecoveryEvent(
                step=self.step, victims=list(groups), wipeout=False,
                reordered=False, patch_count=0, s_a_before=s_a_before,
                s_a_after=int(st.s_a), readmit=True)
            ev_args.update(s_a_before=s_a_before, s_a_after=int(st.s_a),
                           wipeout=False)
        report.readmits += 1
        report.events.append(event)
        if tel is not None:
            tel.counter("health.readmits").inc()
            tel.gauge("train.s_a").set(int(st.s_a))

    def _health_restart(self, groups: list[int], hr, injector,
                        report: TrainReport) -> None:
        """The policy judged the degradation worth a full restart: swap
        the slow hardware during the outage and roll back."""
        tel = self.telemetry
        ev_args = {"step": self.step, "victims": list(groups),
                   "demote": False}
        with maybe_span(tel, "recover", args=ev_args):
            report.wipeouts += 1
            self._global_restart()
            rolled_from = self.step
            self.step, (self.params, self.opt_state) = self._rollback()
            sec_per_step = float(getattr(
                injector, "seconds_per_step", 0.0) or 0.0)
            event = RecoveryEvent(
                step=rolled_from, victims=list(groups), wipeout=True,
                reordered=False, patch_count=0, s_a_before=1,
                s_a_after=1, rollback_depth=rolled_from - self.step,
                slow_factor=max(float(hr.factors[g]) for g in groups))
            event.step_seconds = event.rollback_depth * sec_per_step
            event.restart_seconds = self._t_restart
            ev_args.update(wipeout=True,
                           rollback_depth=event.rollback_depth,
                           restart_seconds=event.restart_seconds)
            notify = getattr(injector, "notify_outage", None)
            if notify is not None:
                notify(self._t_restart, kind="restart")
        report.events.append(event)
        if tel is not None:
            tel.counter("train.wipeouts").inc()
            tel.counter("train.rollback_steps").inc(event.rollback_depth)

    def _health_reshape(self, groups: list[int], hr, injector,
                        report: TrainReport) -> None:
        """Elastic escape hatch: shrink the mesh away from the slow
        groups. Only meaningful where :meth:`_apply_reshape` exists
        (the elastic executor); the base policy never picks it because
        :meth:`_degraded_dp_new` returns 0."""
        event = RecoveryEvent(
            step=self.step, victims=list(groups), wipeout=False,
            reordered=False, patch_count=0,
            s_a_before=int(self.state.s_a), s_a_after=int(self.state.s_a),
            slow_factor=max(float(hr.factors[g]) for g in groups))
        tel = self.telemetry
        ev_args = {"step": self.step, "victims": list(groups),
                   "reshape": True}
        with maybe_span(tel, "recover", args=ev_args):
            report.reshapes += 1
            self._apply_reshape(event, list(groups), injector, report)
            self._schedule_version += 1
            # the reshape rebuilt the schedule in a new group space:
            # demotion bookkeeping does not survive it
            self._demoted.clear()
            self._demote_snapshot = None
            ev_args.update(dp_before=event.dp_before,
                           dp_after=event.dp_after,
                           reshape_seconds=event.reshape_seconds)
        report.events.append(event)
        if tel is not None:
            tel.counter("train.reshapes").inc()
            tel.gauge("train.dp_degree").set(event.dp_after)

    # ---------------------------------------------------------------- #
    def run(self, steps: int,
            injector: Callable[[SpareState], list[int]] | None = None,
            snapshot_every: int = 10,
            verify_equivalence: bool = False,
            equivalence_tol: float = 1e-2) -> TrainReport:
        report = TrainReport()
        tel = self.telemetry
        if tel is not None and injector is not None \
                and hasattr(injector, "telemetry"):
            injector.telemetry = tel    # scenario bridge reports too
        self._snapshot_now()
        target = self.step + steps
        while self.step < target:
            wiped = False
            for victims in self._poll_events(injector):
                # detection at the all-reduce: the in-flight step fails;
                # the pluggable scheme decides wipe-out vs. mask/reorder.
                # Every event's full victim batch (a rack/pod blast
                # radius at once) reaches recover() in ONE call.
                victims = self._event_victims([int(w) for w in victims])
                victims = [w for w in victims if self.state.alive[w]]
                if not victims:
                    continue
                report.failures += len(victims)
                if tel is not None:
                    tel.counter("train.failures").inc(len(victims))
                    for g in victims:
                        tel.instant("failure", track=f"dp/{g}",
                                    args={"step": self.step})
                # span args carry only schedule-deterministic fields
                # (no measured times) so seeded traces stay byte-stable
                ev_args = {"step": self.step, "victims": list(victims)}
                t_ev = time.perf_counter()
                with maybe_span(tel, "recover", args=ev_args):
                    outcome = self.scheme.recover(self.state, victims,
                                                  step=self.step)
                    # any fail-stop recovery invalidates the demotion
                    # snapshot (re-admit falls back to a clean rebuild)
                    self._schedule_version += 1
                    report.controller_seconds += outcome.controller_seconds
                    action = "mask"
                    if outcome.wipeout:
                        # the elastic tier may absorb an unmaskable set
                        # by shrinking the mesh instead of restarting
                        action = self._unmaskable_action(victims, injector)
                    event = RecoveryEvent(
                        step=self.step, victims=victims,
                        wipeout=outcome.wipeout and action != "reshape",
                        reordered=outcome.reordered,
                        patch_count=outcome.patch_count,
                        s_a_before=outcome.s_a_before,
                        s_a_after=outcome.s_a_after, moves=outcome.moves)
                    ev_args.update(wipeout=event.wipeout,
                                   s_a_before=outcome.s_a_before,
                                   s_a_after=outcome.s_a_after)
                    if action == "reshape":
                        report.reshapes += 1
                        self._apply_reshape(event, victims, injector,
                                            report)
                        event.step_seconds = outcome.controller_seconds
                        ev_args.update(
                            reshape=True, dp_before=event.dp_before,
                            dp_after=event.dp_after,
                            s_a_after=event.s_a_after,
                            reshape_seconds=event.reshape_seconds)
                    elif outcome.wipeout:
                        report.wipeouts += 1
                        self._global_restart()
                        rolled_from = self.step
                        self.step, (self.params, self.opt_state) = \
                            self._rollback()
                        event.rollback_depth = rolled_from - self.step
                        sec_per_step = float(getattr(
                            injector, "seconds_per_step", 0.0) or 0.0)
                        event.step_seconds = \
                            event.rollback_depth * sec_per_step
                        event.restart_seconds = self._t_restart
                        ev_args.update(
                            rollback_depth=event.rollback_depth,
                            restart_seconds=event.restart_seconds)
                        notify = getattr(injector, "notify_outage", None)
                        if notify is not None:
                            # outage elapsed; re-arm the arrival model
                            notify(self._t_restart, kind="restart")
                        else:
                            legacy = getattr(injector, "notify_wipeout",
                                             None)
                            if legacy is not None:
                                legacy()
                        wiped = True
                    else:
                        # masked: the step-clock cost is the controller
                        event.step_seconds = outcome.controller_seconds
                event.wall_seconds = time.perf_counter() - t_ev
                if tel is not None:
                    if event.wipeout:
                        tel.counter("train.wipeouts").inc()
                        tel.counter("train.rollback_steps").inc(
                            event.rollback_depth)
                    if event.reshape:
                        tel.counter("train.reshapes").inc()
                        tel.gauge("train.dp_degree").set(event.dp_after)
                    tel.gauge("train.s_a").set(event.s_a_after)
                if wiped:
                    report.events.append(event)
                    break   # later events hit a system already down
                report.reorders += int(outcome.reordered)
                report.patches += outcome.patch_count
                if verify_equivalence:
                    # §3.1 invariant: the recovered schedule must still
                    # collect vanilla DP's exact batch gradient
                    with maybe_span(tel, "grad_check",
                                    args={"step": self.step}):
                        event.grad_check_err = self.equivalence_error()
                    if event.grad_check_err > equivalence_tol:
                        raise RuntimeError(
                            f"§3.1 gradient equivalence violated after "
                            f"recovering {victims} at step {self.step}: "
                            f"rel err {event.grad_check_err:.3e} > "
                            f"{equivalence_tol:.3e}")
                report.events.append(event)
                # patch compute + shrink happened; schedule is consistent
                # again — the step below re-collects every type
            if wiped:
                continue
            with maybe_span(
                    tel, "step",
                    args=(None if tel is None else
                          {"step": self.step,
                           "s_a": self.state.s_a})) as step_span:
                with maybe_span(tel, "compute"):
                    new_params, new_opt, metrics = self._dispatch(report)
                    self.params, self.opt_state = new_params, new_opt
                    loss = float(metrics["loss"])   # blocks on the device
                report.losses.append(loss)
                self.step += 1
                report.steps_done += 1
                if self.step % snapshot_every == 0:
                    with maybe_span(tel, "ckpt_save"):
                        self._snapshot_now()
                        if self.ckpt is not None:
                            self.ckpt.maybe_save(
                                self.step, (self.params, self.opt_state))
                            report.ckpt_saves = self.ckpt.saves
            if tel is not None:
                tel.counter("train.steps").inc()
                tel.histogram("train.step_seconds").observe(step_span.dur)
                if step_span.dur > 0:
                    tel.gauge("train.steps_per_s").set(1.0 / step_span.dur)
            # gray-failure tier: one detector observation per completed
            # step; may demote stragglers or re-admit healed groups
            self._health_tick(injector, report)
        if self.ckpt is not None:
            self.ckpt.wait()
            # forced/trailing saves land between snapshot boundaries:
            # refresh after the final wait so the report counts them all
            report.ckpt_saves = self.ckpt.saves
        return report

    # ---------------------------------------------------------------- #
    def _batch_grads(self, batch: dict):
        """Jitted total-batch gradient (compiled once per stack shape —
        the §3.1 oracle runs after every recovery when verification is
        on, so the eager path would dominate the run)."""
        if getattr(self, "_grad_fn", None) is None:
            from repro.train.step import weighted_loss

            def total_loss(params, batch):
                def body(acc, micro):
                    return acc + weighted_loss(self.model, params,
                                               micro), None
                out, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                      batch)
                return out

            self._grad_fn = jax.jit(jax.grad(total_loss))
        return self._grad_fn(self.params, batch)

    def vanilla_reference_grads(self, step: int | None = None):
        """Vanilla-DP gradient of the same logical batch (all N types,
        weight 1/N each) — the §3.1 equivalence oracle used by tests."""
        step = self.step if step is None else step
        pristine = SpareState(self.state.n, self.state.r)
        batch_np = spare_batch(self.pipeline, pristine, step)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        return self._batch_grads(batch)

    def equivalence_error(self, step: int | None = None) -> float:
        """§3.1 check: relative gradient-equivalence error of the current
        schedule vs the vanilla-DP oracle — ``max |g_spare - g_vanilla|
        / max(max |g_vanilla|, 1)``. Zero for a healthy system; fp32
        summation-order noise only after any successful recovery."""
        # lazy: repro.exec pulls in this module at import time
        from repro.exec.equivalence import tree_max_rel_err
        return tree_max_rel_err(self.spare_grads(step),
                                self.vanilla_reference_grads(step))

    def spare_grads(self, step: int | None = None):
        """Gradient under the *current* (possibly failed/reordered)
        schedule — must equal :meth:`vanilla_reference_grads` exactly."""
        step = self.step if step is None else step
        batch_np = spare_batch(self.pipeline, self.state, step)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        return self._batch_grads(batch)
