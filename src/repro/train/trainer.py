"""SpareTrainer — the paper's Alg. 1 as an executable training loop.

Glues every substrate together:

  data pipeline  ->  SPARe schedule (stacks, weights)   [host, RECTLR]
       |                      |
       v                      v
  jitted train_step(params, opt, stacked_batch)          [device, SPMD]
       |
  checkpoint manager (Eq.-1 interval, in-memory snapshot + disk)

Failure handling per Alg. 1, delegated to a pluggable
:class:`repro.des.FaultToleranceScheme` (the *same* scheme objects the
DES simulates — ``trainer.scheme.recover(state, failed)`` is the
protocol decision point shared by both):
  * injected node failures are detected "at the all-reduce" — i.e. the
    trainer consults the injector after dispatching a step and, on
    failure, discards that step's update (the all-reduce failed), asks
    the scheme for a recovery decision (RECTLR for SPARe), performs
    patch compute by re-dispatching with the updated schedule, and
    continues;
  * wipe-out -> global restart: state.reset(), rollback to the last
    snapshot (in-memory tier) or disk checkpoint;
  * S_A changes recompile the step once per depth (cached).

The trainer runs the *real protocol* at laptop scale (N groups emulated
in one process, weights mask dead groups' contributions); the same code
paths scale to the production mesh — the dry-run lowers exactly this
``train_step``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.core import Rectlr, SpareState
from repro.data import ShardedTokenPipeline, spare_batch
from repro.des import DESParams, FaultToleranceScheme, get_scheme
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.optim import adamw_init
from repro.train.step import make_train_step

__all__ = ["SpareTrainer", "PoissonInjector", "TrainReport"]


class PoissonInjector:
    """Host-side failure injector: exponential arrivals in *step* time.

    ``mean_steps_between_failures`` is the *system* mean when ``n_groups``
    is 0 (the default), or the *per-group* mean when ``n_groups`` is
    given — the aggregate arrival rate then scales with cluster size
    (``mean / n_groups`` steps between system failures), matching the
    DES's rate-∝-active-GPUs failure model.
    """

    def __init__(self, mean_steps_between_failures: float, seed: int = 0,
                 n_groups: int = 0):
        self.rng = np.random.default_rng(seed)
        self.mean = (mean_steps_between_failures / n_groups if n_groups > 0
                     else mean_steps_between_failures)
        self.next_at = self.rng.exponential(self.mean)
        self.clock = 0.0

    def __call__(self, state: SpareState) -> list[int]:
        self.clock += 1.0
        failed = []
        while self.clock >= self.next_at:
            survivors = state.survivors
            if survivors.size:
                failed.append(int(self.rng.choice(survivors)))
            self.next_at += self.rng.exponential(self.mean)
        return failed


@dataclass
class TrainReport:
    steps_done: int = 0
    losses: list = field(default_factory=list)
    failures: int = 0
    wipeouts: int = 0
    reorders: int = 0
    patches: int = 0
    recompiles: int = 0
    ckpt_saves: int = 0
    controller_seconds: float = 0.0


class SpareTrainer:
    def __init__(self, cfg: ModelConfig, *, n_groups: int, redundancy: int,
                 seq: int = 128, per_type_batch: int = 2, seed: int = 0,
                 ckpt_dir: str | None = None, mtbf: float = 300.0,
                 t_save: float = 60.0, t_restart: float = 3600.0,
                 base_lr: float = 3e-4, total_steps: int = 1000,
                 scheme: FaultToleranceScheme | None = None):
        self.cfg = cfg
        self.state = SpareState(n_groups, redundancy)
        # recovery policy: any registered FaultToleranceScheme; defaults to
        # SPARe (Alg. 1/2). `ctl` stays exposed for direct controller pokes
        # (tests, deep dives) and aliases the scheme's own controller so
        # both views mutate the same bookkeeping.
        self.scheme = scheme if scheme is not None \
            else get_scheme("spare", r=redundancy)
        self.scheme.prepare(DESParams(n=n_groups, mtbf=mtbf, t_save=t_save,
                                      t_restart=t_restart))
        self.ctl = getattr(self.scheme, "ctl", None) or Rectlr()
        self.model = build_model(cfg)
        self.pipeline = ShardedTokenPipeline(cfg, seq, per_type_batch,
                                             seed=seed)
        key = jax.random.PRNGKey(seed)
        self.params = self.model.init(key)
        self.opt_state = adamw_init(self.params,
                                    moment_dtype=cfg.moment_dtype)
        self._step_fn = make_train_step(self.model, base_lr=base_lr,
                                        total_steps=total_steps)
        self._jitted: dict[int, Any] = {}       # S_A -> compiled step
        self.ckpt = None
        if ckpt_dir is not None:
            self.ckpt = CheckpointManager(
                ckpt_dir, n_groups=n_groups, redundancy=redundancy,
                mtbf=mtbf, t_save=t_save, t_restart=t_restart)
        self.step = 0

    # ---------------------------------------------------------------- #
    def _compiled(self, s_a: int, report: TrainReport):
        if s_a not in self._jitted:
            self._jitted[s_a] = jax.jit(self._step_fn, donate_argnums=(0, 1))
            report.recompiles += 1
        return self._jitted[s_a]

    def _dispatch(self, report: TrainReport):
        batch_np = spare_batch(self.pipeline, self.state, self.step)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        fn = self._compiled(self.state.s_a, report)
        return fn(self.params, self.opt_state, batch)

    # ---------------------------------------------------------------- #
    def run(self, steps: int,
            injector: Callable[[SpareState], list[int]] | None = None,
            snapshot_every: int = 10) -> TrainReport:
        report = TrainReport()
        if self.ckpt is not None:
            self.ckpt.snapshot(self.step, (self.params, self.opt_state))
        target = self.step + steps
        while self.step < target:
            failed = injector(self.state) if injector is not None else []
            if failed:
                # detection at the all-reduce: the in-flight step fails;
                # the pluggable scheme decides wipe-out vs. mask/reorder
                report.failures += len(failed)
                outcome = self.scheme.recover(self.state, failed,
                                              step=self.step)
                report.controller_seconds += outcome.controller_seconds
                if outcome.wipeout:
                    report.wipeouts += 1
                    self.state.reset()
                    if self.ckpt is not None:
                        self.step, (self.params, self.opt_state) = \
                            self.ckpt.rollback()
                    continue
                report.reorders += int(outcome.reordered)
                report.patches += outcome.patch_count
                # patch compute + shrink happened; schedule is consistent
                # again — the step below re-collects every type
            new_params, new_opt, metrics = self._dispatch(report)
            self.params, self.opt_state = new_params, new_opt
            report.losses.append(float(metrics["loss"]))
            self.step += 1
            report.steps_done += 1
            if self.ckpt is not None and self.step % snapshot_every == 0:
                self.ckpt.snapshot(self.step, (self.params, self.opt_state))
                self.ckpt.maybe_save(self.step,
                                     (self.params, self.opt_state))
                report.ckpt_saves = self.ckpt.saves
        if self.ckpt is not None:
            self.ckpt.wait()
        return report

    # ---------------------------------------------------------------- #
    def vanilla_reference_grads(self, step: int | None = None):
        """Vanilla-DP gradient of the same logical batch (all N types,
        weight 1/N each) — the §3.1 equivalence oracle used by tests."""
        step = self.step if step is None else step
        pristine = SpareState(self.state.n, self.state.r)
        batch_np = spare_batch(self.pipeline, pristine, step)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        from repro.train.step import weighted_loss

        def total_loss(params):
            def body(acc, micro):
                return acc + weighted_loss(self.model, params, micro), None
            out, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), batch)
            return out

        return jax.grad(total_loss)(self.params)

    def spare_grads(self, step: int | None = None):
        """Gradient under the *current* (possibly failed/reordered)
        schedule — must equal :meth:`vanilla_reference_grads` exactly."""
        step = self.step if step is None else step
        batch_np = spare_batch(self.pipeline, self.state, step)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        from repro.train.step import weighted_loss

        def total_loss(params):
            def body(acc, micro):
                return acc + weighted_loss(self.model, params, micro), None
            out, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), batch)
            return out

        return jax.grad(total_loss)(self.params)
