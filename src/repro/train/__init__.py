from .injection import ScenarioInjector, StepEvent
from .step import make_prefill, make_serve_step, make_train_step, weighted_loss

__all__ = ["make_train_step", "make_serve_step", "make_prefill",
           "weighted_loss", "ScenarioInjector", "StepEvent"]
