"""Device-side SPARe step functions.

``make_train_step(model)`` builds the jitted SPMD training step:

    (params, opt, batch) -> (params, opt, metrics)

``batch`` carries a leading *stack* axis (``S_A x grad_accum`` micro
steps). The SPARe failure-masking weights ride along as a per-example
weight vector — a dead group's slots weigh 0, the designated supplier of
each shard type weighs 1/N — so the accumulated gradient equals vanilla
DP's batch gradient for every survivor set (the §3.1 invariant; the
weighted psum over the data axis is issued by XLA from the same einsum it
would emit for plain DP: failure masking costs *zero* extra collectives).

The stack axis is scanned (gradient accumulation): activation memory is
one microbatch deep regardless of S_A, and a recompile happens only when
S_A itself changes (S_A in {1..4} in practice; each depth is compiled
once and cached).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.collectives import all_reduce_grads, weighted_all_reduce
from repro.models.model import Model
from repro.optim import adamw_update, cosine_lr

__all__ = ["weighted_loss", "make_train_step", "make_serve_step",
           "make_prefill"]


def weighted_loss(model: Model, params: Any, micro: dict,
                  axis_name: str | None = None) -> jax.Array:
    """Per-example-weighted CE over one microbatch.

    micro: tokens/embeds (b, S[, D]), labels (b, S), weights (b,).
    Returns sum_b weights[b] * mean-CE(example b). With SPARe weights this
    sums to (1/N) * sum-over-types of per-type mean loss == vanilla DP loss.

    The supplier-weighted reduction routes through
    :func:`repro.dist.collectives.weighted_all_reduce` — the single place
    the §3.1 weighted all-reduce is issued. Host-side (the emulated
    trainer) it is a weighted contraction; on a real mesh pass
    ``axis_name`` and it additionally psums across the data axis.
    """
    logits = model.forward(params, tokens=micro.get("tokens"),
                           embeds=micro.get("embeds"))
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, micro["labels"][..., None],
                                 axis=-1)[..., 0]
    ce = jnp.mean(lse - picked, axis=-1)           # (b,) per-example mean
    return weighted_all_reduce(ce, micro["weights"], axis_name=axis_name)


def make_train_step(model: Model, *, base_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000,
                    weight_decay: float = 0.1, clip_norm: float = 1.0,
                    grad_shardings=None, axis_name: str | None = None,
                    grad_sync=None):
    """Build the pure train_step; caller jits with shardings.

    ``grad_shardings`` (pytree of NamedSharding matching params) pins the
    gradient accumulator to the parameter sharding — without it GSPMD
    replicates the fp32 accumulator and all-reduces the *full* gradient
    every microbatch (measured +300 GiB/step of all-reduce on a 3B model);
    with it the backward lowers to reduce-scatters into the shard.

    ``axis_name`` is the ``shard_map`` spelling (the mesh executor):
    each device computes its *local* supplier-weighted partial gradient
    over its slice of the stacked batch, and the accumulated partials
    are psummed ONCE per step after the microbatch scan — the §3.1
    weighted all-reduce. Because the masking weights ride in the batch,
    a failure re-weight changes neither the program nor its collectives.

    ``grad_sync`` replaces the default per-leaf
    :func:`~repro.dist.collectives.all_reduce_grads` with a custom
    post-scan reduction — :class:`~repro.dist.collectives
    .BucketedAllReduce` (O(1) flat-bucket psums) or
    :class:`~repro.dist.collectives.CompressedBucketSync` (int8 EF over
    the wire). A *stateful* sync (``grad_sync.stateful``) changes the
    step signature to ``(params, opt, batch, ef_state) -> (params, opt,
    metrics, ef_state)``: the error-feedback residuals are device-local
    sharded state the caller threads (and snapshots) alongside params.
    """

    def micro_grads(params, micro):
        return jax.value_and_grad(partial(weighted_loss, model))(
            params, micro, axis_name=axis_name)

    acc_dtype = jnp.dtype(model.cfg.grad_accum_dtype)
    stateful = getattr(grad_sync, "stateful", False)

    def accumulate(params, batch):
        # batch leaves: (n_micro, b, ...) — scan-accumulate gradients
        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dtype), params)
        if grad_shardings is not None:
            zero = jax.tree.map(jax.lax.with_sharding_constraint, zero,
                                grad_shardings)

        def acc(carry, micro):
            loss_acc, g_acc = carry
            loss, g = micro_grads(params, micro)
            if grad_shardings is not None:
                # pin the per-microbatch gradient too: the accumulator
                # constraint alone still lets GSPMD all-reduce each micro
                # gradient to replicated form before the (sharded) add
                g = jax.tree.map(jax.lax.with_sharding_constraint, g,
                                 grad_shardings)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(acc_dtype), g_acc, g)
            return (loss_acc + loss, g_acc), None

        (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros((), jnp.float32), zero),
                                        batch)
        return loss, grads

    def update(params, opt_state, loss, grads):
        # step+1: opt.step counts *completed* updates; lr(0)=0 would make
        # the first update a silent no-op
        lr = cosine_lr(opt_state.step + 1, base_lr, warmup, total_steps)
        params, opt_state, gnorm = adamw_update(
            grads, opt_state, params, lr,
            weight_decay=weight_decay, clip_norm=clip_norm)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    def train_step(params, opt_state, batch):
        loss, grads = accumulate(params, batch)
        if grad_sync is not None:
            # the one gradient sync of the step, bucketed: O(1) psums
            # (or the compressed int8-EF wire protocol via train_step_ef)
            grads = grad_sync(grads)
        elif axis_name is not None:
            # per-leaf spelling: sum the accumulated (already
            # supplier-weighted) partials across the data axis
            grads = all_reduce_grads(grads, axis_name)
        return update(params, opt_state, loss, grads)

    def train_step_ef(params, opt_state, batch, ef_state):
        loss, grads = accumulate(params, batch)
        grads, ef_state = grad_sync(grads, ef_state)
        return (*update(params, opt_state, loss, grads), ef_state)

    return train_step_ef if stateful else train_step


def make_serve_step(model: Model, *, paged: bool = False):
    """One-token decode step. Greedy sampling left to the caller.

    Default (dense): ``(params, state, pos, tokens/embeds) ->
    (next_token_logits, new_state)`` with scalar ``pos`` — every row at
    the same position (the dry-run/analyze spelling).

    ``paged=True``: ``(params, state, table, pos, tokens/embeds)`` with
    ``table (B, max_pages)`` page ids and ``pos (B,)`` per-row positions
    over :meth:`Model.init_paged_state` pools — the continuous-batching
    spelling (``repro.serve.engine``), where admission/eviction are pure
    data and the step compiles exactly once.
    """
    if paged:
        def serve_step_paged(params, state, table, pos,
                             tokens=None, embeds=None):
            logits, new_state = model.decode_step_paged(
                params, state, table, pos, tokens=tokens, embeds=embeds)
            return logits[:, -1, :], new_state

        return serve_step_paged

    def serve_step(params, state, pos, tokens=None, embeds=None):
        logits, new_state = model.decode_step(
            params, state, pos, tokens=tokens, embeds=embeds)
        return logits[:, -1, :], new_state

    return serve_step


def make_prefill(model: Model, *, return_cache: bool = False):
    """Batched prefill.

    Default: run the full prompt through the train forward and return
    last-position logits only (the dry-run lowers this exact
    computation; no cache materializes).

    ``return_cache=True``: the fused cache-filling prefill —
    ``(params, tokens/embeds) -> (all_logits (B, S, V), state)`` where
    ``state`` matches :meth:`Model.init_decode_state` leaf for leaf, so
    decode can continue from position S without re-running the prompt
    token by token. Prompts must be exact-length (no right-padding): the
    SSM recurrence runs through every input token.
    """
    if return_cache:
        def prefill_cached(params, tokens=None, embeds=None):
            return model.prefill(params, tokens=tokens, embeds=embeds)

        return prefill_cached

    def prefill(params, tokens=None, embeds=None):
        logits = model.forward(params, tokens=tokens, embeds=embeds)
        return logits[:, -1, :]

    return prefill
