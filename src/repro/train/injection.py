"""Live-failure bridge: drive :class:`SpareTrainer` from repro.scenarios.

The scenario engine (PR 2) made the failure regime a pluggable axis for
the *simulator*; this module closes the loop for the real trainer. A
:class:`ScenarioInjector` binds any registered
:class:`repro.scenarios.models.FailureModel` plus a
:class:`repro.scenarios.topology.ClusterTopology` to the live training
loop:

* model arrival times (seconds) convert to the trainer's *step clock* —
  each step advances the bridge by ``seconds_per_step`` (default: the
  DES step cost ``t_comp + t_allreduce``) and every arrival landing in
  that window surfaces at the step's all-reduce;
* blast radii resolve to DP-group victim *batches* through the topology
  (a rack kill delivers all of its groups in one event), exactly the
  shared :func:`repro.scenarios.models.drain_event_window` loop the DES
  clock uses;
* the trainer delivers each event batch to ``scheme.recover`` in one
  call, so the recovery controller sees simultaneous multi-group kills —
  the path that was DES-only before this bridge;
* on wipe-out the trainer calls :meth:`notify_wipeout`: the bridge
  advances its wall clock past the restart outage and re-arms the model
  (trace replay skips events that landed while the system was down,
  renewal streams re-draw at full capacity).

The bridge satisfies the plain injector protocol
(``injector(state) -> list[int]``) for drop-in use, but
:meth:`SpareTrainer.run` detects :meth:`poll` and consumes per-event
batches so recovery outcomes are recorded event by event.
"""
from __future__ import annotations

import numpy as np

from repro.core.state import SpareState
from repro.des.params import DESParams
from repro.scenarios.models import bind_model, drain_event_window
from repro.scenarios.topology import ClusterTopology

__all__ = ["StepEvent", "ScenarioInjector", "ScriptedInjector"]


class StepEvent:
    """One failure event delivered at a step's all-reduce.

    ``victims`` is the full simultaneous-kill set (blast radius minus
    already-dead groups); ``time`` is the model's arrival clock in
    seconds; ``step`` is the bridge's own monotone poll index — it
    matches the trainer's step counter until the first wipe-out rolls
    that counter back, after which the two diverge by the cumulative
    rollback depth (the trainer-side step of each recovery is recorded
    in :class:`repro.train.trainer.RecoveryEvent.step`).
    """

    __slots__ = ("step", "time", "victims")

    def __init__(self, step: int, time: float, victims: list[int]):
        self.step = step
        self.time = time
        self.victims = list(victims)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"StepEvent(step={self.step}, time={self.time:.1f}, "
                f"victims={self.victims})")


class ScenarioInjector:
    """Step-time failure injection from a scenario model + topology.

    Parameters
    ----------
    model: failure-model spec — registry name, ``{"kind": ...}`` dict, or
        a :class:`FailureModel` instance (see :func:`model_from_spec`).
    topology: cluster layout — preset name, dict, instance, or ``None``
        for the default small layout at ``n_groups``.
    n_groups: the trainer's data-parallel degree N (must match the
        trainer this injector drives).
    seconds_per_step: wall seconds one trainer step represents on the
        model's clock; defaults to ``params.t_comp + params.t_allreduce``
        (the DES per-step cost, so DES-calibrated MTBFs carry over).
    params: :class:`DESParams` the model binds against (MTBF, Weibull
        shape, restart latency...); ``n`` is forced to ``n_groups``.
    seed: RNG seed for arrival draws and victim choices.
    """

    def __init__(self, model, topology=None, *, n_groups: int,
                 seconds_per_step: float | None = None,
                 params: DESParams | None = None, seed: int = 0):
        self.n = n_groups
        self.rng = np.random.default_rng(seed)
        self.model, self.p, self.topology = bind_model(
            model, n_groups, self.rng, topology=topology, params=params)
        self.seconds_per_step = (seconds_per_step
                                 if seconds_per_step is not None
                                 else self.p.t_comp + self.p.t_allreduce)
        if self.seconds_per_step <= 0:
            raise ValueError("seconds_per_step must be positive")
        self.clock = 0.0                 # model-time seconds elapsed
        self.step = 0                    # step windows polled
        self._next_fail = self.model.next_arrival(0.0, self.n, self.n)
        self.events_delivered = 0
        self.victims_delivered = 0
        self.outage_seconds = 0.0        # cumulative downtime accounted
        # SpareTrainer.run auto-attaches its Telemetry here (if any) so
        # injection counters land in the same metrics snapshot
        self.telemetry = None

    # ------------------------------------------------------------- #
    def poll(self, state: SpareState) -> list[StepEvent]:
        """Advance one step on the model clock; return the failure
        events whose arrival landed inside the step window, one
        :class:`StepEvent` per model event (victims already resolved to
        live DP groups through the topology)."""
        dead = set(int(w) for w in np.flatnonzero(~state.alive))
        alive = int(state.alive.sum())
        end = self.clock + self.seconds_per_step
        events, self._next_fail, _ = drain_event_window(
            self.model, self._next_fail, end, dead, alive, self.n)
        self.clock = end
        out = [StepEvent(self.step, t, victims) for t, victims in events]
        self.step += 1
        self.events_delivered += len(out)
        self.victims_delivered += sum(len(e.victims) for e in out)
        if self.telemetry is not None and out:
            self.telemetry.counter("inject.events").inc(len(out))
            self.telemetry.counter("inject.victims").inc(
                sum(len(e.victims) for e in out))
        return out

    def __call__(self, state: SpareState) -> list[int]:
        """Plain-injector protocol: the flattened victim set of every
        event in this step's window (one merged batch)."""
        return [w for ev in self.poll(state) for w in ev.victims]

    # ------------------------------------------------------------- #
    def notify_outage(self, seconds: float | None = None,
                      kind: str = "restart") -> None:
        """Account ``seconds`` of downtime on the model clock.

        ``kind="restart"`` (the wipe-out path) additionally re-arms the
        arrival stream at full capacity — trace replay drops events that
        hit the downed system, renewal models re-draw. Other kinds
        (``"reshape"``) only advance the clock: the arrival process keeps
        running because the surviving hardware stays powered through the
        reconfiguration."""
        if seconds is None:
            seconds = self.p.t_restart
        self.clock += float(seconds)
        self.outage_seconds += float(seconds)
        if kind == "restart":
            self._next_fail = self.model.reset(self.clock, self.n, self.n)

    def notify_wipeout(self) -> None:
        """Legacy alias for ``notify_outage(kind="restart")``."""
        self.notify_outage(self.p.t_restart, kind="restart")


class ScriptedInjector:
    """Deterministic injector: a fixed ``{poll index: victims}`` script.

    Used by the elastic campaign arms and CI smoke runs, where the
    benchmark needs the *same* beyond-recoverable burst at the same step
    in every arm. Satisfies both injector protocols (``poll`` and plain
    call) and the ``notify_outage`` accounting interface.
    """

    def __init__(self, schedule: dict[int, list[int]], *,
                 seconds_per_step: float = 1.0):
        self.schedule = {int(k): list(v) for k, v in schedule.items()}
        self.seconds_per_step = float(seconds_per_step)
        self.clock = 0.0
        self.step = 0
        self.outage_seconds = 0.0
        self.events_delivered = 0
        self.victims_delivered = 0
        self.telemetry = None

    def poll(self, state: SpareState) -> list[StepEvent]:
        victims = self.schedule.get(self.step, [])
        self.clock += self.seconds_per_step
        out = ([StepEvent(self.step, self.clock, victims)]
               if victims else [])
        self.step += 1
        self.events_delivered += len(out)
        self.victims_delivered += sum(len(e.victims) for e in out)
        return out

    def __call__(self, state: SpareState) -> list[int]:
        return [w for ev in self.poll(state) for w in ev.victims]

    def notify_outage(self, seconds: float | None = None,
                      kind: str = "restart") -> None:
        if seconds is None:
            seconds = 0.0
        self.clock += float(seconds)
        self.outage_seconds += float(seconds)

    def notify_wipeout(self) -> None:
        self.notify_outage(0.0, kind="restart")
