"""Live-failure bridge: drive :class:`SpareTrainer` from repro.scenarios.

The scenario engine (PR 2) made the failure regime a pluggable axis for
the *simulator*; this module closes the loop for the real trainer. A
:class:`ScenarioInjector` binds any registered
:class:`repro.scenarios.models.FailureModel` plus a
:class:`repro.scenarios.topology.ClusterTopology` to the live training
loop:

* model arrival times (seconds) convert to the trainer's *step clock* —
  each step advances the bridge by ``seconds_per_step`` (default: the
  DES step cost ``t_comp + t_allreduce``) and every arrival landing in
  that window surfaces at the step's all-reduce;
* blast radii resolve to DP-group victim *batches* through the topology
  (a rack kill delivers all of its groups in one event), exactly the
  shared :func:`repro.scenarios.models.drain_event_window` loop the DES
  clock uses;
* the trainer delivers each event batch to ``scheme.recover`` in one
  call, so the recovery controller sees simultaneous multi-group kills —
  the path that was DES-only before this bridge;
* on wipe-out the trainer calls :meth:`notify_wipeout`: the bridge
  advances its wall clock past the restart outage and re-arms the model
  (trace replay skips events that landed while the system was down,
  renewal streams re-draw at full capacity).

The bridge satisfies the plain injector protocol
(``injector(state) -> list[int]``) for drop-in use, but
:meth:`SpareTrainer.run` detects :meth:`poll` and consumes per-event
batches so recovery outcomes are recorded event by event.
"""
from __future__ import annotations

import numpy as np

from repro.core.state import SpareState
from repro.des.params import DESParams
from repro.scenarios.models import (bind_model, drain_event_window,
                                    drain_slow_window, model_from_spec)
from repro.scenarios.topology import ClusterTopology

__all__ = ["StepEvent", "ScenarioInjector", "ScriptedInjector"]


class StepEvent:
    """One failure event delivered at a step's all-reduce.

    ``victims`` is the full simultaneous-kill set (blast radius minus
    already-dead groups); ``time`` is the model's arrival clock in
    seconds; ``step`` is the bridge's own monotone poll index — it
    matches the trainer's step counter until the first wipe-out rolls
    that counter back, after which the two diverge by the cumulative
    rollback depth (the trainer-side step of each recovery is recorded
    in :class:`repro.train.trainer.RecoveryEvent.step`).
    """

    __slots__ = ("step", "time", "victims")

    def __init__(self, step: int, time: float, victims: list[int]):
        self.step = step
        self.time = time
        self.victims = list(victims)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"StepEvent(step={self.step}, time={self.time:.1f}, "
                f"victims={self.victims})")


class _SlowChannel:
    """Shared fail-slow bookkeeping for both injector flavors.

    Per-group slowdown state lives in ``_slow: {group: (factor,
    until)}``. Because every gradient sync is a barrier, the effective
    step window is ``seconds_per_step * max(factor)`` over groups that
    are alive *and still in the sync* — demoting a straggler (masking
    it out of the weighted all-reduce) removes its factor from that max
    while its degradation keeps being tracked for re-admission.
    """

    def _init_slow(self) -> None:
        self._slow: dict[int, tuple[float, float]] = {}
        self._demoted: set[int] = set()
        self.slow_events_delivered = 0
        self.last_step_seconds = float(self.seconds_per_step)
        # one entry per poll: the effective window in seconds — the
        # benchmark's per-step throughput record
        self.window_log: list[float] = []

    # ---------------------------------------------------------- #
    def slow_factor(self, group: int) -> float:
        """Current modeled slowdown factor of ``group`` (1.0 = healthy)."""
        ent = self._slow.get(int(group))
        return ent[0] if ent is not None else 1.0

    def group_step_seconds(self) -> np.ndarray:
        """Per-group modeled step seconds — what each group's local
        compute+comm would take this step. The detector's input."""
        out = np.full(self.n, float(self.seconds_per_step))
        for g, (factor, _) in self._slow.items():
            out[g] *= factor
        return out

    @property
    def demoted(self) -> frozenset[int]:
        return frozenset(self._demoted)

    def notify_demoted(self, groups, flag: bool = True) -> None:
        """Mark ``groups`` as masked out of (``flag=True``) or
        re-admitted to (``flag=False``) the synchronous step barrier."""
        if isinstance(groups, (int, np.integer)):
            groups = [groups]
        if flag:
            self._demoted.update(int(g) for g in groups)
        else:
            self._demoted.difference_update(int(g) for g in groups)

    # ---------------------------------------------------------- #
    def _apply_episode(self, groups, factor: float, until: float) -> None:
        for g in groups:
            g = int(g)
            old = self._slow.get(g)
            if old is not None:        # overlap: max factor, extend
                factor = max(factor, old[0])
                until = max(until, old[1])
            self._slow[g] = (float(factor), float(until))

    def _expire_slow(self, now: float) -> None:
        healed = [g for g, (_, until) in self._slow.items() if until <= now]
        for g in healed:
            del self._slow[g]

    def _window_factor(self, state: SpareState) -> float:
        factor = 1.0
        for g, (f, _) in self._slow.items():
            if state.alive[g] and g not in self._demoted:
                factor = max(factor, f)
        return factor

    def _clear_slow(self) -> None:
        self._slow.clear()
        self._demoted.clear()


class ScenarioInjector(_SlowChannel):
    """Step-time failure injection from a scenario model + topology.

    Parameters
    ----------
    model: failure-model spec — registry name, ``{"kind": ...}`` dict, or
        a :class:`FailureModel` instance (see :func:`model_from_spec`).
    topology: cluster layout — preset name, dict, instance, or ``None``
        for the default small layout at ``n_groups``.
    n_groups: the trainer's data-parallel degree N (must match the
        trainer this injector drives).
    seconds_per_step: wall seconds one trainer step represents on the
        model's clock; defaults to ``params.t_comp + params.t_allreduce``
        (the DES per-step cost, so DES-calibrated MTBFs carry over).
    params: :class:`DESParams` the model binds against (MTBF, Weibull
        shape, restart latency...); ``n`` is forced to ``n_groups``.
    seed: RNG seed for arrival draws and victim choices.
    slow_model: optional fail-slow stream spec (a
        :class:`repro.scenarios.models.SlowdownModel`) driven on its own
        RNG (``seed + 1`` unless ``slow_seed`` given) so adding a slow
        channel never perturbs the kill stream's pinned draw order.
    slow_seed: RNG seed for the slow channel (default ``seed + 1``).
    """

    def __init__(self, model, topology=None, *, n_groups: int,
                 seconds_per_step: float | None = None,
                 params: DESParams | None = None, seed: int = 0,
                 slow_model=None, slow_seed: int | None = None):
        self.n = n_groups
        self.rng = np.random.default_rng(seed)
        self.model, self.p, self.topology = bind_model(
            model, n_groups, self.rng, topology=topology, params=params)
        self.seconds_per_step = (seconds_per_step
                                 if seconds_per_step is not None
                                 else self.p.t_comp + self.p.t_allreduce)
        if self.seconds_per_step <= 0:
            raise ValueError("seconds_per_step must be positive")
        self.clock = 0.0                 # model-time seconds elapsed
        self.step = 0                    # step windows polled
        self._next_fail = self.model.next_arrival(0.0, self.n, self.n)
        self.events_delivered = 0
        self.victims_delivered = 0
        self.outage_seconds = 0.0        # cumulative downtime accounted
        self._init_slow()
        self.slow_model = None
        self._next_slow = float("inf")
        if slow_model is not None:
            self.slow_model = model_from_spec(slow_model)
            if not getattr(self.slow_model, "degrades", False):
                raise TypeError("slow_model must be a SlowdownModel "
                                "(fail-stop specs go in `model`)")
            self.slow_rng = np.random.default_rng(
                slow_seed if slow_seed is not None else seed + 1)
            self.slow_model.bind(self.p, self.slow_rng, self.topology)
            self._next_slow = self.slow_model.next_arrival(0.0, self.n,
                                                           self.n)
        # SpareTrainer.run auto-attaches its Telemetry here (if any) so
        # injection counters land in the same metrics snapshot
        self.telemetry = None

    # ------------------------------------------------------------- #
    def poll(self, state: SpareState) -> list[StepEvent]:
        """Advance one step on the model clock; return the failure
        events whose arrival landed inside the step window, one
        :class:`StepEvent` per model event (victims already resolved to
        live DP groups through the topology)."""
        dead = set(int(w) for w in np.flatnonzero(~state.alive))
        alive = int(state.alive.sum())
        # fail-slow channel: heal expired episodes at the window
        # boundary, then stretch this step's window by the worst factor
        # among groups still in the sync barrier (episodes arriving
        # inside the window take effect from the *next* step)
        self._expire_slow(self.clock)
        window = self.seconds_per_step * self._window_factor(state)
        self.last_step_seconds = window
        self.window_log.append(window)
        end = self.clock + window
        if self.slow_model is not None:
            episodes, self._next_slow = drain_slow_window(
                self.slow_model, self._next_slow, end, set(self._slow))
            for _, groups, factor, until in episodes:
                self._apply_episode(groups, factor, until)
            self.slow_events_delivered += len(episodes)
            if self.telemetry is not None and episodes:
                self.telemetry.counter("inject.slow_events").inc(
                    len(episodes))
        events, self._next_fail, _ = drain_event_window(
            self.model, self._next_fail, end, dead, alive, self.n)
        self.clock = end
        out = [StepEvent(self.step, t, victims) for t, victims in events]
        self.step += 1
        self.events_delivered += len(out)
        self.victims_delivered += sum(len(e.victims) for e in out)
        if self.telemetry is not None and out:
            self.telemetry.counter("inject.events").inc(len(out))
            self.telemetry.counter("inject.victims").inc(
                sum(len(e.victims) for e in out))
        return out

    def __call__(self, state: SpareState) -> list[int]:
        """Plain-injector protocol: the flattened victim set of every
        event in this step's window (one merged batch)."""
        return [w for ev in self.poll(state) for w in ev.victims]

    # ------------------------------------------------------------- #
    def notify_outage(self, seconds: float | None = None,
                      kind: str = "restart") -> None:
        """Account ``seconds`` of downtime on the model clock.

        ``kind="restart"`` (the wipe-out path) additionally re-arms the
        arrival stream at full capacity — trace replay drops events that
        hit the downed system, renewal models re-draw. Other kinds
        (``"reshape"``) only advance the clock: the arrival process keeps
        running because the surviving hardware stays powered through the
        reconfiguration."""
        if seconds is None:
            seconds = self.p.t_restart
        self.clock += float(seconds)
        self.outage_seconds += float(seconds)
        if kind == "restart":
            self._next_fail = self.model.reset(self.clock, self.n, self.n)
            # a global restart swaps/repairs degraded hardware and
            # rebuilds the full schedule: clear slow + demotion state
            # and re-arm the slow stream past the outage
            self._clear_slow()
            if self.slow_model is not None:
                self._next_slow = self.slow_model.reset(
                    self.clock, self.n, self.n)

    def notify_wipeout(self) -> None:
        """Legacy alias for ``notify_outage(kind="restart")``."""
        self.notify_outage(self.p.t_restart, kind="restart")


class ScriptedInjector(_SlowChannel):
    """Deterministic injector: a fixed ``{poll index: victims}`` script.

    Used by the elastic campaign arms and CI smoke runs, where the
    benchmark needs the *same* beyond-recoverable burst at the same step
    in every arm. Satisfies both injector protocols (``poll`` and plain
    call) and the ``notify_outage`` accounting interface.

    ``slow_schedule`` scripts the fail-slow channel deterministically:
    ``{poll_idx: [(group, factor, until_poll_idx), ...]}`` — each entry
    degrades ``group`` by ``factor`` for poll windows
    ``[poll_idx, until_poll_idx)`` (``until_poll_idx=None`` for a
    persistent episode). Requires ``n_groups`` so
    :meth:`group_step_seconds` knows its width.
    """

    def __init__(self, schedule: dict[int, list[int]], *,
                 seconds_per_step: float = 1.0,
                 slow_schedule: dict | None = None,
                 n_groups: int | None = None):
        self.schedule = {int(k): list(v) for k, v in schedule.items()}
        self.seconds_per_step = float(seconds_per_step)
        self.n = n_groups
        self.clock = 0.0
        self.step = 0
        self.outage_seconds = 0.0
        self.events_delivered = 0
        self.victims_delivered = 0
        self.telemetry = None
        self._init_slow()
        self.slow_schedule = {
            int(k): [(int(g), float(f),
                      float("inf") if until is None else float(until))
                     for g, f, until in v]
            for k, v in (slow_schedule or {}).items()}
        if self.slow_schedule and self.n is None:
            raise ValueError("slow_schedule needs n_groups")

    def group_step_seconds(self) -> np.ndarray:
        if self.n is None:
            raise ValueError("ScriptedInjector needs n_groups for "
                             "group_step_seconds()")
        return super().group_step_seconds()

    def poll(self, state: SpareState) -> list[StepEvent]:
        # scripted slow episodes: entries at this poll index take
        # effect for this window; `until` is a poll index, so the
        # slow-state clock here is the step counter, not seconds
        for g, factor, until in self.slow_schedule.get(self.step, []):
            self._apply_episode([g], factor, until)
        self._expire_slow(float(self.step))
        window = self.seconds_per_step * self._window_factor(state)
        self.last_step_seconds = window
        self.window_log.append(window)
        victims = self.schedule.get(self.step, [])
        self.clock += window
        out = ([StepEvent(self.step, self.clock, victims)]
               if victims else [])
        self.step += 1
        self.events_delivered += len(out)
        self.victims_delivered += sum(len(e.victims) for e in out)
        return out

    def __call__(self, state: SpareState) -> list[int]:
        return [w for ev in self.poll(state) for w in ev.victims]

    def notify_outage(self, seconds: float | None = None,
                      kind: str = "restart") -> None:
        if seconds is None:
            seconds = 0.0
        self.clock += float(seconds)
        self.outage_seconds += float(seconds)
        if kind == "restart":
            self._clear_slow()

    def notify_wipeout(self) -> None:
        self.notify_outage(0.0, kind="restart")
