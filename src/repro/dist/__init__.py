"""Distributed-communication substrate for the SPARe reproduction.

``repro.dist`` hosts the collective-communication helpers that sit between
the SPARe control plane (host-side schedules, supplier weights) and the
device-side SPMD program:

* :func:`repro.dist.collectives.weighted_all_reduce` — the supplier-
  weighted reduction of §3.1 (``ḡ = Σ_i w_i g_i``); inside a mapped
  computation it lowers to a single ``psum`` over the data axis, on the
  host it is the exact emulation the trainer and tests use.
* :func:`repro.dist.collectives.compress_grad_int8` /
  :func:`repro.dist.collectives.decompress_grad_int8` — int8
  error-feedback gradient quantization (beyond-paper): 4x less all-reduce
  traffic, with the residual carried forward so the long-run transmitted
  signal is unbiased.
"""
from .collectives import (
    BucketedAllReduce,
    BucketLayout,
    CompressedBucketSync,
    all_reduce_grads,
    bucket_layout,
    compress_grad_int8,
    constrain_grad,
    decompress_grad_int8,
    flatten_grads,
    psum_partial,
    shard_map_compat,
    unflatten_grads,
    weighted_all_reduce,
)
from .sharding import batch_spec, cache_specs, opt_specs, param_specs

__all__ = [
    "BucketedAllReduce",
    "BucketLayout",
    "CompressedBucketSync",
    "all_reduce_grads",
    "batch_spec",
    "bucket_layout",
    "cache_specs",
    "compress_grad_int8",
    "constrain_grad",
    "decompress_grad_int8",
    "flatten_grads",
    "opt_specs",
    "param_specs",
    "psum_partial",
    "shard_map_compat",
    "unflatten_grads",
    "weighted_all_reduce",
]
