"""Collective-communication helpers (weighted all-reduce, int8 EF compression).

SPARe's failure masking is, at the wire level, nothing but a *weighted*
gradient all-reduce: every (group, stack-slot) contributes its partial
gradient scaled by the supplier weight (``1/N`` for the designated
supplier of a shard type, ``0`` otherwise — :meth:`repro.core.SpareState
.device_schedule`), so the collected gradient equals vanilla DP's batch
gradient for every survivor set (§3.1 invariant). This module is the one
place that reduction is issued:

* on a real mesh (inside ``pmap``/``shard_map``) pass ``axis_name`` and
  the helper lowers to a single ``psum`` — failure masking costs zero
  extra collectives;
* host-side (laptop-scale emulation, trainers, tests) the same call is a
  plain weighted contraction with identical numerics.

The int8 error-feedback compressor is a beyond-paper bandwidth
optimization for the 20 TB-gradient all-reduce (paper Table 1): gradients
are quantized to int8 with a per-tensor scale (4x traffic reduction) and
the quantization residual is fed back into the next step's compression,
making the *cumulative* transmitted signal unbiased (Seide et al. 2014;
Karimireddy et al. 2019 — EF-SGD).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["weighted_all_reduce", "compress_grad_int8",
           "decompress_grad_int8"]

_INT8_MAX = 127.0


def weighted_all_reduce(values: jax.Array, weights: jax.Array,
                        axis_name: str | None = None) -> jax.Array:
    """Supplier-weighted reduction ``Σ_i weights_i · values_i``.

    ``values`` and ``weights`` share a leading contraction shape (the
    per-example / per-slot axis); the result is the scalar (or trailing-
    shape) weighted sum. With ``axis_name`` set, the local partial sum is
    additionally ``psum``-reduced across the named mapped axis — this is
    the production spelling of the §3.1 weighted all-reduce; without it,
    the call is the exact host-side emulation.
    """
    w = weights.reshape(weights.shape + (1,) * (values.ndim - weights.ndim))
    local = jnp.sum(values * w.astype(values.dtype),
                    axis=tuple(range(weights.ndim)))
    if axis_name is not None:
        local = jax.lax.psum(local, axis_name)
    return local


def compress_grad_int8(
    grad: jax.Array, error: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Int8 error-feedback quantization of one gradient tensor.

    Compresses ``grad + error`` (the fresh gradient plus the residual the
    previous step failed to transmit) to int8 with a shared per-tensor
    scale, and returns the residual to carry into the next step::

        q, scale, new_error = compress_grad_int8(grad, error)
        wire_bytes = q          # 1/4 of fp32
        restored   = decompress_grad_int8(q, scale)
        # invariant: restored + new_error == grad + error   (exactly)

    Returns ``(q, scale, new_error)`` where ``q`` is int8 with the same
    shape as ``grad``, ``scale`` is the scalar dequantization step, and
    ``new_error = (grad + error) - decompress(q, scale)``.

    The whole arithmetic runs in fp32 regardless of ``grad``'s dtype:
    :func:`decompress_grad_int8` dequantizes in fp32, so a residual
    computed in e.g. bf16 would break the exact invariant above (the
    bf16 rounding of ``x - q*scale`` diverges from the fp32 value the
    receiver reconstructs). ``error`` carries the fp32 residual between
    steps; ``new_error`` is always returned as fp32.

    The max quantization error of a single step is ``scale/2 <= scale``;
    with error feedback the *cumulative* transmitted signal converges to
    the cumulative true gradient, which is what makes aggressive 8-bit
    compression safe for SGD-family optimizers.
    """
    x = grad.astype(jnp.float32) + error.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) / _INT8_MAX
    # all-zero tensors: keep scale 0 (q == 0, decompress == 0) but avoid
    # the 0/0 in the quantization divide
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -_INT8_MAX, _INT8_MAX).astype(jnp.int8)
    new_error = x - q.astype(jnp.float32) * scale
    return q, scale, new_error


def decompress_grad_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`compress_grad_int8`: ``q * scale`` in fp32."""
    return q.astype(jnp.float32) * scale
