"""Collective-communication helpers (weighted all-reduce, int8 EF compression).

SPARe's failure masking is, at the wire level, nothing but a *weighted*
gradient all-reduce: every (group, stack-slot) contributes its partial
gradient scaled by the supplier weight (``1/N`` for the designated
supplier of a shard type, ``0`` otherwise — :meth:`repro.core.SpareState
.device_schedule`), so the collected gradient equals vanilla DP's batch
gradient for every survivor set (§3.1 invariant). This module is the one
place that reduction is issued:

* on a real mesh (inside ``pmap``/``shard_map``) pass ``axis_name`` and
  the helper lowers to a single ``psum`` — failure masking costs zero
  extra collectives;
* host-side (laptop-scale emulation, trainers, tests) the same call is a
  plain weighted contraction with identical numerics.

The int8 error-feedback compressor is a beyond-paper bandwidth
optimization for the 20 TB-gradient all-reduce (paper Table 1): gradients
are quantized to int8 with a per-tensor scale (4x traffic reduction) and
the quantization residual is fed back into the next step's compression,
making the *cumulative* transmitted signal unbiased (Seide et al. 2014;
Karimireddy et al. 2019 — EF-SGD).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["weighted_all_reduce", "psum_partial", "all_reduce_grads",
           "constrain_grad", "compress_grad_int8", "decompress_grad_int8"]


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def psum_partial(x: jax.Array, axis_name) -> jax.Array:
    """``psum`` whose inputs are *partial sums*, with the matching VJP.

    Inside ``shard_map`` each device holds its own partial contribution
    (a local weighted gradient, a local weighted loss): the derivative of
    the global sum w.r.t. a device's partial is exactly 1, so the
    backward pass is the identity. The stock ``lax.psum`` cannot know
    this — under ``check_rep=False`` its transpose is another ``psum``,
    which silently multiplies every gradient by the axis size (we
    measured exactly ``dp_degree``x on the first mesh bring-up). Routing
    the §3.1 reduction through this wrapper is what lets
    ``value_and_grad`` of a psummed loss return the correct *local*
    partial gradient, which is then all-reduced once per step.
    """
    return jax.lax.psum(x, axis_name)


def _psum_partial_fwd(x, axis_name):
    return jax.lax.psum(x, axis_name), None


def _psum_partial_bwd(axis_name, _res, ct):
    return (ct,)


psum_partial.defvjp(_psum_partial_fwd, _psum_partial_bwd)


def weighted_all_reduce(values: jax.Array, weights: jax.Array,
                        axis_name: str | None = None) -> jax.Array:
    """Supplier-weighted reduction ``Σ_i weights_i · values_i``.

    ``values`` and ``weights`` share a leading contraction shape (the
    per-example / per-slot axis); the result is the scalar (or trailing-
    shape) weighted sum. With ``axis_name`` set, the local partial sum is
    additionally ``psum``-reduced across the named mapped axis — this is
    the production spelling of the §3.1 weighted all-reduce; without it,
    the call is the exact host-side emulation. The psum is the
    partial-sum flavor (:func:`psum_partial`), so differentiating a loss
    built on this reduction yields each device's own partial gradient —
    see :func:`all_reduce_grads` for the per-step gradient sync.
    """
    w = weights.reshape(weights.shape + (1,) * (values.ndim - weights.ndim))
    local = jnp.sum(values * w.astype(values.dtype),
                    axis=tuple(range(weights.ndim)))
    if axis_name is not None:
        local = psum_partial(local, axis_name)
    return local


def all_reduce_grads(grads, axis_name: str):
    """One gradient all-reduce per step: psum every leaf of the (already
    supplier-weighted) local gradient pytree across the mapped data axis.

    This is the single collective SPARe's failure masking rides on — the
    weights folded into the per-example loss make the psummed result
    equal vanilla DP's batch gradient for every survivor set, so masking
    a failure never changes the collective schedule (paper §3.1, "zero
    extra collectives").
    """
    return jax.tree.map(lambda g: psum_partial(g, axis_name), grads)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def constrain_grad(x: jax.Array, sharding) -> jax.Array:
    """Identity forward; pins the *cotangent* to ``sharding``.

    Used to force GSPMD to reduce-scatter weight gradients to their
    shard at the point of production (inside the backward of the layer
    scan) instead of all-reducing them to replicated form inside the
    loop.
    """
    return x


def _constrain_grad_fwd(x, sharding):
    return x, None


def _constrain_grad_bwd(sharding, _res, ct):
    return (jax.lax.with_sharding_constraint(ct, sharding),)


constrain_grad.defvjp(_constrain_grad_fwd, _constrain_grad_bwd)


def compress_grad_int8(
    grad: jax.Array, error: jax.Array, *, fused: bool | None = None
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Int8 error-feedback quantization of one gradient tensor.

    Compresses ``grad + error`` (the fresh gradient plus the residual the
    previous step failed to transmit) to int8 with a shared per-tensor
    scale, and returns the residual to carry into the next step::

        q, scale, new_error = compress_grad_int8(grad, error)
        wire_bytes = q          # 1/4 of fp32
        restored   = decompress_grad_int8(q, scale)
        # invariant: restored + new_error == grad + error   (exactly)

    Returns ``(q, scale, new_error)`` where ``q`` is int8 with the same
    shape as ``grad``, ``scale`` is the scalar dequantization step, and
    ``new_error = (grad + error) - decompress(q, scale)``.

    The whole arithmetic runs in fp32 regardless of ``grad``'s dtype:
    :func:`decompress_grad_int8` dequantizes in fp32, so a residual
    computed in e.g. bf16 would break the exact invariant above (the
    bf16 rounding of ``x - q*scale`` diverges from the fp32 value the
    receiver reconstructs). ``error`` carries the fp32 residual between
    steps; ``new_error`` is always returned as fp32.

    The max quantization error of a single step is ``scale/2 <= scale``;
    with error feedback the *cumulative* transmitted signal converges to
    the cumulative true gradient, which is what makes aggressive 8-bit
    compression safe for SGD-family optimizers.

    ``fused`` routes through the Pallas quantize-accumulate kernel
    (:func:`repro.kernels.ops.int8_ef_quantize`): one VMEM pass computes
    the EF accumulate, the quantization, and the residual together
    instead of the unfused XLA chain. Defaults to the kernel on TPU and
    the plain jnp spelling elsewhere; both compute the identical fp32
    math — ``q`` and ``scale`` bit-identical, the residual up to one
    fp32 ulp (compiler FMA contraction of ``x - q*scale``; the exact
    invariant above strictly holds on the op-by-op/eager path).
    """
    if fused is None:
        from repro.kernels.ops import on_tpu
        fused = on_tpu()
    if fused:
        from repro.kernels.ops import int8_ef_quantize
        return int8_ef_quantize(grad, error)
    # the unfused spelling IS the kernel oracle — one definition of the
    # accumulate/scale/clip/residual math keeps the bit-identical
    # contract between the paths from drifting
    from repro.kernels.ref import int8_ef_ref
    return int8_ef_ref(grad, error)


def decompress_grad_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`compress_grad_int8`: ``q * scale`` in fp32."""
    return q.astype(jnp.float32) * scale
